"""Batched serving example: prefill-on-admit continuous batching with the
slot-pool scheduler, over any assigned arch — scan-cache families
(ssm/hybrid/encdec) included, served from their slot-addressable
recurrent state (pass --mode lockstep for the group-barrier baseline).

  PYTHONPATH=src python examples/serve_lm.py --arch zamba2-1.2b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.configs import list_archs, smoke_config  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serving import Request, ServeEngine  # noqa: E402

N_REQS = 6


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list_archs())
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "continuous", "lockstep"])
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    extra = {}
    if cfg.family == "vlm":
        import jax.numpy as jnp
        extra["patches"] = jnp.zeros(
            (N_REQS, cfg.n_patches, cfg.patch_embed_dim), jnp.bfloat16)
    if cfg.family == "encdec":
        import jax.numpy as jnp
        extra["frames"] = jnp.zeros((N_REQS, 16, cfg.d_model), jnp.bfloat16)
    eng = ServeEngine(model, params, max_batch=4, cache_len=128,
                      extra_inputs=extra, mode=args.mode)
    reqs = [Request([i + 1, i + 2, i + 3], args.max_new,
                    temperature=0.7 if i % 2 else 0.0, rid=i)
            for i in range(N_REQS)]
    for r in eng.generate(reqs):
        print(f"[serve_lm] rid={r.rid} ttft={r.prefill_ms:.0f}ms "
              f"decode={r.decode_ms_per_tok:.1f}ms/tok -> {r.tokens}")
    s = eng.last_stats
    print(f"[serve_lm] mode={s.mode} tokens/s={s.tokens_per_s:.1f} "
          f"occupancy={s.occupancy:.2f} ttft_mean={s.ttft_ms_mean:.0f}ms")


if __name__ == "__main__":
    main()
