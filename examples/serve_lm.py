"""Batched serving example: prefill + KV-cache decode with the engine's
continuous-batching-lite scheduler, over any assigned arch.

  PYTHONPATH=src python examples/serve_lm.py --arch zamba2-1.2b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.configs import list_archs, smoke_config  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serving import Request, ServeEngine  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list_archs())
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    extra = {}
    if cfg.family == "vlm":
        import jax.numpy as jnp
        extra["patches"] = jnp.zeros((4, cfg.n_patches, cfg.patch_embed_dim),
                                     jnp.bfloat16)
    if cfg.family == "encdec":
        import jax.numpy as jnp
        extra["frames"] = jnp.zeros((4, 16, cfg.d_model), jnp.bfloat16)
    eng = ServeEngine(model, params, max_batch=4, cache_len=128,
                      extra_inputs=extra)
    reqs = [Request([i + 1, i + 2, i + 3], args.max_new,
                    temperature=0.7 if i % 2 else 0.0, rid=i)
            for i in range(6)]
    for r in eng.generate(reqs):
        print(f"[serve_lm] rid={r.rid} prefill={r.prefill_ms:.0f}ms "
              f"decode={r.decode_ms_per_tok:.1f}ms/tok -> {r.tokens}")


if __name__ == "__main__":
    main()
