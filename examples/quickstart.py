"""Quickstart: train a tiny qwen3-family model for 30 steps on CPU, then
serve a couple of prompts from it.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.configs import smoke_config  # noqa: E402
from repro.data import SyntheticTokens  # noqa: E402
from repro.distributed.sharding import ShardingPolicy  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.optim import AdamW, warmup_cosine  # noqa: E402
from repro.serving import Request, ServeEngine  # noqa: E402
from repro.train import TrainConfig, Trainer  # noqa: E402


def main():
    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    print(f"model: {cfg.name} ({model.n_params/1e3:.0f}k params)")

    mesh = make_mesh((1, 1), ("data", "model"))
    data = SyntheticTokens(cfg, batch_size=8, seq_len=64, seed=0)
    with tempfile.TemporaryDirectory() as ckpt:
        tc = TrainConfig(steps=30, ckpt_dir=ckpt, ckpt_every=10, log_every=5)
        trainer = Trainer(model, AdamW(lr=warmup_cosine(2e-3, 5, 30)),
                          ShardingPolicy(fsdp=False), mesh, data, tc)
        state, log = trainer.run()
    print(f"loss: {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f}")

    params = jax.tree_util.tree_map(
        lambda w: w.astype(jax.numpy.bfloat16) if w.ndim else w,
        state["master"])
    eng = ServeEngine(model, params, max_batch=2, cache_len=128)
    results = eng.generate([Request([1, 2, 3, 4], 12, rid=0),
                            Request([42, 43], 12, temperature=0.8, rid=1)])
    for r in results:
        print(f"generated rid={r.rid}: {r.tokens}")


if __name__ == "__main__":
    main()
