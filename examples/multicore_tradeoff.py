"""The paper's §7 experiment, twice over:

1. The Ara2 silicon model: cores x lanes at a fixed 16-FPU budget across
   problem sizes (Figs 13-15).
2. The TPU transplant: (data, model) mesh factorizations at a fixed
   256-chip budget per assigned (arch x shape) - the same trade-off, at
   pod scale.

  PYTHONPATH=src python examples/multicore_tradeoff.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import SHAPES, get_config  # noqa: E402
from repro.core import (energy_efficiency_gflops_w, fixed_fpu_sweep,  # noqa: E402
                        matmul_opc, real_throughput_gflops)
from repro.distributed.mesh_policy import choose_mesh  # noqa: E402


def main():
    print("=== Ara2 silicon (16 FPUs, fmatmul) ===")
    sizes = (16, 32, 64, 128, 256)
    print(f"{'config':8s}" + "".join(f"{n:>9d}" for n in sizes)
          + f"{'eff@256':>10s}")
    for c in fixed_fpu_sweep(16):
        row = "".join(f"{matmul_opc(n, c):9.1f}" for n in sizes)
        print(f"{c.describe():8s}{row}"
              f"{energy_efficiency_gflops_w(256, c):10.1f}")
    print("(DP-FLOP/cycle; paper: 8x2L wins small, 1-2 big cores win large;"
          " 4x4L most efficient)")

    print("\n=== TPU transplant (256 chips) ===")
    for arch, shape in [("qwen3-0.6b", "train_4k"), ("yi-6b", "train_4k"),
                        ("qwen3-moe-235b-a22b", "train_4k"),
                        ("yi-6b", "decode_32k")]:
        cands = choose_mesh(get_config(arch), SHAPES[shape], 256)
        best = ", ".join(
            f"dp{c.dp}xtp{c.tp}={c.t_total*1e3:.1f}ms"
            f"{'' if c.fits else '(OOM)'}" for c in cands[:3])
        print(f"{arch:22s} {shape:11s} best: {best}")


if __name__ == "__main__":
    main()
