"""End-to-end driver (deliverable b): train a ~100M-param decoder LM with
the full production stack - FSDP/TP/SP-capable sharding, AdamW + cosine
schedule, checkpointing with auto-resume, straggler watchdog, restartable
synthetic data stream.

Full run (a few hundred steps, as the paper's kind dictates):
  PYTHONPATH=src python examples/train_lm.py --steps 300

CI-speed run:
  PYTHONPATH=src python examples/train_lm.py --steps 3 --tiny
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.configs.base import ModelConfig  # noqa: E402
from repro.data import SyntheticTokens  # noqa: E402
from repro.distributed.sharding import ShardingPolicy  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.optim import AdamW, warmup_cosine  # noqa: E402
from repro.train import TrainConfig, Trainer  # noqa: E402

# ~100M params: 12L x 768 with a 50k vocab (tied embeddings)
LM100M = ModelConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=4, d_ff=3072, vocab_size=50304, head_dim=64,
    rope_theta=10000.0, tie_embeddings=True,
)
TINY = dataclasses.replace(LM100M, name="lm-tiny", n_layers=2, d_model=128,
                           n_heads=4, n_kv_heads=2, d_ff=512,
                           vocab_size=4096)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/lm100m_ckpt")
    ap.add_argument("--tiny", action="store_true",
                    help="2L/128d variant for CI-speed runs")
    args = ap.parse_args()

    cfg = TINY if args.tiny else LM100M
    if args.tiny:
        args.seq = min(args.seq, 128)
    model = build_model(cfg)
    print(f"[train_lm] {cfg.name}: {model.n_params/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")
    mesh = make_mesh((1, 1), ("data", "model"))
    data = SyntheticTokens(cfg, args.batch, args.seq, seed=0)
    tc = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                     ckpt_every=max(10, args.steps // 5), log_every=5)
    trainer = Trainer(model, AdamW(lr=warmup_cosine(args.lr, 20, args.steps)),
                      ShardingPolicy(fsdp=False), mesh, data, tc)
    _, log = trainer.run()
    print(f"[train_lm] loss {log[0]['loss']:.4f} -> {log[-1]['loss']:.4f} "
          f"({trainer.watchdog.stragglers} straggler steps)")


if __name__ == "__main__":
    main()
