"""Reproduce the paper's Fig 4/5 ideality analysis and validate the Pallas
kernels against their oracles at one configuration.

  PYTHONPATH=src python examples/ideality_sweep.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import KERNELS, ideality  # noqa: E402
from repro.core.vector_engine import VectorEngineConfig  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402


def main():
    print("=== raw-throughput ideality (rows: kernel, cols: bytes/lane) ===")
    bpls = (16, 32, 64, 128, 256, 512)
    eng = VectorEngineConfig(n_lanes=4)
    print(f"{'kernel':12s}" + "".join(f"{b:>7d}" for b in bpls))
    for k in KERNELS:
        row = "".join(f"{ideality(k, b * 4, eng):7.2f}" for b in bpls)
        print(f"{k:12s}{row}")

    print("\n=== Pallas kernels (interpret) vs jnp oracles ===")
    key = jax.random.key(0)
    x = jax.random.normal(key, (256, 256), jnp.float32)
    err = float(jnp.abs(ops.matmul(x, x, impl='interpret')
                        - ref.matmul_ref(x, x)).max())
    print(f"matmul:     max|err| = {err:.2e}")
    v = jax.random.normal(key, (4096,), jnp.float32)
    err = float(jnp.abs(ops.dotproduct(v, v, impl='interpret')
                        - ref.dotproduct_ref(v, v)))
    print(f"dotproduct: |err| = {err:.2e}")
    fr = jax.random.normal(key, (1024,), jnp.float32)
    gr, gi = ops.fft(fr, fr, impl="interpret")
    wr, wi = ref.fft_ref(fr, fr)
    print(f"fft:        max|err| = {float(jnp.abs(gr - wr).max()):.2e}")


if __name__ == "__main__":
    main()
