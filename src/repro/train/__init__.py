from . import checkpoint
from .trainer import (TrainConfig, Trainer, Watchdog, make_train_step,
                      param_template, state_shardings)
