"""Training runtime: pjit step assembly, multi-step dispatch (the §5.4.2
issue-rate amortization, transplanted: one host dispatch drives K fused
steps via lax.scan), checkpoint/auto-resume, straggler watchdog.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..distributed.act_sharding import activation_sharding
from ..distributed.sharding import ShardingPolicy, tree_shardings
from ..models.layers import PT
from ..models.model import Model
from ..optim import AdamW, clip_by_global_norm


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    steps_per_dispatch: int = 1       # §5.4.2: fused steps per host dispatch
    grad_clip: float = 1.0
    ckpt_dir: str | None = None
    ckpt_every: int = 200
    ckpt_async: bool = True
    log_every: int = 10
    straggler_factor: float = 2.0     # step > factor x median -> straggler
    max_step_time: float | None = None  # abort-and-resume watchdog


def param_template(model: Model):
    """ShapeDtypeStruct tree matching the model's compute params."""
    return jax.tree_util.tree_map(
        lambda t: jax.ShapeDtypeStruct(t.shape, t.dtype), model.templates,
        is_leaf=lambda x: isinstance(x, PT))


def state_shardings(model: Model, policy: ShardingPolicy, mesh):
    pspecs = model.pspecs(policy.param_rules(), dict(mesh.shape))
    param_sh = tree_shardings(mesh, pspecs)
    return param_sh, {"master": param_sh, "m": param_sh, "v": param_sh,
                      "step": NamedSharding(mesh, P())}


def _step_body(model: Model, opt: AdamW, mesh, rules, grad_clip, remat,
               microbatches: int = 1):
    like = param_template(model)

    def grads_of(params, batch):
        def loss_fn(p):
            with activation_sharding(mesh, rules):
                loss, metrics = model.loss(p, batch, remat=remat)
            return loss, metrics
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        return grads, metrics

    def step_fn(state, batch):
        params = opt.params_from_state(state, like)
        if microbatches == 1:
            grads, metrics = grads_of(params, batch)
        else:
            # gradient accumulation: activation-scale temps shrink by the
            # microbatch factor at the cost of one f32 grad buffer
            split = jax.tree_util.tree_map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def micro(acc, mb):
                g, metrics = grads_of(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return acc, metrics

            g0 = jax.tree_util.tree_map(
                lambda t: jnp.zeros(t.shape, jnp.float32), like)
            grads, ms = jax.lax.scan(micro, g0, split)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            metrics = jax.tree_util.tree_map(lambda x: x.mean(), ms)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        state = opt.update(grads, state)
        return state, dict(metrics, grad_norm=gnorm)

    return step_fn


def make_train_step(model: Model, opt: AdamW, policy: ShardingPolicy, mesh,
                    *, grad_clip: float = 1.0, remat: bool = True,
                    donate: bool = True, steps_per_dispatch: int = 1):
    """Jitted (state, batch) -> (state, metrics) with full in/out shardings.
    With steps_per_dispatch > 1, ``batch`` must be stacked (K, ...) and one
    dispatch drives K optimizer steps (issue-rate amortization, §5.4.2)."""
    _, opt_sh = state_shardings(model, policy, mesh)
    body = _step_body(model, opt, mesh, policy.act_rules(), grad_clip, remat)

    if steps_per_dispatch == 1:
        fn = body
    else:
        def fn(state, batches):
            state, ms = jax.lax.scan(body, state, batches)
            return state, jax.tree_util.tree_map(lambda x: x[-1], ms)

    return jax.jit(fn, in_shardings=(opt_sh, None),
                   out_shardings=(opt_sh, None),
                   donate_argnums=(0,) if donate else ())


class Watchdog:
    """Step-time anomaly detector: logs stragglers, optionally aborts."""

    def __init__(self, factor: float = 2.0,
                 max_step_time: float | None = None):
        self.times: list[float] = []
        self.factor = factor
        self.max_step_time = max_step_time
        self.stragglers = 0

    def observe(self, dt: float) -> str | None:
        self.times.append(dt)
        med = float(np.median(self.times[-50:]))
        if self.max_step_time and dt > self.max_step_time:
            return "abort"
        if len(self.times) > 5 and dt > self.factor * med:
            self.stragglers += 1
            return "straggler"
        return None


class Trainer:
    """End-to-end loop with auto-resume.  ``data(step) -> host batch``."""

    def __init__(self, model: Model, opt: AdamW, policy: ShardingPolicy,
                 mesh, data: Callable[[int], dict], tc: TrainConfig,
                 log: Callable[[str], None] = print):
        self.model, self.opt, self.policy = model, opt, policy
        self.mesh, self.data, self.tc, self.log = mesh, data, tc, log
        self.param_sh, self.opt_sh = state_shardings(model, policy, mesh)
        self.step_fn = make_train_step(model, opt, policy, mesh,
                                       grad_clip=tc.grad_clip)
        self.watchdog = Watchdog(tc.straggler_factor, tc.max_step_time)
        self.metrics_log: list[dict] = []

    def init_state(self, seed: int = 0):
        params = jax.jit(self.model.init, out_shardings=self.param_sh)(
            jax.random.key(seed))
        return jax.jit(self.opt.init, out_shardings=self.opt_sh)(params)

    def run(self, state=None, start_step: int = 0):
        from . import checkpoint as ckpt
        tc = self.tc
        if state is None:
            if tc.ckpt_dir and ckpt.latest_step(tc.ckpt_dir) is not None:
                start_step, state = ckpt.restore(tc.ckpt_dir,
                                                 shardings=self.opt_sh)
                self.log(f"[trainer] resumed from step {start_step}")
            else:
                state = self.init_state()
        step = start_step
        pending_save = None
        while step < tc.steps:
            batch = jax.tree_util.tree_map(jnp.asarray, self.data(step))
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            verdict = self.watchdog.observe(dt)
            if verdict == "straggler":
                self.log(f"[watchdog] straggler step {step}: {dt:.3f}s")
            elif verdict == "abort":
                self.log(f"[watchdog] step {step} exceeded max_step_time; "
                         "checkpoint + abort for external restart")
                if tc.ckpt_dir:
                    ckpt.save(tc.ckpt_dir, step, state)
                raise TimeoutError(f"step {step} took {dt:.3f}s")
            step += 1
            row = {"step": step, "time_s": dt,
                   **{k: float(v) for k, v in metrics.items()}}
            self.metrics_log.append(row)
            if step % tc.log_every == 0 or step == tc.steps:
                self.log(f"[train] step {step} loss {row['loss']:.4f} "
                         f"acc {row.get('accuracy', 0):.3f} {dt*1e3:.0f}ms")
            if tc.ckpt_dir and step % tc.ckpt_every == 0 and step < tc.steps:
                pending_save = ckpt.save(tc.ckpt_dir, step, state,
                                         async_=tc.ckpt_async)
        if pending_save is not None:
            pending_save.join()
        if tc.ckpt_dir:
            ckpt.save(tc.ckpt_dir, step, state)
        return state, self.metrics_log
