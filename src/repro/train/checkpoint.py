"""Sharded checkpointing with elastic restore (from scratch - no orbax).

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per leaf (full logical
arrays - elastic across mesh shapes: restore re-shards via device_put) plus
``tree.json`` (paths, shapes, dtypes).  Writes are atomic (tmp dir +
rename); saves can run on a background thread after a synchronous host
snapshot (jax.device_get), so a node failure mid-write never corrupts the
latest complete checkpoint.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np

_SAVE_LOCK = threading.Lock()


def _leafpath(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def _to_native(a: np.ndarray):
    """numpy can't serialize ml_dtypes (bfloat16, fp8); store raw bytes."""
    if a.dtype.kind in "biufc":
        return a, str(a.dtype)
    return np.ascontiguousarray(a).view(np.uint8), f"raw:{a.dtype}"


def _from_native(a: np.ndarray, dtype: str, shape):
    if not dtype.startswith("raw:"):
        return a
    import ml_dtypes  # noqa: F401 - registers the dtypes with numpy
    return a.view(np.dtype(dtype[4:])).reshape(shape)


def save(ckpt_dir: str, step: int, tree, *, async_: bool = False,
         keep_last: int = 3):
    """Snapshot to host synchronously; write to disk (optionally async)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
    natives = [_to_native(a) for a in host_leaves]
    meta = {
        "step": step,
        "treedef": _treedef_to_json(tree),
        "leaves": [{"file": _leafpath(i), "shape": list(a.shape),
                    "dtype": d}
                   for i, (a, (_, d)) in enumerate(zip(host_leaves, natives))],
    }

    def write():
        with _SAVE_LOCK:
            final = os.path.join(ckpt_dir, f"step_{step:08d}")
            tmp = final + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            for i, (a, _) in enumerate(natives):
                np.save(os.path.join(tmp, _leafpath(i)), a)
            with open(os.path.join(tmp, "tree.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            _cleanup(ckpt_dir, keep_last)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _cleanup(ckpt_dir: str, keep_last: int):
    steps = list_steps(ckpt_dir)
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "tree.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int | None = None, *, shardings=None):
    """Returns (step, tree).  ``shardings``: optional matching pytree of
    NamedShardings - restoring onto a different mesh than the save mesh is
    supported because leaves are full logical arrays (elastic re-mesh)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "tree.json")) as f:
        meta = json.load(f)
    leaves = [_from_native(np.load(os.path.join(d, info["file"])),
                           info["dtype"], tuple(info["shape"]))
              for info in meta["leaves"]]
    tree = _treedef_from_json(meta["treedef"], leaves)
    if shardings is not None:
        flat_s, sdef = jax.tree_util.tree_flatten(shardings)
        flat_l = sdef.flatten_up_to(tree)
        tree = jax.tree_util.tree_unflatten(
            sdef, [jax.device_put(a, s) for a, s in zip(flat_l, flat_s)])
    return step, tree


# -- minimal treedef (de)serialization: nested dicts/lists/tuples only ------

def _treedef_to_json(tree):
    if isinstance(tree, dict):
        # jax flattens dicts in sorted-key order; mirror it so the leaf
        # files land back on the right nodes
        return {"__d__": {k: _treedef_to_json(tree[k])
                          for k in sorted(tree)}}
    if isinstance(tree, (list, tuple)):
        return {"__l__" if isinstance(tree, list) else "__t__":
                [_treedef_to_json(v) for v in tree]}
    return "LEAF"


def _treedef_from_json(spec, leaves):
    it = iter(leaves)

    def build(node):
        if node == "LEAF":
            return next(it)
        if "__d__" in node:
            return {k: build(v) for k, v in node["__d__"].items()}
        if "__l__" in node:
            return [build(v) for v in node["__l__"]]
        return tuple(build(v) for v in node["__t__"])

    out = build(spec)
    rest = list(it)
    assert not rest, f"{len(rest)} unused leaves"
    return out
