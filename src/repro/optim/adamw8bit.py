"""8-bit AdamW: m/v stored as int8 quantized per 256-value block along each
parameter's LAST axis (bitsandbytes-style, layout-preserving).

Layout preservation is the point: q keeps the parameter's shape (last dim
padded to a block multiple) and the scales keep the leading dims, so both
inherit the parameter's sharding - a flattened block layout forces GSPMD to
replicate the fp32 de/re-quantization intermediates (measured ~1 TB/device
on the 235B MoE train cell).  Masters stay fp32.  m uses symmetric int8;
v >= 0 uses unsigned uint8.  State is re-quantized from the updated fp32
value each step, so the ~0.4%-of-block-max rounding error does not
accumulate.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

BLOCK = 256
_I8_MAX = 127.0
_U8_MAX = 255.0


def padded_last(n: int) -> int:
    return -(-n // BLOCK) * BLOCK


def _blocks(x):
    *lead, n = x.shape
    npad = padded_last(n) - n
    if npad:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, npad)]
        x = jnp.pad(x, pad)
    return x.reshape(*lead, x.shape[-1] // BLOCK, BLOCK)


def _q_sym(x):
    b = _blocks(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(b), axis=-1) / _I8_MAX
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(b / scale[..., None]), -_I8_MAX, _I8_MAX
                 ).astype(jnp.int8)
    return q.reshape(*x.shape[:-1], -1), scale


def _q_pos(x):
    """v is stored as quantized sqrt(v): halves the dynamic range, so small
    second moments keep ~2x more precision (matters near convergence)."""
    b = jnp.sqrt(_blocks(x.astype(jnp.float32)))
    scale = jnp.max(b, axis=-1) / _U8_MAX
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(b / scale[..., None]), 0, _U8_MAX
                 ).astype(jnp.uint8)
    return q.reshape(*x.shape[:-1], -1), scale


def _dq(q, scale, shape, *, square=False):
    *lead, npad = q.shape
    b = q.reshape(*lead, npad // BLOCK, BLOCK).astype(jnp.float32)
    x = (b * scale[..., None]).reshape(*lead, npad)
    x = x[..., :shape[-1]].reshape(shape)
    return x * x if square else x


@dataclasses.dataclass(frozen=True)
class AdamW8bit:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    decay_min_ndim: int = 2

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.float32(self.lr)

    def init(self, params):
        def qm(p):
            q, s = _q_sym(jnp.zeros(p.shape, jnp.float32))
            return {"q": q, "s": s}

        def qv(p):
            q, s = _q_pos(jnp.zeros(p.shape, jnp.float32))
            return {"q": q, "s": s}

        return {
            "master": jax.tree_util.tree_map(
                lambda p: p.astype(jnp.float32), params),
            "m": jax.tree_util.tree_map(qm, params),
            "v": jax.tree_util.tree_map(qv, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state):
        step = state["step"] + 1
        lr = self._lr(step)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, mq, vq, master):
            g = g.astype(jnp.float32)
            m = _dq(mq["q"], mq["s"], g.shape)
            v = _dq(vq["q"], vq["s"], g.shape, square=True)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            delta = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            if master.ndim >= self.decay_min_ndim and self.weight_decay:
                delta = delta + self.weight_decay * master
            master = master - lr * delta
            q_m, s_m = _q_sym(m)
            q_v, s_v = _q_pos(v)
            return {"q": q_m, "s": s_m}, {"q": q_v, "s": s_v}, master

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_w = treedef.flatten_up_to(state["master"])
        new_m, new_v, new_w = [], [], []
        for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
            m2, v2, w2 = upd(g, m, v, w)
            new_m.append(m2)
            new_v.append(v2)
            new_w.append(w2)
        unf = treedef.unflatten
        return {"master": unf(new_w), "m": unf(new_m), "v": unf(new_v),
                "step": step}

    def params_from_state(self, state, like):
        return jax.tree_util.tree_map(
            lambda w, p: w.astype(p.dtype), state["master"], like)
