"""AdamW from scratch, mixed precision: bf16 compute params derived from
fp32 masters; m/v fp32.  Optimizer state inherits the parameter shardings
(ZeRO-3 when the policy FSDP-shards params)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    # skip weight decay on 1-D params (norms, biases)
    decay_min_ndim: int = 2

    def init(self, params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "master": jax.tree_util.tree_map(
                lambda p: p.astype(jnp.float32), params),
            "m": jax.tree_util.tree_map(f32, params),
            "v": jax.tree_util.tree_map(f32, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.float32(self.lr)

    def update(self, grads, state):
        """Returns (new_params_bf16-like-masters-cast, new_state)."""
        step = state["step"] + 1
        lr = self._lr(step)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, master):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / c1
            vh = v / c2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if master.ndim >= self.decay_min_ndim and self.weight_decay:
                delta = delta + self.weight_decay * master
            master = master - lr * delta
            return m, v, master

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_w = treedef.flatten_up_to(state["master"])
        new_m, new_v, new_w = [], [], []
        for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
            m2, v2, w2 = upd(g, m, v, w)
            new_m.append(m2)
            new_v.append(v2)
            new_w.append(w2)
        unf = treedef.unflatten
        state = {"master": unf(new_w), "m": unf(new_m), "v": unf(new_v),
                 "step": step}
        return state

    def params_from_state(self, state, like):
        """Cast fp32 masters to the compute dtypes of ``like``."""
        return jax.tree_util.tree_map(
            lambda w, p: w.astype(p.dtype), state["master"], like)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype),
                                  grads), gn
