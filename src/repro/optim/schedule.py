"""LR schedules (from scratch; callables of step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def wsd(peak_lr: float, warmup_steps: int, total_steps: int,
        decay_frac: float = 0.1):
    """Warmup-stable-decay."""
    decay_steps = int(total_steps * decay_frac)
    stable_end = total_steps - decay_steps

    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        dec = peak_lr * jnp.clip((total_steps - step) / max(decay_steps, 1),
                                 0.0, 1.0)
        out = jnp.where(step < warmup_steps, warm,
                        jnp.where(step < stable_end, peak_lr, dec))
        return out
    return lr
