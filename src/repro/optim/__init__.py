from .adamw import AdamW, clip_by_global_norm
from .adamw8bit import AdamW8bit
from .schedule import warmup_cosine, wsd
