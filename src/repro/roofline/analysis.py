"""Roofline analysis of compiled dry-run artifacts (deliverable g).

Terms (per the task spec; cost_analysis() is per-device in SPMD, verified
empirically):
  compute    = HLO_FLOPs_per_device / peak_FLOPs_chip
  memory     = HLO_bytes_per_device / HBM_bw_chip
  collective = collective_bytes_per_device / link_bw

collective bytes are parsed from the post-SPMD compiled HLO text: the sum
of operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (shard shapes, i.e. per-device).
"""
from __future__ import annotations

import dataclasses
import re

from ..core.ppa import TPU_V5E, TpuSpec

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^=]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(([^)]*)\)", re.M)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device operand bytes per collective opcode (``-done`` ops carry
    no operand payload and are skipped; ``-start`` counted once)."""
    out: dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for m in _OP_RE.finditer(hlo_text):
        op, args = m.group(1), m.group(2)
        if "-done(" in m.group(0):
            continue
        b = sum(_shape_bytes(t, d) for t, d in _SHAPE_RE.findall(args))
        out[op] += b
    return out


def collective_counts(hlo_text: str) -> dict[str, int]:
    out: dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for m in _OP_RE.finditer(hlo_text):
        if "-done(" not in m.group(0):
            out[m.group(1)] += 1
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: dict
    model_flops: float
    peak_memory_bytes: float       # per device (memory_analysis)
    arg_bytes: float
    spec: TpuSpec = TPU_V5E

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.spec.peak_bf16_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / self.spec.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / self.spec.ici_link_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline-model step time: overlapped compute/memory + exposed
        collectives (conservative)."""
        return max(self.t_compute, self.t_memory) + self.t_collective

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO flops x chips): compiled-compute usefulness."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs throughput achieved at the roofline-model step time,
        as a fraction of the cluster bf16 peak - the headline §Perf score."""
        if self.t_bound == 0:
            return 0.0
        ach = self.model_flops / self.t_bound
        return ach / (self.chips * self.spec.peak_bf16_flops)

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "peak_memory_bytes": self.peak_memory_bytes,
            "arg_bytes": self.arg_bytes,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "dominant": self.dominant,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(compiled, *, arch: str, shape: str, mesh_desc: str, chips: int,
            model_flops: float, spec: TpuSpec = TPU_V5E) -> Roofline:
    """Roofline terms from the compiled artifact.

    flops/bytes/collective-bytes come from the while-trip-scaled HLO text
    parser (``hlo_cost``): this build's ``cost_analysis()`` counts scan
    bodies once, which would undercount every layer stack by ~n_layers
    (verified; see hlo_cost module doc)."""
    from .hlo_cost import HloCost
    txt = compiled.as_text()
    cost = HloCost(txt).cost()
    ma = compiled.memory_analysis()
    peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_desc, chips=chips,
        flops_per_device=cost.flops,
        bytes_per_device=cost.mem_bytes,
        coll_bytes_per_device=cost.coll_bytes,
        coll_breakdown={k: v for k, v in cost.coll_breakdown.items() if v},
        model_flops=model_flops,
        peak_memory_bytes=float(peak),
        arg_bytes=float(ma.argument_size_in_bytes),
        spec=spec,
    )


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference)."""
    from ..distributed.mesh_policy import _active_params
    n = _active_params(cfg)
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    return (6.0 if shape.kind == "train" else 2.0) * n * tokens
