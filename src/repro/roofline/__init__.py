from .analysis import Roofline, analyze, collective_bytes, collective_counts, model_flops_estimate
from .hlo_cost import HloCost
