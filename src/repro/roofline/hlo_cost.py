"""Text-level cost analysis of compiled (post-SPMD) HLO with while-loop
trip-count scaling.

Why: ``compiled.cost_analysis()`` counts a while body ONCE (verified on this
jax build), so anything under ``lax.scan`` - i.e. every layer stack in this
framework - is undercounted by ~n_layers.  This parser walks the computation
graph, multiplies while bodies by their trip counts (read from the loop
condition's comparison constant), and produces:

  * flops            - dot/convolution MACs x 2 (elementwise flops are
                       second-order and ignored; documented in DESIGN.md)
  * memory bytes     - sum over non-plumbing ops of result+operand bytes
                       (fusions counted as single ops = perfect-fusion HBM
                       traffic model)
  * collective bytes - per-device *operand* bytes per collective, with
                       all-gather operands inferred as result/group_size and
                       reduce-scatter as result x group_size
"""
from __future__ import annotations

import dataclasses
import re
from functools import lru_cache

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)(?:_spmd)?\s*\(")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\]"
    r"(?:\{[^}]*\})?))\s+([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_TRIP_CONST = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_OPERAND = re.compile(r"%([\w.\-]+)")


def _type_bytes(tstr: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(tstr):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _first_shape_dims(tstr: str):
    m = _SHAPE.search(tstr)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class Op:
    name: str
    rtype: str
    opcode: str
    rest: str      # args + attributes (single line)


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    text: str

    def op_types(self) -> dict:
        return {o.name: o.rtype for o in self.ops}


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur_name, cur_ops, cur_lines = None, [], []
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur_name is None:
            if stripped.endswith("{") and ("(" in stripped) and \
                    (stripped.startswith("%") or stripped.startswith("ENTRY")):
                m = _COMP_START.match(stripped.lstrip())
                header = stripped.split("(")[0].replace("ENTRY", "").strip()
                cur_name = header.lstrip("%").strip()
                cur_ops, cur_lines = [], [line]
            continue
        cur_lines.append(line)
        if stripped == "}":
            comps[cur_name] = Computation(cur_name, cur_ops,
                                          "\n".join(cur_lines))
            cur_name = None
            continue
        m = _OP_LINE.match(line)
        if m:
            cur_ops.append(Op(m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps


_PLUMBING = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "while", "conditional", "call", "after-all", "iota",
             "partition-id", "replica-id"}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_breakdown: dict = dataclasses.field(default_factory=dict)

    def __add__(self, o):
        bd = dict(self.coll_breakdown)
        for k, v in o.coll_breakdown.items():
            bd[k] = bd.get(k, 0.0) + v
        return Cost(self.flops + o.flops, self.mem_bytes + o.mem_bytes,
                    self.coll_bytes + o.coll_bytes, bd)

    def __mul__(self, k):
        return Cost(self.flops * k, self.mem_bytes * k, self.coll_bytes * k,
                    {a: b * k for a, b in self.coll_breakdown.items()})


def _dot_flops(op: Op, types: dict) -> float:
    out_elems = 1
    for d in _first_shape_dims(op.rtype):
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contract = 1
    if m:
        operands = _OPERAND.findall(op.rest.split(")")[0])
        lhs_shape = _first_shape_dims(types.get(operands[0], "")) \
            if operands else []
        for idx in (int(i) for i in m.group(1).split(",") if i):
            if idx < len(lhs_shape):
                contract *= lhs_shape[idx]
    return 2.0 * out_elems * contract


def _conv_flops(op: Op, types: dict) -> float:
    out_elems = 1
    for d in _first_shape_dims(op.rtype):
        out_elems *= d
    operands = _OPERAND.findall(op.rest.split(")")[0])
    if len(operands) >= 2:
        k_shape = _first_shape_dims(types.get(operands[1], ""))
        k_elems = 1
        for d in k_shape:
            k_elems *= d
        # rough: 2 * out * (kernel elems / out-channels)
        if k_shape:
            return 2.0 * out_elems * (k_elems / max(k_shape[-1], 1))
    return 2.0 * out_elems


def _collective_bytes(op: Op) -> float:
    rbytes = _type_bytes(op.rtype)
    m = _GROUPS.search(op.rest)
    gsize = int(m.group(2)) if m else 1
    if op.opcode.startswith("all-gather"):
        return rbytes / max(gsize, 1)
    if op.opcode.startswith("reduce-scatter"):
        return rbytes * gsize
    return float(rbytes)


def _trip_count(cond: Computation) -> int:
    consts = [int(c) for c in _TRIP_CONST.findall(cond.text)]
    return max(consts) if consts else 1


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps = parse_computations(hlo_text)
        self.entry = next((n for n in self.comps if n.endswith("_spmd")
                           and "region" not in n),
                          None)
        if self.entry is None:
            # fall back: the computation named main-ish or the last one
            cands = [n for n in self.comps if n.startswith("main")]
            self.entry = cands[0] if cands else list(self.comps)[-1]
        self._memo: dict[str, Cost] = {}

    def cost(self) -> Cost:
        return self._cost_of(self.entry)

    def _cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        if comp is None:
            return Cost()
        self._memo[comp_name] = Cost()  # break cycles
        types = comp.op_types()
        total = Cost()
        for op in comp.ops:
            oc = op.opcode
            base = oc.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES:
                if oc.endswith("-done"):
                    continue
                b = _collective_bytes(op)
                total = total + Cost(0.0, 0.0, b, {base: b})
                continue
            if oc == "while":
                mb = re.search(r"body=%([\w.\-]+)", op.rest)
                mc = re.search(r"condition=%([\w.\-]+)", op.rest)
                if mb and mc:
                    trips = _trip_count(self.comps.get(mc.group(1),
                                                       Computation("", [], "")))
                    total = total + self._cost_of(mb.group(1)) * trips
                continue
            if oc == "call":
                m = re.search(r"to_apply=%([\w.\-]+)", op.rest)
                if m:
                    total = total + self._cost_of(m.group(1))
                continue
            if oc == "fusion":
                m = re.search(r"calls=%([\w.\-]+)", op.rest)
                if m:
                    # flops (dots can hide in fusions); memory = this op only
                    inner = self._flops_only(m.group(1))
                    total = total + Cost(inner, 0.0, 0.0, {})
                total = total + Cost(0.0, self._op_mem(op, types), 0.0, {})
                continue
            if oc == "dot":
                total = total + Cost(_dot_flops(op, types),
                                     self._op_mem(op, types), 0.0, {})
                continue
            if oc == "convolution":
                total = total + Cost(_conv_flops(op, types),
                                     self._op_mem(op, types), 0.0, {})
                continue
            if oc in _PLUMBING or oc.startswith("custom-call"):
                continue
            total = total + Cost(0.0, self._op_mem(op, types), 0.0, {})
        self._memo[comp_name] = total
        return total

    def _flops_only(self, comp_name: str) -> float:
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0.0
        types = comp.op_types()
        fl = 0.0
        for op in comp.ops:
            if op.opcode == "dot":
                fl += _dot_flops(op, types)
            elif op.opcode == "convolution":
                fl += _conv_flops(op, types)
            elif op.opcode == "fusion":
                m = re.search(r"calls=%([\w.\-]+)", op.rest)
                if m and m.group(1) != comp_name:
                    fl += self._flops_only(m.group(1))
        return fl

    def _op_mem(self, op: Op, types: dict) -> float:
        b = _type_bytes(op.rtype)
        args = op.rest.split(")")[0]
        for operand in _OPERAND.findall(args):
            b += _type_bytes(types.get(operand, ""))
        return float(b)
