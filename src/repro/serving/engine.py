"""Slot-based continuous-batching serving engine.

The paper's multi-core result (Ara2 §7.1: eight 2-lane cores beat one
16-lane core by >3x at equal FPU count, because eight independent issue
streams remove the single-dispatcher bottleneck) maps onto serving as:
many independently scheduled decode *slots* beat one lock-step batch whose
cadence is set by its slowest member.

Two scheduling modes:

* ``continuous`` (default for slot-addressable caches: dense/moe/vlm) - a
  fixed pool of ``max_batch`` decode slots with per-slot KV state and
  per-slot positions.  An admission scheduler prefills a queued request
  into a freed slot *immediately* (prefill-on-admit via
  ``model.cache_slot_write``); the other slots keep decoding on the next
  step.  A short request never holds its neighbors hostage.

* ``lockstep`` - the legacy group scheduler, kept behind the ``mode`` flag
  for scan-layout caches (ssm/hybrid/encdec, where per-slot cache writes
  are not addressable): requests run in groups of ``max_batch``; a
  finished sequence's slot idles until the whole group drains, and slot
  refill re-runs a batched prefill over the next waiting group.

Prompts are prefilled at their exact length (one compile per distinct
prompt length; serving traces with many unique lengths should bucket
prompts client-side).  Per-request sampling is vectorized: temperature<=0
rows take argmax (deterministic regardless of the shared PRNG key),
temperature>0 rows sample at their own temperature - never at the batch
max.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    rid: int = 0


@dataclasses.dataclass
class Result:
    rid: int
    tokens: list[int]
    prefill_ms: float = 0.0        # time-to-first-token for this request
    decode_ms_per_tok: float = 0.0


@dataclasses.dataclass
class EngineStats:
    """Aggregate metrics for the last ``generate`` call."""
    mode: str
    wall_s: float
    generated_tokens: int
    tokens_per_s: float
    decode_steps: int
    occupancy: float               # busy slot-steps / (max_batch * steps)
    ttft_ms_mean: float            # mean time-to-first-token


@dataclasses.dataclass
class _Slot:
    req: Request
    order: int                     # submission index (stable result order)
    tokens: list[int]
    ttft_ms: float
    decode_s: float = 0.0
    steps: int = 0


def _sample_rows(logits, temps, key):
    """Per-row temperature sampling over (B, V) logits.

    temps: (B,).  Rows with temperature <= 0 take argmax (greedy,
    independent of the key); rows with temperature > 0 sample a categorical
    at their own temperature."""
    greedy = jnp.argmax(logits, axis=-1)
    safe = jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.random.categorical(key, logits / safe, axis=-1)
    return jnp.where(temps > 0.0, sampled, greedy)


class ServeEngine:
    """Batched generation over the uniform Model API.

    mode: "auto" (continuous when the model exposes slot-cache hooks,
    else lockstep), "continuous", or "lockstep".  Requesting "continuous"
    on a scan-layout cache silently falls back to lockstep - check
    ``engine.mode`` for the resolved scheduler.

    ``extra_inputs`` (vlm patches / encdec frames): leaves carry one row
    per request, indexed by submission order; a leaf with leading dim 1
    broadcasts to every request.  Too few rows is an error, not a clamp.
    """

    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 cache_len: int = 1024, extra_inputs: dict | None = None,
                 mode: str = "auto"):
        assert mode in ("auto", "continuous", "lockstep"), mode
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.extra = extra_inputs or {}
        slot_capable = model.cache_slot_write is not None
        if mode == "auto":
            mode = "continuous" if slot_capable else "lockstep"
        if mode == "continuous" and not slot_capable:
            mode = "lockstep"      # re-prefill fallback (scan-cache layout)
        self.mode = mode
        self.last_stats: EngineStats | None = None
        # the cache is dead after every call that consumes it - donate so
        # XLA updates the multi-GB KV buffers in place instead of copying
        self._decode = jax.jit(model.decode, donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=cache_len))
        self._sample = jax.jit(_sample_rows)
        self._slot_capable = slot_capable
        if slot_capable:
            self._cache_expand = jax.jit(model.cache_expand,
                                         static_argnums=(1,))
            self._slot_write = jax.jit(model.cache_slot_write,
                                       donate_argnums=(0,))

    # ------------------------------------------------------------------
    # Public API.
    # ------------------------------------------------------------------

    def generate(self, requests: list[Request], key=None) -> list[Result]:
        key = key if key is not None else jax.random.key(0)
        requests = list(requests)
        if not requests or all(r.max_new_tokens <= 0 for r in requests):
            self.last_stats = EngineStats(self.mode, 0.0, 0, 0.0, 0, 0.0,
                                          0.0)
            return [Result(r.rid, []) for r in requests]
        # max_new_tokens <= 0 requests produce no tokens and never occupy
        # a slot; everything else goes to the scheduler
        todo = [(i, r) for i, r in enumerate(requests)
                if r.max_new_tokens > 0]
        if self.mode == "continuous":
            done = self._generate_continuous(todo, key)
        else:
            done = self._generate_lockstep(todo, key)
        results = [Result(r.rid, []) for r in requests]
        for (i, _), res in zip(todo, done):
            results[i] = res
        return results

    # ------------------------------------------------------------------
    # Continuous batching (slot pool + admission scheduler).
    # ------------------------------------------------------------------

    def _gather_extra(self, rows: list[int]) -> dict:
        """Select extra-input rows by submission order (dim-1 broadcasts)."""
        out = {}
        for k, v in self.extra.items():
            if v.shape[0] == 1:
                out[k] = jnp.broadcast_to(jnp.asarray(v),
                                          (len(rows),) + tuple(v.shape[1:]))
            elif max(rows) < v.shape[0]:
                out[k] = jnp.asarray(v)[jnp.asarray(rows)]
            else:
                raise ValueError(
                    f"extra_inputs[{k!r}] has {v.shape[0]} rows but request "
                    f"#{max(rows)} needs its own (pass one row per request, "
                    "or a single row to broadcast)")
        return out

    def _check_budget(self, prefill_pos: int, max_new: int, rid) -> None:
        """Every position written past prefill must fit in cache_len
        (writes beyond it are silently dropped by the one-hot update)."""
        writes = prefill_pos + max(max_new - 1, 0)
        if writes > self.cache_len:
            raise ValueError(
                f"request rid={rid} needs {writes} cache positions "
                f"(prefill {prefill_pos} + {max_new - 1} decode writes) "
                f"but cache_len={self.cache_len}")

    def _admit(self, r: Request, order: int, seq: int, slot: int, cache,
               key):
        """Prefill ``r`` into ``slot`` and sample its first token.

        ``order`` is the original submission index (extra-input row);
        ``seq`` indexes the scheduler's result list."""
        prompt = np.asarray(r.prompt, np.int32)
        t0 = time.perf_counter()
        batch = {"tokens": jnp.asarray(prompt[None]),
                 **self._gather_extra([order])}
        logits, sub = self._prefill(self.params, batch)
        # sub["pos"] covers any model-side prefix (e.g. vlm patches)
        self._check_budget(int(np.asarray(sub["pos"])), r.max_new_tokens,
                           r.rid)
        if cache is None:
            cache = self._cache_expand(sub, self.max_batch)
        cache = self._slot_write(cache, sub, slot)
        tok = self._sample(logits, jnp.full((1,), r.temperature), key)
        tok = int(np.asarray(jax.block_until_ready(tok))[0])
        ttft_ms = (time.perf_counter() - t0) * 1e3
        return cache, _Slot(req=r, order=seq, tokens=[tok],
                            ttft_ms=ttft_ms)

    def _generate_continuous(self, items, key) -> list[Result]:
        """items: [(submission order, Request)]; results align with items."""
        bsz = self.max_batch
        queue = collections.deque(
            (seq, order, r) for seq, (order, r) in enumerate(items))
        slots: list[_Slot | None] = [None] * bsz
        results: list[Result | None] = [None] * len(items)
        cache = None
        toks = np.zeros((bsz, 1), np.int32)
        temps = np.zeros((bsz,), np.float32)
        decode_steps = busy_steps = 0
        ttfts: list[float] = []
        t_start = time.perf_counter()

        def _finish(s: _Slot):
            per_tok = s.decode_s * 1e3 / max(s.steps, 1)
            results[s.order] = Result(s.req.rid, s.tokens, s.ttft_ms,
                                      per_tok)

        while queue or any(s is not None for s in slots):
            # admission: refill every free slot before the next decode step
            for i in range(bsz):
                if slots[i] is None and queue:
                    seq, order, r = queue.popleft()
                    key, sk = jax.random.split(key)
                    cache, s = self._admit(r, order, seq, i, cache, sk)
                    ttfts.append(s.ttft_ms)
                    if len(s.tokens) >= r.max_new_tokens:
                        _finish(s)      # satisfied by prefill alone
                    else:
                        slots[i] = s
                        toks[i, 0] = s.tokens[-1]
                        temps[i] = r.temperature
            active = [i for i in range(bsz) if slots[i] is not None]
            if not active:
                continue
            # one decode step over the whole slot pool (fixed shapes; idle
            # slots compute too - their rows are masked by per-slot pos and
            # fully rewritten on the next admission)
            t0 = time.perf_counter()
            key, sk = jax.random.split(key)
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(toks))
            nxt = np.asarray(self._sample(logits, jnp.asarray(temps), sk))
            dt = time.perf_counter() - t0
            decode_steps += 1
            busy_steps += len(active)
            for i in active:
                s = slots[i]
                s.tokens.append(int(nxt[i]))
                s.steps += 1
                s.decode_s += dt
                toks[i, 0] = nxt[i]
                if len(s.tokens) >= s.req.max_new_tokens:
                    _finish(s)
                    slots[i] = None     # freed: refilled on the next pass

        wall = time.perf_counter() - t_start
        gen = sum(len(r.tokens) for r in results)
        self.last_stats = EngineStats(
            "continuous", wall, gen, gen / max(wall, 1e-9), decode_steps,
            busy_steps / max(bsz * decode_steps, 1),
            float(np.mean(ttfts)) if ttfts else 0.0)
        return results

    # ------------------------------------------------------------------
    # Lock-step group batching (legacy / scan-cache fallback).
    # ------------------------------------------------------------------

    def _pad_prompts(self, prompts: list[list[int]]) -> np.ndarray:
        # left-pad to a common length (uniform-position cache layout)
        maxlen = max(len(p) for p in prompts)
        out = np.zeros((len(prompts), maxlen), np.int32)
        for i, p in enumerate(prompts):
            out[i, maxlen - len(p):] = p
        return out

    def _generate_lockstep(self, items, key) -> list[Result]:
        """items: [(submission order, Request)]; results align with items."""
        results: list[Result | None] = [None] * len(items)
        queue = [(seq, order, r) for seq, (order, r) in enumerate(items)]
        decode_steps = busy_steps = 0
        ttfts: list[float] = []
        t_start = time.perf_counter()
        while queue:
            group = queue[: self.max_batch]
            queue = queue[self.max_batch:]
            key = jax.random.fold_in(key, len(queue))
            stats = self._generate_group(group, key, results)
            decode_steps += stats[0]
            busy_steps += stats[1]
            ttfts.extend(stats[2])
        wall = time.perf_counter() - t_start
        gen = sum(len(r.tokens) for r in results)
        self.last_stats = EngineStats(
            "lockstep", wall, gen, gen / max(wall, 1e-9), decode_steps,
            busy_steps / max(self.max_batch * decode_steps, 1),
            float(np.mean(ttfts)) if ttfts else 0.0)
        return results

    def _generate_group(self, group, key, results):
        reqs = [r for _, _, r in group]
        prompts = self._pad_prompts([r.prompt for r in reqs])
        batch = {"tokens": jnp.asarray(prompts),
                 **self._gather_extra([order for _, order, _ in group])}
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        jax.block_until_ready(logits)
        prefill_ms = (time.perf_counter() - t0) * 1e3
        max_new = max(r.max_new_tokens for r in reqs)
        if self._slot_capable:
            # uniform-position KV layout: the whole group decodes in step,
            # so the group's slowest member sets the write budget (scan/ring
            # cache families manage their own state length)
            self._check_budget(int(np.asarray(cache["pos"])), max_new,
                               [r.rid for r in reqs])
        temps = jnp.asarray([r.temperature for r in reqs], jnp.float32)
        key, sk = jax.random.split(key)
        toks = np.asarray(self._sample(logits, temps, sk))[:, None]
        outs = [[int(toks[i, 0])] for i in range(len(reqs))]
        t1 = time.perf_counter()
        n_steps = 0
        for _ in range(max_new - 1):
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(toks, jnp.int32))
            key, sk = jax.random.split(key)
            toks = np.asarray(self._sample(logits, temps, sk))[:, None]
            n_steps += 1
            for i, r in enumerate(reqs):
                if len(outs[i]) < r.max_new_tokens:
                    outs[i].append(int(toks[i, 0]))
        jax.block_until_ready(logits)
        decode_ms = ((time.perf_counter() - t1) * 1e3 / max(n_steps, 1))
        busy_total = 0
        # recompute busy slot-steps: request i is busy for its first
        # (max_new_tokens - 1) decode steps of this group
        for r in reqs:
            busy_total += min(max(r.max_new_tokens - 1, 0), max(n_steps, 0))
        for i, (seq, _, r) in enumerate(group):
            results[seq] = Result(r.rid, outs[i], prefill_ms, decode_ms)
        return n_steps, busy_total, [prefill_ms] * len(reqs)
