"""Serving engine: batched prefill + decode with continuous-batching-lite.

Fixed B decode slots; finished sequences free their slot for the next
queued request (re-prefilled into the shared cache at the slot's batch
index is out of scope for the scan-cache layout, so slot refill re-runs a
batched prefill over the waiting group - documented trade-off).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    rid: int = 0


@dataclasses.dataclass
class Result:
    rid: int
    tokens: list[int]
    prefill_ms: float = 0.0
    decode_ms_per_tok: float = 0.0


def _sample(logits, temperature, key):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


class ServeEngine:
    """Greedy/temperature batched generation over the uniform Model API."""

    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 cache_len: int = 1024, extra_inputs: dict | None = None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.extra = extra_inputs or {}
        self._decode = jax.jit(model.decode)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=cache_len))

    def _pad_prompts(self, prompts: list[list[int]]) -> np.ndarray:
        # left-pad to a common length (uniform-position cache layout)
        maxlen = max(len(p) for p in prompts)
        out = np.zeros((len(prompts), maxlen), np.int32)
        for i, p in enumerate(prompts):
            out[i, maxlen - len(p):] = p
        return out

    def generate(self, requests: list[Request], key=None) -> list[Result]:
        key = key if key is not None else jax.random.key(0)
        results: list[Result] = []
        queue = list(requests)
        while queue:
            group = queue[: self.max_batch]
            queue = queue[self.max_batch:]
            results.extend(self._generate_group(group, key))
            key = jax.random.fold_in(key, len(results))
        return results

    def _generate_group(self, group: list[Request], key) -> list[Result]:
        prompts = self._pad_prompts([r.prompt for r in group])
        batch = {"tokens": jnp.asarray(prompts), **self.extra}
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        jax.block_until_ready(logits)
        prefill_ms = (time.perf_counter() - t0) * 1e3
        max_new = max(r.max_new_tokens for r in group)
        temps = np.array([r.temperature for r in group], np.float32)
        toks = np.asarray(_sample(logits, float(temps.max()), key))[:, None]
        outs = [[int(toks[i, 0])] for i in range(len(group))]
        t1 = time.perf_counter()
        n_steps = 0
        for stepi in range(max_new - 1):
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(toks, jnp.int32))
            key = jax.random.fold_in(key, stepi)
            toks = np.asarray(_sample(logits, float(temps.max()), key))[:, None]
            n_steps += 1
            for i, r in enumerate(group):
                if len(outs[i]) < r.max_new_tokens:
                    outs[i].append(int(toks[i, 0]))
        jax.block_until_ready(logits)
        decode_ms = ((time.perf_counter() - t1) * 1e3 / max(n_steps, 1))
        return [Result(r.rid, outs[i], prefill_ms, decode_ms)
                for i, r in enumerate(group)]
