"""Slot-based continuous-batching serving engine.

The paper's multi-core result (Ara2 §7.1: eight 2-lane cores beat one
16-lane core by >3x at equal FPU count, because eight independent issue
streams remove the single-dispatcher bottleneck) maps onto serving as:
many independently scheduled decode *slots* beat one lock-step batch whose
cadence is set by its slowest member.

Two scheduling modes:

* ``continuous`` (default for slot-addressable caches: dense/moe/vlm) - a
  fixed pool of ``max_batch`` decode slots with per-slot KV state and
  per-slot positions.  An admission scheduler prefills a queued request
  into a freed slot *immediately* (prefill-on-admit via
  ``model.cache_slot_write``); the other slots keep decoding on the next
  step.  A short request never holds its neighbors hostage.

* ``lockstep`` - the legacy group scheduler, kept behind the ``mode`` flag
  for scan-layout caches (ssm/hybrid/encdec, where per-slot cache writes
  are not addressable): requests run in groups of ``max_batch``; a
  finished sequence's slot idles until the whole group drains, and slot
  refill re-runs a batched prefill over the next waiting group.

Continuous mode supports two KV layouts (``kv_layout``):

* ``dense`` (default) - every slot reserves a full ``(Hkv, cache_len, D)``
  KV strip per layer, so admission enforces ``prefill + decode writes <=
  cache_len`` per request and memory is bounded by worst-case reservation
  (``max_batch * cache_len`` positions live at all times).

* ``paged`` - KV lives in one global pool of fixed-size blocks
  (``repro.serving.kvcache.BlockAllocator``) addressed through per-slot
  block tables; decode runs the paged-attention kernel
  (``repro.kernels.paged_attention``).  Admission is bounded by *free
  blocks*, not a per-slot length: a request is admitted when the pool can
  cover its worst-case block count, blocks are allocated lazily as its
  position grows, and a finished request returns its blocks immediately -
  so a trace whose summed KV footprint exceeds ``max_batch * cache_len``
  still serves as long as the *concurrently live* footprint fits the pool.
  ``cache_len`` remains only the per-request context bound (the block
  table's width).

Prompt-length bucketing (``bucket=``): prompts are prefilled at their
exact length by default - one compile per distinct length.  With
``bucket="pow2"`` (or an integer multiple), continuous-mode prefills are
right-padded up to the bucket boundary and the true length rides in
``batch["prefill_len"]``; causal masking hides the pads, so outputs are
identical while compiles drop to one per bucket
(``EngineStats.prefill_compiles`` counts distinct compiled prefill
shapes).  Per-request sampling is vectorized: temperature<=0 rows take
argmax (deterministic regardless of the shared PRNG key), temperature>0
rows sample at their own temperature - never at the batch max.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model
from . import kvcache
from .kvcache import BlockAllocator, blocks_needed


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    rid: int = 0


@dataclasses.dataclass
class Result:
    rid: int
    tokens: list[int]
    prefill_ms: float = 0.0        # time-to-first-token for this request
    decode_ms_per_tok: float = 0.0


@dataclasses.dataclass
class EngineStats:
    """Aggregate metrics for the last ``generate`` call."""
    mode: str
    wall_s: float
    generated_tokens: int
    tokens_per_s: float
    decode_steps: int
    occupancy: float               # busy slot-steps / (max_batch * steps)
    ttft_ms_mean: float            # mean time-to-first-token
    kv_layout: str = "dense"
    prefill_compiles: int = 0      # distinct prefill shapes compiled so far
    block_util_peak: float = 0.0   # paged: peak live blocks / pool capacity


@dataclasses.dataclass
class _Slot:
    req: Request
    order: int                     # submission index (stable result order)
    tokens: list[int]
    ttft_ms: float
    decode_s: float = 0.0
    steps: int = 0
    # paged layout bookkeeping
    prefill_pos: int = 0           # cache positions written by prefill
    blocks: list[int] = dataclasses.field(default_factory=list)
    reserve_left: int = 0          # worst-case blocks not yet allocated


def _sample_rows(logits, temps, key):
    """Per-row temperature sampling over (B, V) logits.

    temps: (B,).  Rows with temperature <= 0 take argmax (greedy,
    independent of the key); rows with temperature > 0 sample a categorical
    at their own temperature."""
    greedy = jnp.argmax(logits, axis=-1)
    safe = jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.random.categorical(key, logits / safe, axis=-1)
    return jnp.where(temps > 0.0, sampled, greedy)


class ServeEngine:
    """Batched generation over the uniform Model API.

    mode: "auto" (continuous when the model exposes slot-cache hooks,
    else lockstep), "continuous", or "lockstep".  Requesting "continuous"
    on a scan-layout cache silently falls back to lockstep - check
    ``engine.mode`` for the resolved scheduler.

    ``extra_inputs`` (vlm patches / encdec frames): leaves carry one row
    per request, indexed by submission order; a leaf with leading dim 1
    broadcasts to every request.  Too few rows is an error, not a clamp.

    kv_layout: "dense" or "paged" (continuous mode only; see module doc).
    block_size / n_blocks size the paged pool - n_blocks defaults to the
    dense layout's footprint (max_batch * cache_len positions) plus the
    null block.  bucket: None (exact-length prefills), "pow2", or an
    integer pad-to-multiple.
    """

    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 cache_len: int = 1024, extra_inputs: dict | None = None,
                 mode: str = "auto", kv_layout: str = "dense",
                 block_size: int = 16, n_blocks: int | None = None,
                 bucket: str | int | None = None):
        assert mode in ("auto", "continuous", "lockstep"), mode
        assert kv_layout in ("dense", "paged"), kv_layout
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.extra = extra_inputs or {}
        self.bucket = bucket
        slot_capable = model.cache_slot_write is not None
        if mode == "auto":
            mode = "continuous" if slot_capable else "lockstep"
        if mode == "continuous" and not slot_capable:
            mode = "lockstep"      # re-prefill fallback (scan-cache layout)
        if kv_layout == "paged":
            if model.decode_paged is None:
                raise ValueError(
                    f"kv_layout='paged': family {model.cfg.family!r} has "
                    "no paged cache hooks")
            if mode != "continuous":
                raise ValueError(
                    "kv_layout='paged' requires the continuous scheduler")
        self.mode = mode
        self.kv_layout = kv_layout
        self.last_stats: EngineStats | None = None
        self._prefill_shapes: set[int] = set()   # compiled prefill lengths
        # the cache is dead after every call that consumes it - donate so
        # XLA updates the multi-GB KV buffers in place instead of copying
        self._sample = jax.jit(_sample_rows)
        self._slot_capable = slot_capable
        if kv_layout == "paged":
            self.block_size = block_size
            self.max_blocks = blocks_needed(cache_len, block_size)
            if n_blocks is None:
                n_blocks = max_batch * self.max_blocks + 1
            self.allocator = BlockAllocator(n_blocks, block_size)
            self._reserved = 0     # worst-case blocks promised, not yet live
            # prefill at the (bucketed) prompt length - the paged write
            # scatters it into blocks, no cache_len padding needed
            self._prefill = jax.jit(
                lambda p, b: model.prefill(p, b, cache_len=None))
            self._decode = jax.jit(model.decode_paged, donate_argnums=(1,))
            self._paged_write = jax.jit(model.cache_paged_write,
                                        donate_argnums=(0,))
            self._bt_set = jax.jit(kvcache.bt_set_entry, donate_argnums=(0,))
            self._slot_release = jax.jit(kvcache.slot_release,
                                         donate_argnums=(0,))
        else:
            self._decode = jax.jit(model.decode, donate_argnums=(1,))
            self._prefill = jax.jit(
                lambda p, b: model.prefill(p, b, cache_len=cache_len))
            if slot_capable:
                self._cache_expand = jax.jit(model.cache_expand,
                                             static_argnums=(1,))
                self._slot_write = jax.jit(model.cache_slot_write,
                                           donate_argnums=(0,))

    # ------------------------------------------------------------------
    # Public API.
    # ------------------------------------------------------------------

    def generate(self, requests: list[Request], key=None) -> list[Result]:
        key = key if key is not None else jax.random.key(0)
        requests = list(requests)
        if not requests or all(r.max_new_tokens <= 0 for r in requests):
            self.last_stats = EngineStats(
                self.mode, 0.0, 0, 0.0, 0, 0.0, 0.0,
                kv_layout=self.kv_layout,
                prefill_compiles=len(self._prefill_shapes))
            return [Result(r.rid, []) for r in requests]
        # max_new_tokens <= 0 requests produce no tokens and never occupy
        # a slot; everything else goes to the scheduler
        todo = [(i, r) for i, r in enumerate(requests)
                if r.max_new_tokens > 0]
        if self.kv_layout == "paged":
            # reject impossible requests before any work is scheduled: a
            # raise mid-schedule would abort the batch with blocks still
            # allocated (and _can_admit would otherwise stall forever on a
            # request that can never fit)
            for _, r in todo:
                self._check_budget(self._n_prefix() + len(r.prompt),
                                   r.max_new_tokens, r.rid)
                worst = self._worst_blocks(r)
                if worst > self.allocator.capacity:
                    raise ValueError(
                        f"request rid={r.rid} needs {worst} KV blocks "
                        f"(block_size={self.block_size}) but the pool only "
                        f"has {self.allocator.capacity}")
        if self.mode == "continuous":
            done = self._generate_continuous(todo, key)
        else:
            done = self._generate_lockstep(todo, key)
        results = [Result(r.rid, []) for r in requests]
        for (i, _), res in zip(todo, done):
            results[i] = res
        return results

    # ------------------------------------------------------------------
    # Continuous batching (slot pool + admission scheduler).
    # ------------------------------------------------------------------

    def _gather_extra(self, rows: list[int]) -> dict:
        """Select extra-input rows by submission order (dim-1 broadcasts)."""
        out = {}
        for k, v in self.extra.items():
            if v.shape[0] == 1:
                out[k] = jnp.broadcast_to(jnp.asarray(v),
                                          (len(rows),) + tuple(v.shape[1:]))
            elif max(rows) < v.shape[0]:
                out[k] = jnp.asarray(v)[jnp.asarray(rows)]
            else:
                raise ValueError(
                    f"extra_inputs[{k!r}] has {v.shape[0]} rows but request "
                    f"#{max(rows)} needs its own (pass one row per request, "
                    "or a single row to broadcast)")
        return out

    def _check_budget(self, prefill_pos: int, max_new: int, rid) -> None:
        """Every position written past prefill must fit in cache_len: the
        per-slot strip length (dense; writes beyond it are silently dropped
        by the one-hot update) or the block-table width (paged)."""
        writes = prefill_pos + max(max_new - 1, 0)
        if writes > self.cache_len:
            raise ValueError(
                f"request rid={rid} needs {writes} cache positions "
                f"(prefill {prefill_pos} + {max_new - 1} decode writes) "
                f"but cache_len={self.cache_len}")

    def _n_prefix(self) -> int:
        """Model-side prefix positions prefill adds ahead of the tokens."""
        cfg = self.model.cfg
        return cfg.n_patches if cfg.family == "vlm" else 0

    def _bucket_len(self, n: int) -> int:
        """Round a prompt length up to its bucket (pow2 or pad-to-multiple),
        capped so the padded sequence still fits the per-request bound."""
        if not self.bucket:
            return n
        if self.bucket == "pow2":
            b = 1
            while b < n:
                b <<= 1
        else:
            b = -(-n // int(self.bucket)) * int(self.bucket)
        return max(min(b, self.cache_len - self._n_prefix()), n)

    def _worst_blocks(self, r: Request) -> int:
        """Worst-case block count for a request (all cache positions it can
        ever write), computable before prefill runs."""
        writes = self._n_prefix() + len(r.prompt) + max(r.max_new_tokens - 1,
                                                        0)
        return blocks_needed(writes, self.block_size)

    def _can_admit(self, r: Request) -> bool:
        """Paged admission: the pool must cover the request's worst case on
        top of what is already reserved for in-flight requests (so lazy
        growth can never fail mid-decode).  ``generate`` has already
        rejected requests that exceed the whole pool, so a False here
        always clears once live requests finish and recycle blocks."""
        return (self.allocator.n_free - self._reserved
                >= self._worst_blocks(r))

    def _admit(self, r: Request, order: int, seq: int, slot: int, cache,
               key):
        """Prefill ``r`` into ``slot`` and sample its first token.

        ``order`` is the original submission index (extra-input row);
        ``seq`` indexes the scheduler's result list."""
        prompt = np.asarray(r.prompt, np.int32)
        t0 = time.perf_counter()
        plen = len(prompt)
        sb = self._bucket_len(plen)
        if self.bucket:
            # right-pad to the bucket and pass the true length: causality
            # hides the pads, pad KV lands past pos (masked in decode and
            # overwritten as decode proceeds), so outputs are unchanged
            toks = np.zeros((1, sb), np.int32)
            toks[0, :plen] = prompt
            batch = {"tokens": jnp.asarray(toks),
                     "prefill_len": jnp.asarray([plen], np.int32),
                     **self._gather_extra([order])}
        else:
            batch = {"tokens": jnp.asarray(prompt[None]),
                     **self._gather_extra([order])}
        self._prefill_shapes.add(batch["tokens"].shape[1])
        logits, sub = self._prefill(self.params, batch)
        # sub["pos"] covers any model-side prefix (e.g. vlm patches)
        prefill_pos = int(np.asarray(sub["pos"]).reshape(()))
        self._check_budget(prefill_pos, r.max_new_tokens, r.rid)
        blocks: list[int] = []
        reserve_left = 0
        if self.kv_layout == "paged":
            n_pref = blocks_needed(prefill_pos, self.block_size)
            blocks = self.allocator.alloc_n(n_pref)
            reserve_left = self._worst_blocks(r) - n_pref
            self._reserved += reserve_left
            if cache is None:
                cache = self.model.paged_cache_init(
                    batch=self.max_batch, n_blocks=self.allocator.n_blocks,
                    block_size=self.block_size, max_blocks=self.max_blocks,
                    dtype=sub["k"].dtype)
            row = np.zeros((self.max_blocks,), np.int32)
            row[:n_pref] = blocks
            cache = self._paged_write(cache, sub, slot, jnp.asarray(row))
        else:
            if cache is None:
                cache = self._cache_expand(sub, self.max_batch)
            cache = self._slot_write(cache, sub, slot)
        tok = self._sample(logits, jnp.full((1,), r.temperature), key)
        tok = int(np.asarray(jax.block_until_ready(tok))[0])
        ttft_ms = (time.perf_counter() - t0) * 1e3
        return cache, _Slot(req=r, order=seq, tokens=[tok], ttft_ms=ttft_ms,
                            prefill_pos=prefill_pos, blocks=blocks,
                            reserve_left=reserve_left)

    def _generate_continuous(self, items, key) -> list[Result]:
        """items: [(submission order, Request)]; results align with items."""
        bsz = self.max_batch
        paged = self.kv_layout == "paged"
        if paged:
            self.allocator.reset_peak()
        queue = collections.deque(
            (seq, order, r) for seq, (order, r) in enumerate(items))
        slots: list[_Slot | None] = [None] * bsz
        results: list[Result | None] = [None] * len(items)
        cache = None
        toks = np.zeros((bsz, 1), np.int32)
        temps = np.zeros((bsz,), np.float32)
        decode_steps = busy_steps = 0
        ttfts: list[float] = []
        t_start = time.perf_counter()

        def _finish(s: _Slot):
            per_tok = s.decode_s * 1e3 / max(s.steps, 1)
            results[s.order] = Result(s.req.rid, s.tokens, s.ttft_ms,
                                      per_tok)

        def _release(s: _Slot, i: int):
            """Paged: return the slot's blocks to the pool immediately and
            park its block-table row on the null block so its idle decode
            writes cannot touch recycled blocks."""
            nonlocal cache
            if not paged:
                return
            self.allocator.free(s.blocks)
            self._reserved -= s.reserve_left
            s.blocks, s.reserve_left = [], 0
            cache = self._slot_release(cache, i)

        try:
            while queue or any(s is not None for s in slots):
                # admission: refill every free slot before the next decode
                # step
                for i in range(bsz):
                    if slots[i] is None and queue:
                        # paged: admit only when the pool covers the
                        # request's worst case beyond standing reservations
                        # (FIFO - no skip-ahead, so a big request cannot
                        # starve)
                        if paged and not self._can_admit(queue[0][2]):
                            break
                        seq, order, r = queue.popleft()
                        key, sk = jax.random.split(key)
                        cache, s = self._admit(r, order, seq, i, cache, sk)
                        ttfts.append(s.ttft_ms)
                        if len(s.tokens) >= r.max_new_tokens:
                            _finish(s)      # satisfied by prefill alone
                            _release(s, i)
                        else:
                            slots[i] = s
                            toks[i, 0] = s.tokens[-1]
                            temps[i] = r.temperature
                active = [i for i in range(bsz) if slots[i] is not None]
                if not active:
                    continue
                if paged:
                    # lazy growth: each slot's next write position must
                    # have a block before the step; admission reserved the
                    # worst case, so these allocations can never fail
                    # mid-flight
                    for i in active:
                        s = slots[i]
                        pos = s.prefill_pos + s.steps
                        while len(s.blocks) * self.block_size <= pos:
                            blk = self.allocator.alloc()
                            cache = self._bt_set(cache, i, len(s.blocks),
                                                 blk)
                            s.blocks.append(blk)
                            s.reserve_left -= 1
                            self._reserved -= 1
                # one decode step over the whole slot pool (fixed shapes;
                # idle slots compute too - their rows are masked by
                # per-slot pos and fully rewritten on the next admission;
                # paged idle rows write into the null block)
                t0 = time.perf_counter()
                key, sk = jax.random.split(key)
                logits, cache = self._decode(self.params, cache,
                                             jnp.asarray(toks))
                nxt = np.asarray(self._sample(logits, jnp.asarray(temps),
                                              sk))
                dt = time.perf_counter() - t0
                decode_steps += 1
                busy_steps += len(active)
                for i in active:
                    s = slots[i]
                    s.tokens.append(int(nxt[i]))
                    s.steps += 1
                    s.decode_s += dt
                    toks[i, 0] = nxt[i]
                    if len(s.tokens) >= s.req.max_new_tokens:
                        _finish(s)
                        _release(s, i)
                        slots[i] = None  # freed: refilled on the next pass
        except BaseException:
            # keep the allocator consistent if anything aborts the batch
            # mid-schedule (the device cache is rebuilt from scratch per
            # generate call, so host-side block ownership is the only
            # state that must survive for the engine to stay usable)
            if paged:
                for s in slots:
                    if s is not None and s.blocks:
                        self.allocator.free(s.blocks)
                        self._reserved -= s.reserve_left
            raise

        wall = time.perf_counter() - t_start
        gen = sum(len(r.tokens) for r in results)
        self.last_stats = EngineStats(
            "continuous", wall, gen, gen / max(wall, 1e-9), decode_steps,
            busy_steps / max(bsz * decode_steps, 1),
            float(np.mean(ttfts)) if ttfts else 0.0,
            kv_layout=self.kv_layout,
            prefill_compiles=len(self._prefill_shapes),
            block_util_peak=(self.allocator.stats().peak_utilization
                             if paged else 0.0))
        return results

    # ------------------------------------------------------------------
    # Lock-step group batching (legacy / scan-cache fallback).
    # ------------------------------------------------------------------

    def _pad_prompts(self, prompts: list[list[int]]) -> np.ndarray:
        # left-pad to a common length (uniform-position cache layout)
        maxlen = max(len(p) for p in prompts)
        out = np.zeros((len(prompts), maxlen), np.int32)
        for i, p in enumerate(prompts):
            out[i, maxlen - len(p):] = p
        return out

    def _generate_lockstep(self, items, key) -> list[Result]:
        """items: [(submission order, Request)]; results align with items."""
        results: list[Result | None] = [None] * len(items)
        queue = [(seq, order, r) for seq, (order, r) in enumerate(items)]
        decode_steps = busy_steps = 0
        ttfts: list[float] = []
        t_start = time.perf_counter()
        while queue:
            group = queue[: self.max_batch]
            queue = queue[self.max_batch:]
            key = jax.random.fold_in(key, len(queue))
            stats = self._generate_group(group, key, results)
            decode_steps += stats[0]
            busy_steps += stats[1]
            ttfts.extend(stats[2])
        wall = time.perf_counter() - t_start
        gen = sum(len(r.tokens) for r in results)
        self.last_stats = EngineStats(
            "lockstep", wall, gen, gen / max(wall, 1e-9), decode_steps,
            busy_steps / max(self.max_batch * decode_steps, 1),
            float(np.mean(ttfts)) if ttfts else 0.0,
            prefill_compiles=len(self._prefill_shapes))
        return results

    def _generate_group(self, group, key, results):
        reqs = [r for _, _, r in group]
        prompts = self._pad_prompts([r.prompt for r in reqs])
        self._prefill_shapes.add(prompts.shape[1])
        batch = {"tokens": jnp.asarray(prompts),
                 **self._gather_extra([order for _, order, _ in group])}
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        jax.block_until_ready(logits)
        prefill_ms = (time.perf_counter() - t0) * 1e3
        max_new = max(r.max_new_tokens for r in reqs)
        if self._slot_capable:
            # uniform-position KV layout: the whole group decodes in step,
            # so the group's slowest member sets the write budget (scan/ring
            # cache families manage their own state length)
            self._check_budget(int(np.asarray(cache["pos"])), max_new,
                               [r.rid for r in reqs])
        temps = jnp.asarray([r.temperature for r in reqs], jnp.float32)
        key, sk = jax.random.split(key)
        toks = np.asarray(self._sample(logits, temps, sk))[:, None]
        outs = [[int(toks[i, 0])] for i in range(len(reqs))]
        t1 = time.perf_counter()
        n_steps = 0
        for _ in range(max_new - 1):
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(toks, jnp.int32))
            key, sk = jax.random.split(key)
            toks = np.asarray(self._sample(logits, temps, sk))[:, None]
            n_steps += 1
            for i, r in enumerate(reqs):
                if len(outs[i]) < r.max_new_tokens:
                    outs[i].append(int(toks[i, 0]))
        jax.block_until_ready(logits)
        decode_ms = ((time.perf_counter() - t1) * 1e3 / max(n_steps, 1))
        busy_total = 0
        # recompute busy slot-steps: request i is busy for its first
        # (max_new_tokens - 1) decode steps of this group
        for r in reqs:
            busy_total += min(max(r.max_new_tokens - 1, 0), max(n_steps, 0))
        for i, (seq, _, r) in enumerate(group):
            results[seq] = Result(r.rid, outs[i], prefill_ms, decode_ms)
        return n_steps, busy_total, [prefill_ms] * len(reqs)
