"""Slot-based continuous-batching serving engine.

The paper's multi-core result (Ara2 §7.1: eight 2-lane cores beat one
16-lane core by >3x at equal FPU count, because eight independent issue
streams remove the single-dispatcher bottleneck) maps onto serving as:
many independently scheduled decode *slots* beat one lock-step batch whose
cadence is set by its slowest member.

Two scheduling modes:

* ``continuous`` (the default for every family) - a fixed pool of
  ``max_batch`` decode slots with per-slot cache state and per-slot
  positions.  An admission scheduler prefills a queued request into a
  freed slot *immediately* (prefill-on-admit via
  ``model.cache_slot_write``); the other slots keep decoding on the next
  step.  A short request never holds its neighbors hostage.  The slot
  state is per-slot KV strips for the transformer families and per-slot
  *recurrent* state for the scan families (ssm/hybrid/encdec: conv tails,
  SSD/LSTM cell states, sliding-window ring KV, cross-attention strips —
  see ``repro.models.slot_state``); a freed or preempted scan slot is
  zeroed via ``model.cache_slot_reset`` so no recurrent state survives
  its request.

* ``lockstep`` - the legacy group scheduler, kept behind the ``mode``
  flag as a baseline (and as the uniform-length reference the
  conformance property tests continuous mode against): requests run in
  groups of ``max_batch``; a finished sequence's slot idles until the
  whole group drains, and slot refill re-runs a batched prefill over the
  next waiting group.

Continuous mode supports two KV layouts (``kv_layout``):

* ``dense`` (default) - every slot reserves a full ``(Hkv, cache_len, D)``
  KV strip per layer, so admission enforces ``prefill + decode writes <=
  cache_len`` per request and memory is bounded by worst-case reservation
  (``max_batch * cache_len`` positions live at all times).

* ``paged`` - KV lives in one global pool of fixed-size blocks
  (``repro.serving.kvcache.BlockAllocator``) addressed through per-slot
  block tables; *both* phases run the paged-attention kernels
  (``repro.kernels.paged_attention``): decode single-token gather, and a
  **chunked prefill** that admits a prompt in ``block_size`` chunks, each
  chunk's K/V written straight into a just-allocated pool block and its
  queries attending over the blocks written so far — the dense batch-1
  ``(L, Hkv, prompt_len, hd)`` prefill cache of the old
  prefill-then-scatter path never exists, and one compiled chunk shape
  serves every prompt length.  Admission is bounded by *free blocks*, not
  a per-slot length: blocks are allocated lazily as a request's position
  grows (prefill chunks and decode writes alike), and a finished request
  returns its blocks immediately - so a trace whose summed KV footprint
  exceeds ``max_batch * cache_len`` still serves as long as the
  *concurrently live* footprint fits the pool.  ``cache_len`` remains
  only the per-request context bound (the block table's width).  The
  allocator may be *external and shared* between engines (``allocator=``):
  a multi-replica cluster (``repro.serving.cluster.ClusterEngine``)
  passes one pool to every replica, tagging allocations with ``owner=``.

Paged admission policies (``admission=``):

* ``reserve`` (default) - admit only when the pool covers the request's
  worst case beyond standing reservations; lazy growth can never fail.
* ``overcommit`` - admit when the *first prefill chunk's* block is free;
  lazy growth (a later prefill chunk or a decode write) may then find
  the pool empty, which raises
  :class:`repro.serving.kvcache.PoolPressure` out of ``session_step`` so
  a cluster scheduler can preempt a victim (``session_preempt``: blocks
  freed, request re-queued carrying its generated prefix in
  ``Request.done`` for re-prefill) and retry — a long prompt can be
  preempted *mid-prefill* (its chunks already computed are simply redone
  on re-admission) and a retried step resumes a surviving
  half-prefilled slot at its next chunk.  Overcommit is a cluster
  driver mode - plain ``generate`` on an overcommitted engine propagates
  the pressure error instead of preempting.

The continuous scheduler is exposed as a *stepwise session API*
(``begin_session`` / ``session_admit`` / ``session_step`` /
``session_preempt`` / ``end_session``) so an outer scheduler can
interleave several engines over one pool; ``generate`` drives the same
API for the single-engine case.

Prompt-length bucketing (``bucket=``): dense-layout prompts are prefilled
at their exact length by default - one compile per distinct length.  With
``bucket="pow2"`` (or an integer multiple), continuous-mode prefills are
right-padded up to the bucket boundary and the true length rides in
``batch["prefill_len"]``; causal masking hides the pads, so outputs are
identical while compiles drop to one per bucket
(``EngineStats.prefill_compiles`` counts distinct compiled prefill
shapes).  The paged layout ignores ``bucket``: its chunked prefill
compiles exactly one ``(1, block_size)`` chunk shape for all prompts.
Bucketing requires a prefill that understands ``prefill_len``
(``model.supports_prefill_len``) — a scan-family prefill folds every
position into recurrent state, so right-padding would corrupt it, and
``bucket=`` is rejected there.

Prefix caching (``prefix_cache=True``, paged layout only): full
``block_size`` spans of a finished prefill's prompt are *registered* in
the allocator's prefix index under exact chain keys (nested tuples over
the span's token ids, chained on the parent key — token-exact, no hash
collisions).  A later admission whose prompt starts with the same spans
*references* the resident blocks instead of recomputing them: refcount
incremented, chunked prefill fast-forwarded to the first cold block,
``EngineStats.prefix_hits``/``prefix_tokens_reused`` counting the skip.
A request whose whole prefill is covered re-runs only its final chunk
(the engine needs that chunk's logits to sample the first token), and
because that chunk's pool block is shared, the write barrier in
``_advance_prefill`` gives the slot a private **copy-on-write** block
first (allocate, byte-copy, re-table, drop the shared reference).
Blocks written by decode are never registered — only chunk-prefill
output is, so a cache hit serves bytes that are bit-identical to what
the cold path would recompute.  Registered blocks whose last reference
drops park in the allocator's cached LRU (revivable by a later hit,
evicted LRU-first when the pool needs them), and the engine keeps its
device-side pool alive across sessions so cached bytes stay resident;
``session_abort`` flushes this engine's index entries instead (an
aborted session's pool state is not trustworthy).

Per-request sampling is vectorized and **request-keyed**: row ``i``'s
``t``-th token is sampled with ``fold_in(fold_in(key, rid_i), t)``, so a
request's sampled stream depends only on its ``rid`` and the base key -
never on which slot, step, replica, or scheduler served it (and a
preempted request resumes its stream exactly where it stopped).
Temperature<=0 rows take argmax (deterministic regardless of the key);
temperature>0 rows sample at their own temperature - never at the batch
max.
"""
from __future__ import annotations

import collections
import dataclasses
import queue as queue_mod
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model
from . import kvcache
from .attribution import NULL_ATTR, VERDICTS, dominant_verdict
from .kvcache import BlockAllocator, PoolPressure, blocks_needed
from .slo import make_policy
from .telemetry import MONOTONIC, NULL_TRACER, MetricsRegistry


@dataclasses.dataclass
class Request:
    """One generation request.

    The scheduler may admit, move, preempt, and re-admit a request
    freely: everything observable about its output is a pure function of
    (``prompt``, ``max_new_tokens``, ``temperature``, ``rid``, base PRNG
    key) — the conformance property in ``tests/test_serving_props.py``
    holds the token stream byte-identical across every scheduler, cache
    layout, and topology.  The remaining fields are scheduler
    bookkeeping that preemption threads through a requeue."""
    prompt: list[int]
    max_new_tokens: int = 32       # total budget, including ``done``
    temperature: float = 0.0
    rid: int = 0
    priority: int = 0              # preemption picks the lowest first
    # tokens already generated before this (re)admission: set by
    # session_preempt when a request is re-queued; prefill covers
    # prompt + done and sampling resumes at stream index len(done)
    done: tuple = ()
    # time-to-first-token of the *first* admission, carried across
    # preemptions so Result.prefill_ms stays the request's real TTFT
    first_ttft_ms: float | None = None
    # perf_counter of the *first* admission, carried across preemptions
    # that fired before any token was sampled (mid-prefill eviction):
    # the eventual first token's TTFT must span the aborted attempt and
    # the hysteresis wait, not restart at re-admission
    first_admit_t: float | None = None
    # times this request has been preempted (a victim evicted mid-prefill
    # carries no ``done`` prefix, so ``done`` alone cannot mark a requeue)
    requeues: int = 0
    # SLO budgets (None = best-effort, the default): ``slo_ttft_ms`` is
    # the enqueue -> first-token target, ``slo_tpot_ms`` the decode
    # ms-per-output-token target.  Budgets never change the token
    # stream — they drive the scheduling policies in ``serving.slo``
    # (admission order, victim protection, starvation pressure) and the
    # ``slo_*`` attainment metrics.
    slo_ttft_ms: float | None = None
    slo_tpot_ms: float | None = None


@dataclasses.dataclass
class Result:
    """One request's output: the full generated stream (a preempted
    request's ``done`` prefix included — resume is invisible) plus its
    latency split."""
    rid: int
    tokens: list[int]
    prefill_ms: float = 0.0        # time-to-first-token for this request
    decode_ms_per_tok: float = 0.0


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One streamed token, emitted the moment it is sampled.

    ``index`` is the token's position in the request's *full* output
    stream (``Result.tokens``), so a client concatenating events in
    per-rid index order reconstructs the byte-identical stream
    ``generate`` would have returned.  Preemption is invisible here too:
    a preempted request's already-emitted tokens ride along in
    ``Request.done`` and are never re-emitted — emission resumes at
    ``len(done)`` after re-admission.  ``final`` marks the request's
    last token.

    Emitted via the ``on_token`` callback (``begin_session`` /
    ``generate``) or consumed through the pull-based ``stream``
    generator.  In the threaded cluster driver the callback fires on
    replica worker threads, so it must be thread-safe."""
    rid: int
    token: int
    index: int
    final: bool


def _stream_events(run):
    """Drive ``run(on_token_callback)`` on a background thread, yielding
    the :class:`TokenEvent` rows it emits in order.  Shared by
    ``ServeEngine.stream`` and ``ClusterEngine.stream``: the callback
    just enqueues events (thread-safe — cluster workers may emit
    concurrently), the consumer thread pulls them as they land.  An
    exception from the run re-raises out of the generator after the
    driver thread is joined."""
    q: queue_mod.SimpleQueue = queue_mod.SimpleQueue()

    def driver():
        try:
            run(q.put)
            q.put(("done", None))
        except BaseException as e:      # re-raised in the consumer
            q.put(("error", e))

    t = threading.Thread(target=driver, name="stream-driver", daemon=True)
    t.start()
    while True:
        item = q.get()
        if isinstance(item, TokenEvent):
            yield item
            continue
        kind, payload = item
        t.join()
        if kind == "error":
            raise payload
        return


@dataclasses.dataclass
class EngineStats:
    """Aggregate metrics for the last ``generate`` call (or session).

    ``occupancy`` is the utilization headline this repo exists to
    measure: the fixed-shape decode launch always computes ``max_batch``
    slot lanes, so occupancy is the fraction of launched lanes that held
    a live request — the serving twin of the paper's vector-lane
    utilization under short workloads.

    Built as a *view over a* :class:`~repro.serving.telemetry.MetricsRegistry`
    (``from_registry``): the registry holds the raw counters and
    histogram samples, this dataclass snapshots the derived numbers.
    The mean fields predate the registry and are kept for compatibility;
    the ``*_p50/p90/p99`` fields are exact nearest-rank percentiles over
    the raw samples, so cluster stats can merge replica histograms
    instead of averaging replica means."""
    mode: str                      # resolved scheduler ("cluster" at top)
    wall_s: float
    generated_tokens: int
    tokens_per_s: float
    decode_steps: int              # decode launches (cluster: summed)
    occupancy: float               # busy slot-steps / (max_batch * steps)
    ttft_ms_mean: float            # mean time-to-first-token
    kv_layout: str = "dense"
    prefill_compiles: int = 0      # distinct prefill shapes compiled so far
    block_util_peak: float = 0.0   # paged: peak live blocks / pool capacity
    preempted: int = 0             # requests evicted under pool pressure
    requeued: int = 0              # re-admissions of preempted requests
    router_policy: str = ""        # cluster-level: routing policy used
    prefix_hits: int = 0           # prompt blocks admitted by reference
    prefix_tokens_reused: int = 0  # prefill positions skipped via hits
    ttft_ms_p50: float = 0.0       # time-to-first-token percentiles
    ttft_ms_p90: float = 0.0
    ttft_ms_p99: float = 0.0
    tpot_ms_mean: float = 0.0      # time-per-output-token (per request)
    tpot_ms_p50: float = 0.0
    tpot_ms_p90: float = 0.0
    tpot_ms_p99: float = 0.0
    queue_age_ms_mean: float = 0.0  # enqueue -> admission wait
    queue_age_ms_p99: float = 0.0
    # -- SLO attainment (repro.serving.slo) --
    # Only requests carrying a budget are scored; with no budgets in the
    # trace the totals stay 0 and ``slo_attainment`` reads 1.0.
    sched_policy: str = ""         # admission/victim policy in effect
    slo_ttft_total: int = 0        # first tokens scored against a budget
    slo_ttft_attained: int = 0     # ... that landed inside it
    slo_tpot_total: int = 0        # finished requests with a TPOT budget
    slo_tpot_attained: int = 0
    slo_attainment: float = 1.0    # attained / total over both phases
    slo_starve_preempts: int = 0   # cluster: starvation-pressure evictions
    # -- utilization attribution (repro.serving.attribution) --
    # All-zero/empty unless an Attributor was attached.  fu_utilization
    # is the paper-§6 analog: useful flops (idle slot lanes excluded,
    # like idle vector lanes) per second of device-busy time, over the
    # machine's peak — the serving twin of Ara2's FU-utilization figure.
    fu_utilization: float = 0.0
    achieved_flops_per_s: float = 0.0  # useful FLOP/s over busy device time
    achieved_bytes_per_s: float = 0.0  # HBM bytes/s over busy device time
    decode_ai: float = 0.0         # decode executable flops/byte
    ridge_ai: float = 0.0          # machine ridge point (flops/byte)
    bottleneck: str = ""           # dominant decode verdict (issue/
    #                                memory/compute/idle)
    prefill_bottleneck: str = ""   # dominant prefill verdict
    verdict_counts: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def from_registry(cls, m: MetricsRegistry, *, mode: str, wall_s: float,
                      kv_layout: str = "dense", prefill_compiles: int = 0,
                      block_util_peak: float = 0.0,
                      router_policy: str = "",
                      sched_policy: str = "") -> "EngineStats":
        """Derive the stats view from a registry (one engine session's,
        or several replicas' registries merged)."""
        ttft = m.histogram("ttft_ms")
        tpot = m.histogram("tpot_ms")
        qage = m.histogram("queue_age_ms")
        gen = m.counter("generated_tokens").n
        steps = m.counter("decode_steps").n
        busy = m.counter("busy_slot_steps").n
        offered = m.counter("offered_slot_steps").n
        # attribution rollup: raw per-launch samples (replica registries
        # merge losslessly, so a cluster's figure is derived from the
        # union exactly as a single engine's is)
        dev_s = sum(m.histogram("attr_device_ms").samples) / 1e3
        pf_s = sum(m.histogram("attr_prefill_ms").samples) / 1e3
        useful = (sum(m.histogram("attr_step_flops").samples)
                  + sum(m.histogram("attr_prefill_flops").samples))
        moved = (sum(m.histogram("attr_step_bytes").samples)
                 + sum(m.histogram("attr_prefill_bytes").samples))
        busy_s = dev_s + pf_s
        peak = m.gauge("attr_peak_flops").value
        mem_bw = m.gauge("attr_peak_bytes_s").value
        verdicts = {v: m.counter(f"attr_verdict_{v}").n for v in VERDICTS}
        verdicts = {k: n for k, n in verdicts.items() if n}
        pf_verdicts = {v: m.counter(f"attr_prefill_verdict_{v}").n
                       for v in VERDICTS}
        ach_f = useful / busy_s if busy_s > 0 else 0.0
        ach_b = moved / busy_s if busy_s > 0 else 0.0
        slo_tt = m.counter("slo_ttft_total").n
        slo_ta = m.counter("slo_ttft_attained").n
        slo_pt = m.counter("slo_tpot_total").n
        slo_pa = m.counter("slo_tpot_attained").n
        return cls(
            mode, wall_s, gen, gen / max(wall_s, 1e-9), steps,
            busy / max(offered, 1), ttft.mean,
            kv_layout=kv_layout, prefill_compiles=prefill_compiles,
            block_util_peak=block_util_peak,
            preempted=m.counter("preempted").n,
            requeued=m.counter("requeued").n,
            router_policy=router_policy,
            prefix_hits=m.counter("prefix_hits").n,
            prefix_tokens_reused=m.counter("prefix_tokens_reused").n,
            ttft_ms_p50=ttft.percentile(50),
            ttft_ms_p90=ttft.percentile(90),
            ttft_ms_p99=ttft.percentile(99),
            tpot_ms_mean=tpot.mean,
            tpot_ms_p50=tpot.percentile(50),
            tpot_ms_p90=tpot.percentile(90),
            tpot_ms_p99=tpot.percentile(99),
            queue_age_ms_mean=qage.mean,
            queue_age_ms_p99=qage.percentile(99),
            sched_policy=sched_policy,
            slo_ttft_total=slo_tt, slo_ttft_attained=slo_ta,
            slo_tpot_total=slo_pt, slo_tpot_attained=slo_pa,
            slo_attainment=((slo_ta + slo_pa) / (slo_tt + slo_pt)
                            if slo_tt + slo_pt else 1.0),
            slo_starve_preempts=m.counter("slo_starve_preempts").n,
            fu_utilization=ach_f / peak if peak > 0 else 0.0,
            achieved_flops_per_s=ach_f,
            achieved_bytes_per_s=ach_b,
            decode_ai=m.gauge("attr_decode_ai").value,
            ridge_ai=(peak / mem_bw if mem_bw > 0 else 0.0),
            bottleneck=dominant_verdict(verdicts),
            prefill_bottleneck=dominant_verdict(pf_verdicts),
            verdict_counts=verdicts)


@dataclasses.dataclass
class _Slot:
    req: Request
    tag: int                       # caller's result index (``tag`` arg)
    tokens: list[int]              # tokens generated *this* admission
    ttft_ms: float
    admit_seq: int = 0             # global admission order (victim pick)
    decode_s: float = 0.0
    steps: int = 0
    # paged layout bookkeeping
    prefill_pos: int = 0           # cache positions the prefill will write
    blocks: list[int] = dataclasses.field(default_factory=list)
    reserve_left: int = 0          # worst-case blocks not yet allocated
    # chunked-prefill progress: chunks completed so far, or None once the
    # prefill has finished and the first token is sampled (dense slots are
    # always None — their prefill runs at admit)
    chunks_done: int | None = None
    # prefix cache: blocks[:shared_until] are referenced from the prefix
    # index (refcounted, read-only for this slot until copy-on-write)
    shared_until: int = 0
    extra_row: int = 0             # extra_inputs row (vlm patches)
    admit_t: float = 0.0           # clock time of the *first* admission
    #                                (TTFT base, carried across preempts)
    enqueue_t: float | None = None  # clock time the request entered the
    #                                caller's queue (SLO deadline base)
    span_t0: float = 0.0           # clock time of *this* admission (the
    #                                request span's start in the trace)
    first_tok_t: float = 0.0       # clock time of this admission's first
    #                                sampled token (decode-stretch start)


@dataclasses.dataclass
class _Session:
    """Mutable state of one stepwise continuous-batching run.

    All scalar accounting (decode/busy steps, generated tokens, preempt
    and prefix counters) and every latency sample (TTFT, TPOT, queue
    age) live in ``metrics`` — ``end_session`` derives
    :class:`EngineStats` from it, and the cluster merges replica
    registries for exact cross-replica percentiles."""
    key: Any                       # base PRNG key (rid/step-keyed streams)
    slots: list
    toks: np.ndarray               # (B, 1) next-token feed
    temps: np.ndarray              # (B,) per-slot temperature
    rids: np.ndarray               # (B,) per-slot request id
    tok_idx: np.ndarray            # (B,) next sample's stream index
    metrics: MetricsRegistry
    t_start: float
    cache: Any = None
    admit_counter: int = 0
    # Results finished during session_step's prefill phase, parked here so
    # they survive a PoolPressure raised later in the same step (the slot
    # is already released — a lost local would drop the Result for good);
    # the next successful session_step returns them
    finished_pending: list = dataclasses.field(default_factory=list)
    # streaming: called with a TokenEvent for every token the moment it
    # is sampled (None = no streaming).  Runs on whatever thread drives
    # the session, so cluster-level callbacks must be thread-safe.
    on_token: Any = None


def _sample_rows(logits, temps, key, rids, tok_idx):
    """Per-row temperature sampling over (B, V) logits.

    Row ``i`` uses the key ``fold_in(fold_in(key, rids[i]), tok_idx[i])``,
    so a request's sampled stream is a pure function of (base key, rid,
    token index) - independent of slot, step order, and batch composition.
    Rows with temperature <= 0 take argmax (greedy, key-independent);
    rows with temperature > 0 sample a categorical at their own
    temperature."""
    keys = jax.vmap(
        lambda r, t: jax.random.fold_in(jax.random.fold_in(key, r), t)
    )(rids, tok_idx)
    greedy = jnp.argmax(logits, axis=-1)
    safe = jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, logits / safe)
    return jnp.where(temps > 0.0, sampled, greedy)


class ServeEngine:
    """Batched generation over the uniform Model API.

    Invariants the property suite (``tests/test_serving_props.py``)
    asserts over this class:

    * **scheduler-invisible tokens** — for one trace and base key, every
      mode/layout/topology combination emits byte-identical token
      streams (greedy rows are argmax; sampled rows are request-keyed,
      see ``_sample_rows``).
    * **block conservation** (paged) — after ``generate`` returns or
      raises, every block and reservation is back in the pool.
    * **preemption-invisible resume** — a preempted request re-admitted
      with its ``done`` prefix reproduces the uninterrupted stream.
    * **no state leak** — a freed/preempted slot's cache state cannot
      reach a later occupant: scan-family slots are zeroed on release
      (``model.cache_slot_reset``), KV-family slots are masked by their
      per-slot ``pos`` and fully rewritten at the next admission.

    mode: "auto" (resolves to continuous - every family is
    slot-addressable), "continuous", or "lockstep" (the group-barrier
    baseline).

    ``extra_inputs`` (vlm patches / encdec frames): leaves carry one row
    per request, indexed by submission order; a leaf with leading dim 1
    broadcasts to every request.  Too few rows is an error, not a clamp.

    kv_layout: "dense" or "paged" (continuous mode only; see module doc).
    The scan families (ssm/hybrid/encdec) serve on the dense slot layout;
    requesting "paged" for them raises (recurrent state is O(1) per slot
    already - there is nothing to page).
    block_size / n_blocks size the paged pool - n_blocks defaults to the
    dense layout's footprint (max_batch * cache_len positions) plus the
    null block.  ``allocator=`` injects an external (shared) pool instead;
    ``owner=`` tags this engine's allocations in it; ``admission=``
    selects "reserve" (default) or "overcommit" (cluster preemption mode).
    bucket: None (exact-length prefills), "pow2", or an integer
    pad-to-multiple; rejected when the family's prefill cannot mask pads
    (``model.supports_prefill_len``).
    policy: scheduling policy name from ``serving.slo.POLICIES`` (or a
    ``SchedPolicy`` instance) — drives admission order inside
    ``generate`` and the ``session_victims`` ranking; "fifo" (default)
    is byte-for-byte the pre-policy scheduler, and every policy is
    token-identical to it (request-keyed sampling; budgets only move
    *when* a request runs).
    prefix_cache: paged layout only — admit shared prompt prefixes by
    referencing resident pool blocks (see the module doc); rejected for
    families whose prefill carries a non-token prefix (vlm patches:
    patch content is not addressable by token ids).
    tracer / clock / track: telemetry (``repro.serving.telemetry``;
    ``docs/observability.md``).  ``tracer`` defaults to the no-op
    ``NULL_TRACER``; a real ``Tracer`` records request-lifecycle spans,
    pool events, and per-step dispatch/device spans, host-side only (no
    compiled function depends on it — ``set_tracer`` may attach one to
    a warm engine).  ``clock`` injects the timebase every latency
    number is computed from (defaults to the tracer's clock when a
    tracer is given, else the process monotonic clock).  ``track``
    names this engine's trace track (default ``engine{owner}``; the
    cluster passes ``replica{i}``).
    """

    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 cache_len: int = 1024, extra_inputs: dict | None = None,
                 mode: str = "auto", kv_layout: str = "dense",
                 block_size: int | None = None,
                 n_blocks: int | None = None,
                 bucket: str | int | None = None,
                 allocator: BlockAllocator | None = None,
                 admission: str = "reserve", owner: Any = 0,
                 prefix_cache: bool = False, policy="fifo",
                 tracer=None, clock=None, track: str | None = None,
                 attribution=None):
        assert mode in ("auto", "continuous", "lockstep"), mode
        assert kv_layout in ("dense", "paged"), kv_layout
        assert admission in ("reserve", "overcommit"), admission
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.extra = extra_inputs or {}
        self.bucket = bucket
        self.owner = owner
        self.tracer = NULL_TRACER
        self.attr = NULL_ATTR
        self.clock = MONOTONIC
        self.track = track if track is not None else f"engine{owner}"
        # survives end_session so an outer aggregator (the cluster) can
        # merge per-replica registries after sessions close
        self.last_metrics = MetricsRegistry()
        slot_capable = model.cache_slot_write is not None
        if mode == "auto":
            mode = "continuous" if slot_capable else "lockstep"
        if mode == "continuous" and not slot_capable:
            # every built-in family ships slot hooks now; a custom Model
            # without them must ask for lockstep explicitly
            raise ValueError(
                f"mode='continuous': model {model.cfg.name!r} exposes no "
                "cache_slot_write hook (pass mode='lockstep')")
        if bucket and not model.supports_prefill_len:
            raise ValueError(
                f"bucket={bucket!r}: family {model.cfg.family!r} prefill "
                "cannot mask right-pads (recurrent state would absorb "
                "them); drop bucket= for scan families")
        if kv_layout == "paged":
            if model.decode_paged is None:
                raise ValueError(
                    f"kv_layout='paged': family {model.cfg.family!r} has "
                    "no paged cache hooks")
            if mode != "continuous":
                raise ValueError(
                    "kv_layout='paged' requires the continuous scheduler")
        elif allocator is not None:
            raise ValueError("allocator= requires kv_layout='paged'")
        elif admission != "reserve":
            raise ValueError("admission='overcommit' requires "
                             "kv_layout='paged'")
        elif prefix_cache:
            raise ValueError("prefix_cache=True requires kv_layout="
                             "'paged' (there are no blocks to share)")
        if prefix_cache and model.cfg.family == "vlm":
            raise ValueError(
                "prefix_cache=True: vlm prompts start with a patch prefix "
                "whose content is not addressable by token ids, so prefix "
                "blocks cannot be content-hashed")
        self.mode = mode
        self.kv_layout = kv_layout
        self.policy = make_policy(policy)
        self._admission = admission
        self.prefix_cache = prefix_cache
        self.last_stats: EngineStats | None = None
        self._prefill_shapes: set[int] = set()   # compiled prefill lengths
        self._sess: _Session | None = None
        self._sample = jax.jit(_sample_rows)
        self._slot_capable = slot_capable
        # the cache is dead after every call that consumes it - donate so
        # XLA updates the multi-GB KV buffers in place instead of copying
        if kv_layout == "paged":
            if allocator is not None:
                if n_blocks is not None:
                    raise ValueError(
                        "n_blocks conflicts with an external allocator "
                        "(the pool is already sized)")
                if block_size is not None \
                        and block_size != allocator.block_size:
                    raise ValueError(
                        f"block_size={block_size} conflicts with the "
                        f"external allocator's {allocator.block_size}")
                self._owns_pool = False
                block_size = allocator.block_size
            else:
                self._owns_pool = True
                if block_size is None:
                    block_size = 16
            self.block_size = block_size
            self.max_blocks = blocks_needed(cache_len, block_size)
            if allocator is None:
                if n_blocks is None:
                    n_blocks = max_batch * self.max_blocks + 1
                allocator = BlockAllocator(n_blocks, block_size)
            allocator.claim_policy(admission)
            self.allocator = allocator
            # chunked prefill: one block_size chunk per call, slot/chunk/
            # length all traced — a single compile serves every prompt
            # length (``bucket=`` is ignored; there is nothing to bucket)
            self._prefill_chunk = jax.jit(model.prefill_paged,
                                          donate_argnums=(1,))
            self._decode = jax.jit(model.decode_paged, donate_argnums=(1,))
            self._bt_set = jax.jit(kvcache.bt_set_entry, donate_argnums=(0,))
            self._slot_release = jax.jit(kvcache.slot_release,
                                         donate_argnums=(0,))
            self._copy_block = jax.jit(kvcache.pool_copy_block,
                                       donate_argnums=(0,))
            # device pool persisted across sessions (prefix_cache only):
            # cached blocks' bytes must stay resident to be hit again
            self._pcache = None
        else:
            self._decode = jax.jit(model.decode, donate_argnums=(1,))
            self._prefill = jax.jit(
                lambda p, b: model.prefill(p, b, cache_len=cache_len))
            if slot_capable:
                self._cache_expand = jax.jit(model.cache_expand,
                                             static_argnums=(1,))
                self._slot_write = jax.jit(model.cache_slot_write,
                                           donate_argnums=(0,))
            # scan families: zero a slot's recurrent state on free/preempt
            # (KV families have no reset hook - pos masking covers them)
            self._slot_reset = (
                jax.jit(model.cache_slot_reset, donate_argnums=(0,))
                if model.cache_slot_reset is not None else None)
        if tracer is not None:
            self.set_tracer(tracer)
        if clock is not None:
            self.clock = clock
        if attribution is not None:
            self.set_attributor(attribution)

    # ------------------------------------------------------------------
    # Telemetry plumbing.
    # ------------------------------------------------------------------

    def set_tracer(self, tracer, track: str | None = None) -> None:
        """Attach (or detach, with None) a tracer.  Host-side only — no
        compiled function depends on it, so a warm engine keeps its
        caches.  The engine adopts an enabled tracer's clock so spans
        and instants share one timeline (assign ``self.clock`` after to
        override); an owned pool's allocator follows the same tracer."""
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if track is not None:
            self.track = track
        if self.tracer.enabled:
            self.clock = self.tracer.clock
        if self.kv_layout == "paged" and self._owns_pool:
            self.allocator.set_tracer(self.tracer)

    def set_attributor(self, attributor) -> None:
        """Attach (or detach, with None) a utilization attributor
        (``repro.serving.attribution.Attributor``).  Host-side only,
        like the tracer: no compiled function the engine executes
        depends on it (executable costs come from a separate AOT
        lowering of the same jitted callables, memoized per shape), so
        tokens are byte-identical with attribution on vs off, and a
        warm engine keeps its caches.  Attribution covers the
        continuous scheduler's phases — decode launches and prefills
        (dense and chunked paged alike); the legacy lockstep scheduler
        is not attributed.  One attributor may be shared across a
        cluster's replicas (the cost memo is shape-keyed)."""
        self.attr = attributor if attributor is not None else NULL_ATTR

    def _slot_track(self, i: int) -> str:
        """Trace track of slot ``i`` (request spans nest per slot, so
        concurrent slots never interleave spans on one track)."""
        return f"{self.track}/slot{i}"

    # ------------------------------------------------------------------
    # Public API.
    # ------------------------------------------------------------------

    def generate(self, requests: list[Request], key=None,
                 on_token=None) -> list[Result]:
        """Run ``requests`` to completion and return their Results.
        ``on_token`` (continuous mode only) streams every sampled token
        as a :class:`TokenEvent` the moment it exists — see ``stream``
        for the pull-based generator over the same events."""
        key = key if key is not None else jax.random.key(0)
        requests = list(requests)
        todo = [(i, r) for i, r in enumerate(requests)
                if r.max_new_tokens - len(r.done) > 0]
        if not todo:
            self.last_metrics = MetricsRegistry()
            self.last_stats = EngineStats(
                self.mode, 0.0, 0, 0.0, 0, 0.0, 0.0,
                kv_layout=self.kv_layout,
                prefill_compiles=len(self._prefill_shapes))
            return [Result(r.rid, list(r.done)) for r in requests]
        if self.kv_layout == "paged":
            # reject impossible requests before any work is scheduled: a
            # raise mid-schedule would abort the batch with blocks still
            # allocated (and admission would otherwise stall forever on a
            # request that can never fit)
            for _, r in todo:
                self.check_request(r)
        if self.mode == "continuous":
            done = self._generate_continuous(todo, key, on_token)
        else:
            if on_token is not None:
                raise ValueError("streaming (on_token) requires the "
                                 "continuous scheduler")
            done = self._generate_lockstep(todo, key)
        # requests with an exhausted budget produce their prefix verbatim
        # and never occupy a slot; everything else went to the scheduler
        results = [Result(r.rid, list(r.done)) for r in requests]
        for (i, _), res in zip(todo, done):
            results[i] = res
        return results

    def check_request(self, r: Request) -> None:
        """Reject a request that can never be served: context overflow, or
        (paged) a worst case larger than the whole pool."""
        self._check_budget(
            self._n_prefix() + len(r.prompt) + len(r.done),
            r.max_new_tokens - len(r.done), r.rid)
        if self.kv_layout == "paged":
            worst = self._worst_blocks(r)
            if worst > self.allocator.capacity:
                raise ValueError(
                    f"request rid={r.rid} needs {worst} KV blocks "
                    f"(block_size={self.block_size}) but the pool only "
                    f"has {self.allocator.capacity}")

    # ------------------------------------------------------------------
    # Admission accounting helpers.
    # ------------------------------------------------------------------

    def _gather_extra(self, rows: list[int]) -> dict:
        """Select extra-input rows by submission order (dim-1 broadcasts)."""
        out = {}
        for k, v in self.extra.items():
            if v.shape[0] == 1:
                out[k] = jnp.broadcast_to(jnp.asarray(v),
                                          (len(rows),) + tuple(v.shape[1:]))
            elif max(rows) < v.shape[0]:
                out[k] = jnp.asarray(v)[jnp.asarray(rows)]
            else:
                raise ValueError(
                    f"extra_inputs[{k!r}] has {v.shape[0]} rows but request "
                    f"#{max(rows)} needs its own (pass one row per request, "
                    "or a single row to broadcast)")
        return out

    def _check_budget(self, prefill_pos: int, max_new: int, rid) -> None:
        """Every position written past prefill must fit in cache_len: the
        per-slot strip length (dense; writes beyond it are silently dropped
        by the one-hot update) or the block-table width (paged).  Families
        with unbounded state (``model.bounded_cache`` False: ssm's O(1)
        recurrent state, hybrid's state + wrapping attention ring) have no
        write budget to enforce."""
        if not self.model.bounded_cache:
            return
        writes = prefill_pos + max(max_new - 1, 0)
        if writes > self.cache_len:
            raise ValueError(
                f"request rid={rid} needs {writes} cache positions "
                f"(prefill {prefill_pos} + {max_new - 1} decode writes) "
                f"but cache_len={self.cache_len}")

    def _n_prefix(self) -> int:
        """Model-side prefix positions prefill adds ahead of the tokens."""
        cfg = self.model.cfg
        return cfg.n_patches if cfg.family == "vlm" else 0

    def _bucket_len(self, n: int) -> int:
        """Round a prompt length up to its bucket (pow2 or pad-to-multiple),
        capped so the padded sequence still fits the per-request bound."""
        if not self.bucket:
            return n
        if self.bucket == "pow2":
            b = 1
            while b < n:
                b <<= 1
        else:
            b = -(-n // int(self.bucket)) * int(self.bucket)
        return max(min(b, self.cache_len - self._n_prefix()), n)

    def _worst_blocks(self, r: Request) -> int:
        """Worst-case block count for a request (all cache positions it can
        ever write), computable before prefill runs."""
        writes = (self._n_prefix() + len(r.prompt) + len(r.done)
                  + max(r.max_new_tokens - len(r.done) - 1, 0))
        return blocks_needed(writes, self.block_size)

    def _prefix_hits(self, r: Request) -> tuple[list, bool]:
        """Resolve the request's prefill (prompt + done) against the
        prefix index: the longest run of resident full blocks, as
        ``([(chain_key, block_id), ...], full_boundary)``.  Pure — no
        refcounts move until ``session_admit`` applies the hits —
        so ``session_can_admit`` can price an admission exactly.
        ``full_boundary`` is True when the hits cover the *entire*
        prefill: the final chunk must then be recomputed anyway (its
        logits seed the first sampled token), behind a copy-on-write
        of its shared block."""
        if not self.prefix_cache:
            return [], False
        seq = list(r.prompt) + list(r.done)
        hits = []
        for key in kvcache.prefix_chain_keys(seq, self.block_size):
            blk = self.allocator.lookup(key, self.owner)
            if blk is None:
                break
            hits.append((key, blk))
        boundary = bool(hits) and len(hits) * self.block_size == len(seq)
        return hits, boundary

    def _admit_block_need(self, r: Request) -> int:
        """Blocks a reserve admission must find unreserved-free: the worst
        case minus blocks admitted by reference, plus one for the
        full-boundary COW copy, plus one per hit that revives a cached
        (refcount-0) block — a revival spends an allocatable block just
        like a fresh allocation does."""
        hits, boundary = self._prefix_hits(r)
        n_cached = sum(self.allocator.is_cached(b) for _, b in hits)
        return (self._worst_blocks(r) - len(hits) + int(boundary)
                + n_cached)

    # ------------------------------------------------------------------
    # Stepwise session API (one continuous-batching run; ``generate``
    # drives it for the single-engine case, ClusterEngine interleaves
    # several engines' sessions over one shared pool).
    #
    # Thread affinity: an open session's state (_Session, slot arrays,
    # device cache) is NOT internally locked — all session mutators
    # (session_admit / session_step / session_preempt / session_abort /
    # end_session) of one engine must be driven from a single thread at
    # a time.  The threaded cluster driver honors this by pinning each
    # engine to one worker thread and handing admissions/preemptions to
    # that worker over a queue; only the *shared* BlockAllocator (its
    # own lock) and the tracer (locked) are touched cross-thread.
    # ``session_active`` and ``session_can_admit`` are safe advisory
    # reads from other threads (a slot count and a pool-side check).
    # ------------------------------------------------------------------

    def begin_session(self, key=None, on_token=None) -> None:
        """Open a stepwise session.  ``on_token``, when given, streams
        every sampled token as a :class:`TokenEvent` the moment it
        exists (called synchronously from the admitting/stepping
        thread)."""
        if self.mode != "continuous":
            raise ValueError("stepwise sessions require the continuous "
                             "scheduler")
        if self._sess is not None:
            raise RuntimeError("a session is already open on this engine")
        bsz = self.max_batch
        if self.kv_layout == "paged" and self._owns_pool:
            self.allocator.reset_peak()
        self._sess = _Session(
            key=key if key is not None else jax.random.key(0),
            slots=[None] * bsz,
            toks=np.zeros((bsz, 1), np.int32),
            temps=np.zeros((bsz,), np.float32),
            rids=np.zeros((bsz,), np.int32),
            tok_idx=np.zeros((bsz,), np.int32),
            metrics=MetricsRegistry(), t_start=self.clock.now(),
            on_token=on_token)

    def _require_session(self) -> _Session:
        if self._sess is None:
            raise RuntimeError("no session is open on this engine "
                               "(call begin_session first)")
        return self._sess

    @property
    def session_active(self) -> int:
        """Busy slot count of the open session (0 when none is open)."""
        if self._sess is None:
            return 0
        return sum(s is not None for s in self._sess.slots)

    def session_free_slot(self) -> int | None:
        for i, s in enumerate(self._sess.slots):
            if s is None:
                return i
        return None

    def session_slots(self):
        """Live (slot index, slot) pairs - victim scanning."""
        return [(i, s) for i, s in enumerate(self._sess.slots)
                if s is not None]

    def session_victims(self, now: float):
        """Policy-ranked preemption candidates of the open session:
        ``(victim_key, slot)`` pairs — the minimum key is the preferred
        victim.  The key's leading element is the policy's protection
        flag (``slo_adaptive``: 1 while the request is inside its
        deadline slack), so callers can tell a protected pick apart."""
        return [(self.policy.victim_key(s.req, s.admit_seq, s.admit_t,
                                        now), i)
                for i, s in self.session_slots()]

    def session_backlog(self) -> int:
        """Outstanding decode tokens across live slots (shortest-queue
        routing metric)."""
        return sum(s.req.max_new_tokens - len(s.req.done) - len(s.tokens)
                   for _, s in self.session_slots())

    def session_slot_steps(self) -> tuple[int, int]:
        """(busy, offered) slot-steps of the open session - offered counts
        max_batch lanes per launched decode step (cluster occupancy)."""
        m = self._require_session().metrics
        return (m.counter("busy_slot_steps").n,
                m.counter("offered_slot_steps").n)

    def session_can_admit(self, r: Request) -> bool:
        """Pool-side admission test (always true for the dense layout,
        where ``check_request`` already enforced the per-slot budget).

        reserve: the pool must cover the request's worst case on top of
        standing reservations, so lazy growth can never fail mid-prefill
        or mid-decode.
        overcommit: only the *first prefill chunk's* block must be free —
        prefill itself now grows lazily chunk by chunk, so admission is
        bounded by free blocks for prefill exactly as it is for decode,
        and later growth (either phase) may raise PoolPressure, resolved
        by cluster preemption.  A False here always clears once live
        requests finish and recycle blocks (``check_request`` rejected
        requests that exceed the whole pool)."""
        if self.kv_layout != "paged":
            return True
        if self._admission == "overcommit":
            return self.allocator.n_avail >= 1
        return self.allocator.n_avail >= self._admit_block_need(r)

    def _emit_token(self, sess: _Session, r: Request, tok: int,
                    index: int) -> None:
        """Stream one sampled token through the session's ``on_token``
        callback (no-op without one)."""
        if sess.on_token is not None:
            sess.on_token(TokenEvent(r.rid, tok, index,
                                     index + 1 >= r.max_new_tokens))

    def _observe_slo_ttft(self, r: Request, slot: int, enqueue_t,
                          admit_t: float, t1: float) -> None:
        """Score the first token of a TTFT-budgeted request.  The
        deadline base is the enqueue time (what a client experiences);
        a requeued mid-prefill victim falls back to its first admission
        time (``first_admit_t``), so a chain of evictions cannot reset
        the clock.  Host-side only: budgets never touch tokens."""
        base = r.first_admit_t
        if base is None:
            base = enqueue_t if enqueue_t is not None else admit_t
        att_ms = (t1 - base) * 1e3
        m = self._sess.metrics
        m.counter("slo_ttft_total").inc()
        m.histogram("slo_ttft_slack_ms").observe(r.slo_ttft_ms - att_ms)
        if att_ms <= r.slo_ttft_ms:
            m.counter("slo_ttft_attained").inc()
        elif self.tracer.enabled:
            # deadline-miss span: the overrun stretch, deadline -> first
            # token, on the slot track next to the prefill it indicts
            self.tracer.complete(self._slot_track(slot), "slo_miss",
                                 base + r.slo_ttft_ms / 1e3, t1,
                                 rid=r.rid, phase="ttft",
                                 over_ms=att_ms - r.slo_ttft_ms)

    def _observe_slo_tpot(self, s: _Slot, per_tok_ms: float) -> None:
        """Score a finished TPOT-budgeted request's decode rate."""
        m = self._sess.metrics
        m.counter("slo_tpot_total").inc()
        m.histogram("slo_tpot_slack_ms").observe(
            s.req.slo_tpot_ms - per_tok_ms)
        if per_tok_ms <= s.req.slo_tpot_ms:
            m.counter("slo_tpot_attained").inc()
        elif self.tracer.enabled:
            self.tracer.instant(self.track, "slo_miss", rid=s.req.rid,
                                phase="tpot",
                                over_ms=per_tok_ms - s.req.slo_tpot_ms)

    def _replay_done(self, sub, done):
        """Rebuild a preempted scan-family request's recurrent state
        bit-exactly: starting from the prompt-only prefill cache ``sub``,
        feed each already-generated ``done`` token through the decode
        step on a batch-1 slot pool — the same executable family the
        uninterrupted run decoded with, so the resumed stream's logits
        (and tokens) are byte-identical to never having been preempted.
        Returns (last logits, batch-1 pool cache); the last logits are
        the distribution for stream index ``len(done)``."""
        mini = self._slot_write(self._cache_expand(sub, 1), sub, 0)
        logits = None
        for t in done:
            logits, mini = self._decode(self.params, mini,
                                        jnp.asarray([[t]], jnp.int32))
        return logits, mini

    def session_admit(self, r: Request, tag: int, extra_row: int = 0,
                      admit_seq: int | None = None,
                      enqueue_t: float | None = None) -> Result | None:
        """Admit ``r`` into the first free slot.

        dense: prefill runs here (prefill-on-admit) and the first token is
        sampled; returns the finished Result when the token budget is
        satisfied by the admission itself, else None.

        paged: admission only installs the request and (under reserve)
        promises its worst case — the prefill itself runs *chunk by chunk*
        inside ``session_step``, allocating each chunk's block lazily, so
        no block is held before it is written and pool pressure during a
        long prompt's prefill surfaces exactly like decode-time growth
        (PoolPressure → cluster preemption, including of the half-prefilled
        request itself).  Always returns None; budget-satisfied-by-prefill
        results arrive from ``session_step``.

        ``tag`` is echoed back with the Result from ``session_step``;
        ``extra_row`` indexes ``extra_inputs``; ``admit_seq`` orders
        admissions globally for victim selection (defaults to a per-engine
        counter); ``enqueue_t`` is the clock time the request entered the
        caller's queue (recorded as its queue-age sample)."""
        sess = self._require_session()
        slot = self.session_free_slot()
        if slot is None:
            raise RuntimeError("session_admit with no free slot")
        if admit_seq is None:
            admit_seq = sess.admit_counter
        sess.admit_counter = max(sess.admit_counter, admit_seq) + 1
        t0 = self.clock.now()
        if enqueue_t is not None:
            sess.metrics.histogram("queue_age_ms").observe(
                (t0 - enqueue_t) * 1e3)
        if self.kv_layout == "paged":
            prefill_pos = (self._n_prefix() + len(r.prompt) + len(r.done))
            self._check_budget(prefill_pos,
                               r.max_new_tokens - len(r.done), r.rid)
            if sess.cache is None:
                if self._pcache is not None:
                    # prefix cache: the previous session's device pool is
                    # revived so cached blocks' bytes are still resident
                    sess.cache, self._pcache = self._pcache, None
                else:
                    sess.cache = self.model.paged_cache_init(
                        batch=self.max_batch,
                        n_blocks=self.allocator.n_blocks,
                        block_size=self.block_size,
                        max_blocks=self.max_blocks,
                        dtype=self.model.cache_dtype(self.params))
            # Resolve + charge the pool atomically: between a lookup and
            # its incref/take_cached, a co-tenant replica's alloc in
            # another thread could otherwise evict the cached block out
            # from under us.  reserve() runs before any reference moves,
            # so a MemoryError here (a lost admission race under the
            # threaded driver) leaves the pool untouched and the
            # admission can simply be retried.
            with self.allocator.lock:
                hits, boundary = self._prefix_hits(r)
                reserve_left = 0
                if self._admission == "reserve":
                    # promise the whole worst case up front (minus blocks
                    # admitted by reference, plus the boundary COW copy
                    # and any cached revivals — see _admit_block_need);
                    # every lazy allocation converts one promise into a
                    # live block, so growth can never fail
                    reserve_left = (self._worst_blocks(r) - len(hits)
                                    + int(boundary))
                    n_cached = sum(self.allocator.is_cached(b)
                                   for _, b in hits)
                    self.allocator.reserve(reserve_left + n_cached)
                # reference each resident block (reviving cached ones)
                taken: list[int] = []
                for _, blk in hits:
                    if self.allocator.is_cached(blk):
                        # reviving costs one allocatable block; under
                        # reserve it was priced into the reservation
                        # above (and can never fail); under overcommit
                        # the revived block is itself part of n_free, so
                        # this never fails either
                        self.allocator.take_cached(
                            blk, self.owner,
                            from_reservation=self._admission == "reserve")
                    else:
                        self.allocator.incref(blk, self.owner)
                    taken.append(blk)
            # install the (now unevictable) referenced blocks in the
            # slot's block table — device-side, no pool lock needed
            for idx, blk in enumerate(taken):
                sess.cache = self._bt_set(sess.cache, slot, idx, blk)
            h = len(taken)
            # a fully-covered prefill still re-runs its final chunk (the
            # engine needs its logits) behind the COW barrier; partial
            # coverage resumes cold at the first miss
            chunks_done = h - 1 if boundary else h
            sess.metrics.counter("prefix_hits").inc(h)
            sess.metrics.counter("prefix_tokens_reused").inc(
                chunks_done * self.block_size)
            if r.done or r.requeues:
                sess.metrics.counter("requeued").inc()
            tr = self.tracer
            if tr.enabled:
                st = self._slot_track(slot)
                tr.instant(st, "admit", rid=r.rid, slot=slot,
                           readmit=bool(r.done or r.requeues),
                           prefix_hits=h,
                           prefix_tokens=chunks_done * self.block_size)
                if h:
                    tr.instant("pool", "kv_ref", rid=r.rid, n=h)
                if r.requeues:
                    # close the flow arrow the eviction opened: the trace
                    # draws preempt (victim slot) -> re-admission (here)
                    tr.flow_end(st, "preempt_flow",
                                f"preempt-{r.rid}-{r.requeues}")
            sess.slots[slot] = _Slot(
                req=r, tag=tag, tokens=[], ttft_ms=0.0, admit_seq=admit_seq,
                prefill_pos=prefill_pos, reserve_left=reserve_left,
                blocks=taken, shared_until=h,
                chunks_done=chunks_done, extra_row=extra_row,
                admit_t=(r.first_admit_t if r.first_admit_t is not None
                         else t0), enqueue_t=enqueue_t, span_t0=t0)
            sess.temps[slot] = r.temperature
            sess.rids[slot] = r.rid
            return None
        tr = self.tracer
        if tr.enabled:
            tr.instant(self._slot_track(slot), "admit", rid=r.rid,
                       slot=slot, readmit=bool(r.done or r.requeues),
                       prefix_hits=0, prefix_tokens=0)
            if r.requeues:
                tr.flow_end(self._slot_track(slot), "preempt_flow",
                            f"preempt-{r.rid}-{r.requeues}")
        # scan families re-admit by *replay*: chunkwise prefill covers
        # only the original prompt (the computation the uninterrupted run
        # performed) and the generated ``done`` tokens are stepped through
        # the decode recurrence afterwards (``_replay_done``).  The
        # chunked prefill and the stepwise recurrence are mathematically
        # but not bitwise interchangeable, so prefilling prompt+done
        # would perturb the resumed stream's logits.  KV families have no
        # such split (prefill writes per-position KV): prompt+done
        # prefills in one pass, byte-exactly.
        replay = bool(r.done) and self._slot_reset is not None
        prompt = np.asarray(
            list(r.prompt) + ([] if replay else list(r.done)), np.int32)
        plen = len(prompt)
        sb = self._bucket_len(plen)
        if self.bucket:
            # right-pad to the bucket and pass the true length: causality
            # hides the pads, pad KV lands past pos (masked in decode and
            # overwritten as decode proceeds), so outputs are unchanged
            toks = np.zeros((1, sb), np.int32)
            toks[0, :plen] = prompt
            batch = {"tokens": jnp.asarray(toks),
                     "prefill_len": jnp.asarray([plen], np.int32),
                     **self._gather_extra([extra_row])}
        else:
            batch = {"tokens": jnp.asarray(prompt[None]),
                     **self._gather_extra([extra_row])}
        self._prefill_shapes.add(batch["tokens"].shape[1])
        logits, sub = self._prefill(self.params, batch)
        if replay:
            logits, sub = self._replay_done(sub, r.done)
            sess.metrics.counter("resume_replay_tokens").inc(len(r.done))
        # sub["pos"] covers any model-side prefix (e.g. vlm patches)
        prefill_pos = int(np.asarray(sub["pos"]).reshape(()))
        self._check_budget(prefill_pos, r.max_new_tokens - len(r.done),
                           r.rid)
        if sess.cache is None:
            sess.cache = self._cache_expand(sub, self.max_batch)
        sess.cache = self._slot_write(sess.cache, sub, slot)
        # the request's t-th token always uses stream index t, so a
        # re-admitted (preempted) request resumes its stream at len(done)
        tok = self._sample(logits, jnp.full((1,), r.temperature),
                           sess.key, jnp.asarray([r.rid], np.int32),
                           jnp.asarray([len(r.done)], np.int32))
        tok = int(np.asarray(jax.block_until_ready(tok))[0])
        t1 = self.clock.now()
        ttft_ms = (t1 - t0) * 1e3
        if tr.enabled:
            tr.complete(self._slot_track(slot), "prefill", t0, t1,
                        rid=r.rid, tokens=plen)
        at = self.attr
        if at.enabled:
            cost = at.phase_cost(
                ("prefill", self.model.cfg.name, batch["tokens"].shape[1]),
                self._prefill, (self.params, batch))
            at.record_prefill(sess.metrics, tr, self._slot_track(slot),
                              t0=t0, t1=t1, cost=cost)
        if r.done or r.requeues:
            sess.metrics.counter("requeued").inc()
        if not r.done:
            sess.metrics.histogram("ttft_ms").observe(ttft_ms)
            if r.slo_ttft_ms is not None:
                self._observe_slo_ttft(r, slot, enqueue_t, t0, t1)
        if r.first_ttft_ms is not None:
            ttft_ms = r.first_ttft_ms   # re-admission: keep the real TTFT
        self._emit_token(sess, r, tok, len(r.done))
        s = _Slot(req=r, tag=tag, tokens=[tok], ttft_ms=ttft_ms,
                  admit_seq=admit_seq, prefill_pos=prefill_pos, admit_t=t0,
                  enqueue_t=enqueue_t, span_t0=t0, first_tok_t=t1)
        if len(r.done) + 1 >= r.max_new_tokens:
            res = self._finish(s)       # satisfied by prefill alone
            self._release(s, slot)
            if tr.enabled:
                self._trace_finish(s, slot, self.clock.now())
            return res
        sess.slots[slot] = s
        sess.toks[slot, 0] = tok
        sess.temps[slot] = r.temperature
        sess.rids[slot] = r.rid
        sess.tok_idx[slot] = len(r.done) + 1
        return None

    def session_step(self) -> list[tuple[int, Result]]:
        """One scheduler step over the slot pool: finish any pending
        chunked prefills (paged layout), then one decode launch.  Returns
        the (tag, Result) pairs that finished this step; empty when no
        slot is live.  Under overcommit admission, raises PoolPressure
        when lazy block growth (a prefill chunk's block or a decode
        slot's next write position) finds the pool empty - the decode has
        not run, prefill chunks already computed and blocks already grown
        stay put, and the call can be retried after the caller frees
        blocks (``session_preempt``) - a retried step resumes a
        half-prefilled slot at its next chunk."""
        sess = self._require_session()
        bsz = self.max_batch
        if self.kv_layout == "paged":
            for i in range(bsz):
                s = sess.slots[i]
                if s is not None and s.chunks_done is not None:
                    res = self._advance_prefill(sess, i, s)
                    if res is not None:     # satisfied by prefill alone
                        # park it: a PoolPressure later in this same step
                        # must not lose an already-released slot's Result
                        sess.finished_pending.append((s.tag, res))
                        self._release(s, i)
                        sess.slots[i] = None
                        if self.tracer.enabled:
                            self._trace_finish(s, i, self.clock.now())
        active = [i for i in range(bsz) if sess.slots[i] is not None]
        if self.kv_layout == "paged":
            # lazy growth: each slot's next write position must have a
            # block before the step; under reserve admission these
            # allocations can never fail mid-flight
            for i in active:
                s = sess.slots[i]
                pos = s.prefill_pos + s.steps
                while len(s.blocks) * self.block_size <= pos:
                    self._grow_slot(sess, i, s)
        # past the last allocation: nothing below can raise PoolPressure,
        # so parked prefill-phase Results can leave the session now
        finished, sess.finished_pending = sess.finished_pending, []
        if not active:
            return finished
        # one decode step over the whole slot pool (fixed shapes; idle
        # slots compute too - their rows are masked by per-slot pos and
        # fully rewritten on the next admission; paged idle rows write
        # into the null block)
        tr = self.tracer
        t0 = self.clock.now()
        logits, sess.cache = self._decode(self.params, sess.cache,
                                          jnp.asarray(sess.toks))
        # the decode launch returns asynchronously: [t0, t_disp] is host
        # dispatch (trace/lowering lookup + enqueue), the np.asarray
        # below blocks until the device result lands, so [t_disp, t1]
        # is device compute + sampling + transfer
        t_disp = self.clock.now()
        nxt = np.asarray(self._sample(
            logits, jnp.asarray(sess.temps), sess.key,
            jnp.asarray(sess.rids), jnp.asarray(sess.tok_idx)))
        t1 = self.clock.now()
        dt = t1 - t0
        m = sess.metrics
        m.counter("decode_steps").inc()
        m.counter("busy_slot_steps").inc(len(active))
        m.counter("offered_slot_steps").inc(bsz)
        m.timeline("occupancy").record(t1, len(active) / bsz)
        if self.kv_layout == "paged":
            m.timeline("pool_util").record(
                t1, self.allocator.n_live / max(self.allocator.capacity, 1))
        if tr.enabled:
            tr.complete(self.track, "step", t0, t1, active=len(active))
            tr.complete(self.track, "dispatch", t0, t_disp)
            tr.complete(self.track, "device", t_disp, t1)
        at = self.attr
        if at.enabled:
            # shapes only (the post-step cache aliases the pre-step
            # shapes); a memo hit is a dict lookup, a miss lowers this
            # jitted decode AOT without executing or donating anything
            cost = at.phase_cost(
                ("decode", self.kv_layout, self.model.cfg.name, bsz),
                self._decode, (self.params, sess.cache,
                               jnp.asarray(sess.toks)))
            at.record_step(m, tr, self.track, t0=t0, t_disp=t_disp, t1=t1,
                           active=len(active), width=bsz, cost=cost)
        for i in active:
            s = sess.slots[i]
            s.tokens.append(int(nxt[i]))
            self._emit_token(sess, s.req, int(nxt[i]),
                             len(s.req.done) + len(s.tokens) - 1)
            s.steps += 1
            s.decode_s += dt
            sess.toks[i, 0] = nxt[i]
            sess.tok_idx[i] += 1
            if len(s.req.done) + len(s.tokens) >= s.req.max_new_tokens:
                finished.append((s.tag, self._finish(s)))
                self._release(s, i)
                sess.slots[i] = None   # freed: refilled on the next admit
                if tr.enabled:
                    self._trace_finish(s, i, t1)
        return finished

    def _grow_slot(self, sess: _Session, i: int, s: _Slot) -> None:
        """Allocate slot ``i``'s next block and install it in the block
        table (lazy growth, shared by prefill chunks and decode writes).
        Under reserve admission one standing promise becomes live — the
        allocation draws *from the reservation* (``from_reservation=``),
        so it can spend blocks other requests' promises hold back, and
        the allocator retires the promise atomically with the grant;
        under overcommit an empty pool surfaces as PoolPressure."""
        blk = self._alloc_block(i, from_reservation=s.reserve_left > 0)
        if s.reserve_left:
            s.reserve_left -= 1
        if self.tracer.enabled:
            self.tracer.instant("pool", "kv_alloc", rid=s.req.rid, n=1,
                                block=blk)
        sess.cache = self._bt_set(sess.cache, i, len(s.blocks), blk)
        s.blocks.append(blk)

    def _alloc_block(self, i: int, *, from_reservation: bool) -> int:
        """One pool allocation with overcommit pressure translation."""
        try:
            return self.allocator.alloc(self.owner,
                                        from_reservation=from_reservation)
        except MemoryError as e:
            if self._admission == "overcommit":
                if self.tracer.enabled:
                    self.tracer.instant("pool", "pool_pressure",
                                        owner=self.owner, slot=i)
                raise PoolPressure(self.owner, i) from e
            raise

    def _cow_block(self, sess: _Session, i: int, s: _Slot, c: int) -> None:
        """Copy-on-write barrier for chunk ``c`` of slot ``i``: the slot is
        about to write into ``blocks[c]``, which it holds by reference from
        the prefix index.  If any other request also holds it, allocate a
        private block, copy the shared bytes, and swap the table entry
        (the shared block just loses this slot's reference); a sole holder
        rewrites in place — the recompute produces identical bytes, so the
        index entry stays valid either way.  Resumable: a PoolPressure
        from the allocation mutates nothing."""
        old = s.blocks[c]
        if self.allocator.refcount(old) > 1:
            blk = self._alloc_block(i, from_reservation=s.reserve_left > 0)
            if s.reserve_left:
                s.reserve_left -= 1
            sess.cache = self._copy_block(sess.cache, np.int32(blk),
                                          np.int32(old))
            sess.cache = self._bt_set(sess.cache, i, c, blk)
            self.allocator.free([old], self.owner)
            s.blocks[c] = blk
            if self.tracer.enabled:
                self.tracer.instant("pool", "kv_cow", rid=s.req.rid,
                                    alloc=1, freed=1, block=blk)
        s.shared_until = c

    def _chunk_tokens(self, r: Request, chunk: int) -> jnp.ndarray:
        """(1, block_size) token feed for combined positions
        ``[chunk*bs, (chunk+1)*bs)``: prompt + done ids where the position
        maps to a token, 0 where it is a model-side prefix row (vlm
        patches, re-embedded from ``extra_inputs`` by the model) or
        right-pad past the prompt (masked out causally and overwritten as
        decode proceeds)."""
        bs = self.block_size
        npre = self._n_prefix()
        seq = list(r.prompt) + list(r.done)
        toks = np.zeros((1, bs), np.int32)
        lo = max(chunk * bs, npre)
        hi = min((chunk + 1) * bs, npre + len(seq))
        if hi > lo:
            toks[0, lo - chunk * bs:hi - chunk * bs] = seq[lo - npre:
                                                           hi - npre]
        return jnp.asarray(toks)

    def _advance_prefill(self, sess: _Session, i: int,
                         s: _Slot) -> Result | None:
        """Run slot ``i``'s remaining prefill chunks, allocating each
        chunk's block just before computing it (resumable: PoolPressure
        from an allocation leaves ``chunks_done`` and the blocks already
        written intact, and a retried step continues from the next chunk).
        On completion samples the request's first token; returns the
        finished Result when the token budget is satisfied by the prefill
        itself, else None."""
        r = s.req
        n_chunks = blocks_needed(s.prefill_pos, self.block_size)
        extra = self._gather_extra([s.extra_row])   # same rows every chunk
        logits = None
        while s.chunks_done < n_chunks:
            c = s.chunks_done
            if c < s.shared_until:
                # write barrier: this chunk is about to rewrite a block
                # referenced from the prefix index (a full-boundary hit
                # recomputes its final chunk for the logits) — give the
                # slot a private copy first if anyone else reads it
                self._cow_block(sess, i, s, c)  # may raise PoolPressure
            if len(s.blocks) <= c:
                self._grow_slot(sess, i, s)     # may raise PoolPressure
            batch = {"tokens": self._chunk_tokens(r, c), **extra}
            self._prefill_shapes.add(("chunk", self.block_size))
            at = self.attr
            tc0 = self.clock.now() if at.enabled else 0.0
            with self.tracer.span(self._slot_track(i), "chunk",
                                  rid=r.rid, chunk=c):
                logits, sess.cache = self._prefill_chunk(
                    self.params, sess.cache, batch, np.int32(i),
                    np.int32(c), np.int32(s.prefill_pos))
            s.chunks_done += 1
            if at.enabled:
                cost = at.phase_cost(
                    ("prefill_chunk", self.model.cfg.name, self.block_size),
                    self._prefill_chunk,
                    (self.params, sess.cache, batch, np.int32(i),
                     np.int32(c), np.int32(s.prefill_pos)))
                at.record_prefill(sess.metrics, self.tracer,
                                  self._slot_track(i), t0=tc0,
                                  t1=self.clock.now(), cost=cost)
        if self.prefix_cache:
            # publish every full prompt-prefix block (re-registering a hit
            # is a no-op; a COW'd boundary block supersedes the old entry).
            # Decode writes always land past prefill_pos — in blocks beyond
            # the full spans — so registered bytes are pure prefill output
            seq = list(r.prompt) + list(r.done)
            for c, key in enumerate(
                    kvcache.prefix_chain_keys(seq, self.block_size)):
                self.allocator.register(key, s.blocks[c], self.owner)
        tok = self._sample(logits, jnp.full((1,), r.temperature),
                           sess.key, jnp.asarray([r.rid], np.int32),
                           jnp.asarray([len(r.done)], np.int32))
        tok = int(np.asarray(jax.block_until_ready(tok))[0])
        t1 = self.clock.now()
        ttft_ms = (t1 - s.admit_t) * 1e3
        if self.tracer.enabled:
            # this admission's prefill: s.span_t0 (admit), not s.admit_t
            # (which spans back across preemptions to the first attempt)
            self.tracer.complete(self._slot_track(i), "prefill",
                                 s.span_t0, t1, rid=r.rid,
                                 chunks=n_chunks, tokens=s.prefill_pos)
        if not r.done:
            sess.metrics.histogram("ttft_ms").observe(ttft_ms)
            if r.slo_ttft_ms is not None:
                self._observe_slo_ttft(r, i, s.enqueue_t, s.admit_t, t1)
        s.ttft_ms = (r.first_ttft_ms if r.first_ttft_ms is not None
                     else ttft_ms)
        s.first_tok_t = t1
        s.tokens.append(tok)
        self._emit_token(sess, r, tok, len(r.done))
        s.chunks_done = None            # prefill complete: decode from here
        if len(r.done) + 1 >= r.max_new_tokens:
            return self._finish(s)
        sess.toks[i, 0] = tok
        sess.tok_idx[i] = len(r.done) + 1
        return None

    def session_preempt(self, slot: int) -> tuple[int, Request]:
        """Evict the request in ``slot``: free its blocks back to the pool
        and return ``(tag, requeued request)`` - the requeued request
        carries the tokens generated so far in ``done``, so a later
        re-admission prefills prompt + done and resumes the sampled stream
        at index len(done), reproducing the uninterrupted output exactly.
        A slot still mid-prefill (chunked paged prefill) is a valid
        victim: its ``done`` is unchanged and the whole prompt re-prefills
        later."""
        sess = self._require_session()
        s = sess.slots[slot]
        if s is None:
            raise ValueError(f"slot {slot} is not live")
        requeued = dataclasses.replace(
            s.req, done=tuple(s.req.done) + tuple(s.tokens),
            first_ttft_ms=(s.ttft_ms if s.tokens else s.req.first_ttft_ms),
            # s.admit_t already spans back to the first admission (set
            # from first_admit_t on re-admissions), so a chain of
            # mid-prefill evictions keeps the original TTFT base
            first_admit_t=s.admit_t, requeues=s.req.requeues + 1)
        tr = self.tracer
        if tr.enabled:
            st = self._slot_track(slot)
            t1 = self.clock.now()
            if s.steps:
                tr.complete(st, "decode", s.first_tok_t, t1,
                            rid=s.req.rid, tokens=s.steps)
            tr.complete(st, f"req {s.req.rid}", s.span_t0, t1,
                        rid=s.req.rid, preempted=True)
            tr.instant(st, "preempt", rid=s.req.rid,
                       tokens_done=len(requeued.done),
                       mid_prefill=s.chunks_done is not None)
            # open the flow arrow; the requeue/abort that answers this
            # eviction closes it (fid matches the requeued copy's count)
            tr.flow_start(st, "preempt_flow",
                          f"preempt-{s.req.rid}-{requeued.requeues}")
        self._release(s, slot)
        sess.slots[slot] = None
        sess.metrics.counter("preempted").inc()
        return s.tag, requeued

    def session_abort(self) -> None:
        """Tear down an open session after a failure, returning any blocks
        and reservations to the pool so the engine (and a shared pool's
        co-tenants) stay usable.  The device cache is rebuilt per session,
        so host-side block ownership is the only state that must survive."""
        sess = self._sess
        if sess is None:
            return
        if self.tracer.enabled:
            for i, s in enumerate(sess.slots):
                if s is not None:
                    self.tracer.instant(self._slot_track(i), "abort",
                                        rid=s.req.rid)
        if self.kv_layout == "paged":
            for s in sess.slots:
                if s is not None:
                    if s.blocks:
                        self.allocator.free(s.blocks, self.owner)
                    self.allocator.unreserve(s.reserve_left)
            if self.prefix_cache:
                # the aborted session's device pool is not trustworthy
                # (a failure may have left blocks half-written): drop it
                # and de-index everything this engine registered — cached
                # blocks return to the raw free list, so the pool still
                # drains clean
                self._pcache = None
                self.allocator.flush_index(self.owner)
        self._sess = None

    def end_session(self) -> EngineStats:
        """Close the session and return its aggregate stats."""
        sess = self._require_session()
        if self.session_active:
            raise RuntimeError("end_session with live slots (drain or "
                               "preempt them first)")
        if sess.finished_pending:
            raise RuntimeError(
                "end_session with undelivered finished Results (a "
                "PoolPressure interrupted their step; call session_step "
                "once more to collect them)")
        wall = self.clock.now() - sess.t_start
        stats = EngineStats.from_registry(
            sess.metrics, mode="continuous", wall_s=wall,
            kv_layout=self.kv_layout,
            prefill_compiles=len(self._prefill_shapes),
            block_util_peak=(self.allocator.stats().peak_utilization
                             if self.kv_layout == "paged" else 0.0),
            sched_policy=self.policy.name)
        self.last_metrics = sess.metrics
        if self.kv_layout == "paged" and self.prefix_cache:
            # keep the device pool alive across sessions: cached blocks'
            # bytes must stay resident for a later session to hit them
            self._pcache = sess.cache
        self._sess = None
        return stats

    def _finish(self, s: _Slot) -> Result:
        per_tok = s.decode_s * 1e3 / max(s.steps, 1)
        tokens = list(s.req.done) + s.tokens
        m = self._sess.metrics
        m.counter("generated_tokens").inc(len(tokens))
        if s.steps:
            m.histogram("tpot_ms").observe(per_tok)
            if s.req.slo_tpot_ms is not None:
                self._observe_slo_tpot(s, per_tok)
        return Result(s.req.rid, tokens, s.ttft_ms, per_tok)

    def _trace_finish(self, s: _Slot, i: int, t1: float) -> None:
        """Close a finished request's spans on its slot track: the decode
        stretch (first token -> finish), the whole-admission request
        span, and the ``finish`` instant."""
        tr = self.tracer
        st = self._slot_track(i)
        if s.steps:
            tr.complete(st, "decode", s.first_tok_t, t1, rid=s.req.rid,
                        tokens=s.steps)
        tr.complete(st, f"req {s.req.rid}", s.span_t0, t1, rid=s.req.rid)
        tr.instant(st, "finish", rid=s.req.rid,
                   tokens=len(s.req.done) + len(s.tokens))

    def _release(self, s: _Slot, i: int) -> None:
        """Free slot ``i``'s cache-side state.

        dense + scan family: zero the slot's recurrent state and position
        (``model.cache_slot_reset``) so nothing of the finished/preempted
        request survives in the pool — the no-leak invariant the
        regression tests assert directly.

        paged: drop the slot's block references — an unshared block
        returns to the pool immediately, a shared one stays live for its
        other holders, a registered last-reference block parks in the
        cached LRU — and park the block-table row on the null block so
        idle decode writes cannot touch recycled blocks."""
        if self.kv_layout != "paged":
            if self._slot_reset is not None and self._sess.cache is not None:
                self._sess.cache = self._slot_reset(self._sess.cache, i)
            return
        if self.tracer.enabled and s.blocks:
            self.tracer.instant("pool", "kv_free", rid=s.req.rid,
                                n=len(s.blocks))
        self.allocator.free(s.blocks, self.owner)
        self.allocator.unreserve(s.reserve_left)
        s.blocks, s.reserve_left = [], 0
        self._sess.cache = self._slot_release(self._sess.cache, i)

    # ------------------------------------------------------------------
    # Continuous batching (slot pool + admission scheduler).
    # ------------------------------------------------------------------

    def stream(self, requests: list[Request], key=None):
        """Streaming ``generate``: a generator yielding
        :class:`TokenEvent` rows as tokens are sampled (per-rid events
        arrive in index order; cross-request interleaving follows the
        scheduler).  The run itself executes on a background thread;
        once the generator is exhausted ``last_stats``/``last_metrics``
        hold the finished run's aggregates, and any engine exception
        re-raises here.  Abandoning the generator early leaves the run
        to finish in the background (daemon thread)."""
        return _stream_events(
            lambda cb: self.generate(requests, key=key, on_token=cb))

    def _generate_continuous(self, items, key, on_token=None) \
            -> list[Result]:
        """items: [(submission order, Request)]; results align with items."""
        self.begin_session(key, on_token)
        queue = collections.deque(
            (seq, order, r) for seq, (order, r) in enumerate(items))
        results: list[Result | None] = [None] * len(items)
        try:
            while queue or self.session_active:
                # admission: refill every free slot before the next decode
                # step.  The fifo policy admits strictly in arrival order
                # (no skip-ahead, so a big request cannot starve under
                # paged admission); reordering policies pick the minimum
                # order_key instead — but still stop at the first
                # inadmissible pick rather than skipping past it, so the
                # no-starvation property holds per policy choice too
                while queue and self.session_free_slot() is not None:
                    if self.policy.reorders:
                        now = self.clock.now()
                        item = min(queue,
                                   key=lambda it: self.policy.order_key(
                                       it[0], it[2], self._sess.t_start,
                                       now))
                    else:
                        item = queue[0]
                    seq, order, r = item
                    if not self.session_can_admit(r):
                        break
                    queue.remove(item)
                    res = self.session_admit(r, tag=seq, extra_row=order,
                                             enqueue_t=self._sess.t_start)
                    if res is not None:
                        results[seq] = res
                if queue and not self.session_active:
                    # nothing live here yet the head cannot be admitted:
                    # only reachable when a shared pool's co-tenant holds
                    # the blocks - fail loudly instead of spinning (a
                    # cluster driver interleaves engines; generate cannot)
                    raise MemoryError(
                        f"engine owner={self.owner!r} is idle but the "
                        f"shared pool cannot admit rid="
                        f"{queue[0][2].rid} (co-tenants hold "
                        f"{self.allocator.n_live} blocks, "
                        f"{self.allocator.n_reserved} reserved)")
                for tag, res in self.session_step():
                    results[tag] = res
        except BaseException:
            # keep the allocator consistent if anything aborts the batch
            # mid-schedule
            self.session_abort()
            raise
        self.last_stats = self.end_session()
        return results

    # ------------------------------------------------------------------
    # Lock-step group batching (legacy / scan-cache fallback).
    # ------------------------------------------------------------------

    def _pad_prompts(self, prompts: list[list[int]]) -> np.ndarray:
        # left-pad to a common length (uniform-position cache layout)
        maxlen = max(len(p) for p in prompts)
        out = np.zeros((len(prompts), maxlen), np.int32)
        for i, p in enumerate(prompts):
            out[i, maxlen - len(p):] = p
        return out

    def _generate_lockstep(self, items, key) -> list[Result]:
        """items: [(submission order, Request)]; results align with items."""
        results: list[Result | None] = [None] * len(items)
        queue = [(seq, order, r) for seq, (order, r) in enumerate(items)]
        m = MetricsRegistry()
        t_start = self.clock.now()
        while queue:
            group = queue[: self.max_batch]
            queue = queue[self.max_batch:]
            self._generate_group(group, key, results, m)
        wall = self.clock.now() - t_start
        m.counter("generated_tokens").inc(
            sum(len(r.tokens) for r in results))
        self.last_metrics = m
        self.last_stats = EngineStats.from_registry(
            m, mode="lockstep", wall_s=wall,
            prefill_compiles=len(self._prefill_shapes))
        return results

    def _generate_group(self, group, key, results, m: MetricsRegistry):
        reqs = [r for _, _, r in group]
        prompts = self._pad_prompts([list(r.prompt) + list(r.done)
                                     for r in reqs])
        self._prefill_shapes.add(prompts.shape[1])
        batch = {"tokens": jnp.asarray(prompts),
                 **self._gather_extra([order for _, order, _ in group])}
        t0 = self.clock.now()
        logits, cache = self._prefill(self.params, batch)
        jax.block_until_ready(logits)
        t_pf = self.clock.now()
        prefill_ms = (t_pf - t0) * 1e3
        if self.tracer.enabled:
            self.tracer.complete(self.track, "prefill", t0, t_pf,
                                 group=len(reqs))
        remaining = [r.max_new_tokens - len(r.done) for r in reqs]
        max_new = max(remaining)
        if self._slot_capable:
            # uniform-position KV layout: the whole group decodes in step,
            # so the group's slowest member sets the write budget (scan/ring
            # cache families manage their own state length)
            self._check_budget(int(np.asarray(cache["pos"])), max_new,
                               [r.rid for r in reqs])
        temps = jnp.asarray([r.temperature for r in reqs], jnp.float32)
        rids = jnp.asarray([r.rid for r in reqs], np.int32)
        base_idx = np.asarray([len(r.done) for r in reqs], np.int32)
        toks = np.asarray(self._sample(logits, temps, key, rids,
                                       jnp.asarray(base_idx)))[:, None]
        outs = [[int(toks[i, 0])] for i in range(len(reqs))]
        t1 = self.clock.now()
        n_steps = 0
        for _ in range(max_new - 1):
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(toks, jnp.int32))
            n_steps += 1
            toks = np.asarray(self._sample(
                logits, temps, key, rids,
                jnp.asarray(base_idx + n_steps)))[:, None]
            for i, r in enumerate(reqs):
                if len(outs[i]) < remaining[i]:
                    outs[i].append(int(toks[i, 0]))
        jax.block_until_ready(logits)
        t2 = self.clock.now()
        decode_ms = (t2 - t1) * 1e3 / max(n_steps, 1)
        if self.tracer.enabled and n_steps:
            self.tracer.complete(self.track, "decode_group", t1, t2,
                                 steps=n_steps, group=len(reqs))
        busy_total = 0
        # recompute busy slot-steps: request i is busy for its first
        # (remaining - 1) decode steps of this group
        for rem in remaining:
            busy_total += min(max(rem - 1, 0), max(n_steps, 0))
        m.counter("decode_steps").inc(n_steps)
        m.counter("busy_slot_steps").inc(busy_total)
        m.counter("offered_slot_steps").inc(self.max_batch * n_steps)
        for _ in reqs:
            m.histogram("ttft_ms").observe(prefill_ms)
        for i, (seq, _, r) in enumerate(group):
            results[seq] = Result(r.rid, list(r.done) + outs[i], prefill_ms,
                                  decode_ms)
            if remaining[i] > 1:
                m.histogram("tpot_ms").observe(decode_ms)
