from .engine import EngineStats, Request, Result, ServeEngine
