from .engine import Request, Result, ServeEngine
