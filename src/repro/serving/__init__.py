from .engine import EngineStats, Request, Result, ServeEngine
from .kvcache import BlockAllocator, BlockPoolStats, blocks_needed
