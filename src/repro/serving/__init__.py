"""Public serving API.

* :class:`ServeEngine` — slot-pool continuous batching (plus the
  lock-step baseline) over any family of the uniform Model API, with
  dense, paged, and slot-addressable-recurrent cache layouts and a
  stepwise session API for outer schedulers.
* :class:`ClusterEngine` — N replicas behind a router; paged families
  share one :class:`BlockAllocator` pool with preemption under
  :class:`PoolPressure`, scan families run per-replica slot state.
  Two drivers (``DRIVERS``): a deterministic sequential loop and a
  threaded event loop overlapping replica dispatch; byte-identical
  tokens either way.
* scheduling — pluggable :class:`SchedPolicy` strategies (``POLICIES``:
  fifo/priority/edf/slo_adaptive) driving admission order, routing, and
  preemption-victim ranking from per-request latency budgets
  (``Request.slo_ttft_ms``/``slo_tpot_ms``), including the starvation
  pressure signal for dense/scan replicas that can never raise
  :class:`PoolPressure`.  With no budgets set every policy is
  byte-identical to fifo.  See ``docs/serving.md``.
* streaming — ``ServeEngine.stream`` / ``ClusterEngine.stream`` yield
  :class:`TokenEvent` rows as tokens are sampled; ``generate`` takes an
  ``on_token`` callback for push-style consumers.
* telemetry — :class:`Tracer`/:class:`NullTracer` request-lifecycle
  tracing (Chrome-trace/Perfetto export), the :class:`MetricsRegistry`
  percentile metrics every :class:`EngineStats` is derived from, and
  injectable clocks (:class:`FakeClock` for deterministic latency
  tests).  See ``docs/observability.md``.
* attribution — :class:`Attributor`/:class:`NullAttributor` roofline-
  joined utilization accounting: per-launch achieved FLOP/s and bytes/s
  against a :class:`MachineSpec` roofline, bottleneck verdicts
  (``issue``/``memory``/``compute``/``idle``, the paper's §6 regimes),
  and the engine-level ``fu_utilization`` figure on
  :class:`EngineStats`.  See ``docs/observability.md``.

Cross-cutting invariants (asserted in ``tests/test_serving_props.py``,
``tests/test_serving.py``, ``tests/test_cluster.py``): request-keyed
sampling makes token streams placement/scheduler-independent; block
accounting conserves the pool exactly (refcounted prefix sharing
included — ``sum(refs) >= n_live``, cached blocks stay allocatable);
a prefix-cache hit serves bytes bit-identical to a cold prefill;
preemption + requeue is invisible in the output; freed slots leak no
state to later occupants; recorded event streams are
lifecycle-well-formed (:func:`validate_lifecycle`) and tracing never
changes tokens.  The full scheduler matrix and knob reference live in
``docs/serving.md``.
"""
from .attribution import (NULL_ATTR, VERDICTS, Attributor, MachineSpec,
                          NullAttributor, PhaseCost, dominant_verdict)
from .cluster import DRIVERS, ROUTER_POLICIES, ClusterEngine
from .engine import EngineStats, Request, Result, ServeEngine, TokenEvent
from .kvcache import (BlockAllocator, BlockPoolStats, PoolPressure,
                      blocks_needed, prefix_chain_keys)
from .slo import POLICIES, SchedPolicy, make_policy
from .telemetry import (MONOTONIC, NULL_TRACER, FakeClock, MetricsRegistry,
                        MonotonicClock, NullTracer, Tracer,
                        validate_lifecycle)
