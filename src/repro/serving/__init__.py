from .cluster import ROUTER_POLICIES, ClusterEngine
from .engine import EngineStats, Request, Result, ServeEngine
from .kvcache import (BlockAllocator, BlockPoolStats, PoolPressure,
                      blocks_needed)
