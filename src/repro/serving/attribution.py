"""Utilization attribution: roofline-joined step accounting and
bottleneck classification.

Every headline number in Ara2 is *cycle accounting*: §6 instruments
functional-unit utilization per kernel (95% on compute-bound matmul),
and the short-vector regimes are diagnosed as *issue-rate bound* — the
scalar core cannot feed the lanes fast enough — while other kernels pin
the memory system.  PR 7's telemetry records where wall-clock goes
(dispatch vs device spans, slot occupancy) but not *why*: a 4 ms step
span does not say whether the step was starved by dispatch, by HBM, or
was genuinely compute-saturated.

This module closes that gap by joining the two measurement layers the
repo already has:

* the **telemetry** spans/metrics (``repro.serving.telemetry``): per
  decode launch, the host-side dispatch time ``[t0, t_disp]``, the
  blocking device time ``[t_disp, t1]``, and how many of the launch's
  fixed ``max_batch`` slot lanes held a live request;
* the **roofline cost layer** (``repro.roofline.hlo_cost``): exact
  flops and HBM bytes of each compiled executable — the decode step,
  the paged prefill chunk, the dense prefill — read off the compiled
  HLO text with while-trip scaling (the same parser the dry-run
  roofline uses), lowered once per (phase, shape) and memoized.

Joined, every step gets an **attribution record**: achieved FLOP/s and
bytes/s against a :class:`MachineSpec` roofline, and a **bottleneck
verdict** mirroring the paper's §6 regimes:

  ``issue``   - host dispatch dominates the launch (the serving twin of
                the scalar core's issue-rate bound on short vectors);
  ``memory``  - device-bound with useful arithmetic intensity below the
                machine's ridge point (flops/byte where the roofline
                bends);
  ``compute`` - device-bound above the ridge (the regime where Ara2
                reports 95% FU utilization);
  ``idle``    - the launch carried no live request at all.

The engine-level ``fu_utilization`` figure — useful flops (idle lanes
excluded, exactly like idle vector lanes in the paper) per second of
device time, over the machine's peak — is the serving analog of the
paper's FU-utilization headline, and it aggregates across a cluster by
the same lossless-merge discipline as every other metric: replicas
record raw per-step samples into their registries, the cluster
concatenates them, and the figure is derived from the union.

Like tracing, attribution must be free when off and invisible when on:
the default :data:`NULL_ATTR` is a no-op guarded by ``enabled`` on the
hot path (bounded by the ``serving_attr_overhead`` bench row), and an
enabled :class:`Attributor` is host-side only — it never touches the
compiled functions the engine executes (costs come from a *separate*
AOT lowering of the same jitted callables), so tokens are byte-identical
with attribution on vs off (asserted across the conformance matrix).
"""
from __future__ import annotations

import dataclasses
import threading

from ..roofline.hlo_cost import HloCost

#: Bottleneck verdicts, mapped to the paper's §6 regimes (see module doc).
VERDICTS = ("issue", "memory", "compute", "idle")


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """The machine roofline attribution measures against: peak FLOP/s,
    peak memory bytes/s, and the derived ridge point (the arithmetic
    intensity where the roofline bends from the bandwidth slope onto the
    flat compute ceiling)."""
    name: str
    peak_flops: float              # FLOP/s
    mem_bw: float                  # bytes/s

    @property
    def ridge(self) -> float:
        """Ridge-point arithmetic intensity (flops per byte)."""
        return self.peak_flops / max(self.mem_bw, 1e-9)

    @classmethod
    def from_tpu(cls, spec) -> "MachineSpec":
        """From a :class:`repro.core.ppa.TpuSpec`."""
        return cls(spec.name, spec.peak_bf16_flops, spec.hbm_bw)

    @classmethod
    def detect(cls) -> "MachineSpec":
        """Best-effort spec for the current jax backend.  TPU uses the
        repo's v5e silicon constants; CPU/GPU get nominal figures — on
        those backends the *absolute* utilization is indicative only,
        but verdicts and trends are still comparable run-over-run (the
        regression gate's tolerance bands account for this; see
        docs/observability.md)."""
        try:
            import jax
            plat = jax.default_backend()
        except Exception:               # pragma: no cover - jax always here
            plat = "cpu"
        if plat == "tpu":
            from ..core.ppa import TPU_V5E
            return cls.from_tpu(TPU_V5E)
        if plat == "gpu":
            return cls("gpu-nominal", 50e12, 1.0e12)
        return cls("cpu-nominal", 50e9, 25e9)


@dataclasses.dataclass(frozen=True)
class PhaseCost:
    """Per-launch cost of one compiled executable (per device)."""
    flops: float
    mem_bytes: float

    @property
    def ai(self) -> float:
        """Arithmetic intensity (flops per HBM byte)."""
        return self.flops / max(self.mem_bytes, 1e-9)


class NullAttributor:
    """Zero-overhead default: every method is a no-op.  Hot paths guard
    on ``enabled`` (one attribute check per decode step, same contract
    as :class:`~repro.serving.telemetry.NullTracer`)."""

    enabled = False

    def phase_cost(self, key, jitted, args):
        return None

    def record_step(self, metrics, tracer, track, *, t0, t_disp, t1,
                    active, width, cost):
        pass

    def record_prefill(self, metrics, tracer, track, *, t0, t1, cost,
                       tokens=0):
        pass


NULL_ATTR = NullAttributor()


class Attributor(NullAttributor):
    """Recording attributor: joins span timings with executable costs.

    ``spec`` is the roofline to measure against (default: detected from
    the jax backend).  ``issue_threshold`` is the dispatch fraction of a
    launch above which the step is called issue-bound (default 0.5 —
    the host spent at least as long feeding the launch as the device
    spent computing it, the §6 short-vector signature).

    One Attributor may be shared by every replica of a cluster: the cost
    memo is keyed by (phase, shape) so identical replicas lower each
    executable once, and all recording goes into the *caller's* metrics
    registry, which the cluster merges losslessly.
    """

    enabled = True

    def __init__(self, spec: MachineSpec | None = None,
                 issue_threshold: float = 0.5):
        self.spec = spec if spec is not None else MachineSpec.detect()
        self.issue_threshold = float(issue_threshold)
        self._costs: dict = {}
        self._lock = threading.Lock()

    # -- cost extraction ----------------------------------------------

    def phase_cost(self, key, jitted, args) -> PhaseCost:
        """Flops/bytes of ``jitted`` at the shapes of ``args``, memoized
        by ``key``.  A cache miss lowers and compiles a *separate* AOT
        executable of the same function (host-side; the engine's own
        compiled callables and their device buffers are untouched) and
        reads the cost off its HLO text with the while-trip-scaled
        parser the dry-run roofline uses — ``cost_analysis()`` counts
        ``lax.scan`` layer stacks once, which would undercount every
        model here by ~n_layers."""
        c = self._costs.get(key)
        if c is not None:
            return c
        compiled = jitted.lower(*args).compile()
        cost = HloCost(compiled.as_text()).cost()
        c = PhaseCost(float(cost.flops), float(cost.mem_bytes))
        with self._lock:
            c = self._costs.setdefault(key, c)
        return c

    # -- classification -----------------------------------------------

    def classify(self, *, active: int, width: int, dispatch_s: float,
                 device_s: float, cost: PhaseCost) -> str:
        """Bottleneck verdict for one decode launch (see module doc for
        the paper mapping).  ``active``/``width`` are live vs launched
        slot lanes; the *useful* arithmetic intensity scales the
        executable's flops by the live fraction (idle lanes do useless
        work but still drag their rows through the memory system — the
        fixed-width cost `bench_cluster` measures), so a mostly-idle
        launch correctly reads memory-bound even when the executable's
        nominal intensity clears the ridge."""
        if active <= 0:
            return "idle"
        total = dispatch_s + device_s
        if total > 0.0 and dispatch_s >= self.issue_threshold * total:
            return "issue"
        useful_ai = cost.ai * (active / max(width, 1))
        return "memory" if useful_ai < self.spec.ridge else "compute"

    # -- recording ----------------------------------------------------

    def record_step(self, metrics, tracer, track, *, t0, t_disp, t1,
                    active, width, cost) -> None:
        """Attribute one decode launch: verdict counter, raw per-step
        samples (useful flops, bytes, dispatch/device ms — histograms,
        so cluster aggregation stays lossless), and, when a tracer is
        live, a per-step ``roofline`` counter track (percent-of-peak
        FLOP/s and bytes/s) that Perfetto draws alongside the lifecycle
        spans."""
        dispatch_s = max(t_disp - t0, 0.0)
        device_s = max(t1 - t_disp, 0.0)
        verdict = self.classify(active=active, width=width,
                                dispatch_s=dispatch_s, device_s=device_s,
                                cost=cost)
        useful_flops = cost.flops * (active / max(width, 1))
        m = metrics
        m.counter(f"attr_verdict_{verdict}").inc()
        m.histogram("attr_step_flops").observe(useful_flops)
        m.histogram("attr_step_bytes").observe(cost.mem_bytes)
        m.histogram("attr_dispatch_ms").observe(dispatch_s * 1e3)
        m.histogram("attr_device_ms").observe(device_s * 1e3)
        m.gauge("attr_peak_flops").set(self.spec.peak_flops)
        m.gauge("attr_peak_bytes_s").set(self.spec.mem_bw)
        m.gauge("attr_decode_ai").set(cost.ai)
        if tracer.enabled:
            step_s = max(t1 - t0, 1e-12)
            tracer.counter(
                track, "roofline",
                flops_pct=100.0 * useful_flops / (step_s
                                                  * self.spec.peak_flops),
                bytes_pct=100.0 * cost.mem_bytes / (step_s
                                                    * self.spec.mem_bw))

    def record_prefill(self, metrics, tracer, track, *, t0, t1, cost,
                       tokens=0) -> None:
        """Attribute one prefill launch (a paged chunk or a dense
        prefill call).  Prefill has no dispatch/device split recorded
        (the chunk call returns asynchronously and the engine must not
        add a device sync just to measure it), so the verdict is pure
        roofline: the executable's arithmetic intensity against the
        ridge — prefill batches whole prompts, the paper's long-vector
        regime, where issue rate stops being the bound."""
        dt = max(t1 - t0, 0.0)
        verdict = "memory" if cost.ai < self.spec.ridge else "compute"
        m = metrics
        m.counter(f"attr_prefill_verdict_{verdict}").inc()
        m.histogram("attr_prefill_flops").observe(cost.flops)
        m.histogram("attr_prefill_bytes").observe(cost.mem_bytes)
        m.histogram("attr_prefill_ms").observe(dt * 1e3)
        m.gauge("attr_peak_flops").set(self.spec.peak_flops)
        m.gauge("attr_peak_bytes_s").set(self.spec.mem_bw)
        if tracer.enabled:
            span_s = max(dt, 1e-12)
            tracer.counter(
                track, "roofline",
                flops_pct=100.0 * cost.flops / (span_s
                                                * self.spec.peak_flops),
                bytes_pct=100.0 * cost.mem_bytes / (span_s
                                                    * self.spec.mem_bw))


def dominant_verdict(counts: dict) -> str:
    """The verdict with the most steps ('' when nothing was recorded);
    ties break by the VERDICTS order (issue first — the paper's default
    suspicion for short-vector serving workloads)."""
    best, best_n = "", 0
    for v in VERDICTS:
        n = counts.get(v, 0)
        if n > best_n:
            best, best_n = v, n
    return best
