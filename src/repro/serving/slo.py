"""SLO-aware scheduling policies: admission order, victim ranking, and
starvation pressure from per-request latency budgets.

Every knob the scheduler had before this module was static: admission
was strictly FIFO, preemption victims were picked by (priority,
-admit_seq), and the only pressure signal in the system was the paged
pool's :class:`~repro.serving.kvcache.PoolPressure` — dense and
scan-family replicas never felt pressure at all, so a long best-effort
request could sit on a slot forever while short interactive requests
aged in the queue.  That is the serving twin of Ara2's §6 finding: the
*issue policy*, not the raw FPU count, gates utilization in the
short-workload regime.

This module adds the missing signal and the policies that act on it:

* **budgets** — :class:`~repro.serving.engine.Request` carries
  ``slo_ttft_ms`` (enqueue → first token) and ``slo_tpot_ms`` (decode
  ms per output token).  Both default to ``None`` = best-effort; a
  request with neither budget behaves exactly as before.

* **policies** (``POLICIES``) — pluggable :class:`SchedPolicy`
  strategies threaded through ``ServeEngine`` (admission reorder),
  ``ClusterEngine`` (routing, victim pick, both drivers), and
  ``launch.serve`` (``--policy``):

  - ``fifo``          — strict arrival order, head-of-line blocking
                        (byte-for-byte today's behavior);
  - ``priority``      — highest ``Request.priority`` first, FIFO ties;
  - ``edf``           — earliest TTFT deadline first; best-effort
                        requests (deadline = +inf) stay FIFO behind
                        every budgeted one;
  - ``slo_adaptive``  — EDF admission **plus** deadline-aware victim
                        ranking (a budgeted request inside its slack is
                        *protected*: never evicted while a best-effort
                        victim exists), slack-aware routing (budgeted
                        requests go to the emptiest replica), and the
                        **starvation pressure signal**: when no replica
                        has a free slot (slot-count signal) and the most
                        urgent queued request's remaining TTFT slack has
                        fallen inside the guard band (queue-age signal),
                        the cluster preempts an unprotected victim —
                        this is how dense/scan replicas, which can never
                        raise ``PoolPressure``, finally feel pressure.

Correctness contract (asserted across the conformance matrix in
``tests/test_serving_props.py``): with no budgets set every policy's
token output is byte-identical to FIFO — ``edf``/``slo_adaptive`` keys
degenerate to arrival order when every deadline is +inf, and
request-keyed sampling makes token streams a pure function of
(rid, token index) regardless of admission order; with budgets set the
per-request streams are *still* byte-identical — policies reorder,
never alter, sampling.

All scoring here is host-side arithmetic over the injectable clock
(``telemetry.FakeClock`` makes starvation tests deterministic); no
compiled function depends on a policy, so a warm engine keeps its
caches when the policy changes.
"""
from __future__ import annotations

POLICIES = ("fifo", "priority", "edf", "slo_adaptive")

_INF = float("inf")


def ttft_deadline(req, enqueue_t: float) -> float:
    """Absolute first-token deadline (clock seconds) of ``req`` enqueued
    at ``enqueue_t``; +inf for a best-effort request (no TTFT budget)."""
    if req.slo_ttft_ms is None:
        return _INF
    return enqueue_t + req.slo_ttft_ms / 1e3


def slo_budget_s(req) -> float | None:
    """Whole-request latency window (seconds): TTFT budget plus the TPOT
    budget over the tokens still owed.  None when best-effort."""
    if req.slo_ttft_ms is None and req.slo_tpot_ms is None:
        return None
    owed = max(req.max_new_tokens - len(req.done), 0)
    return ((req.slo_ttft_ms or 0.0) + (req.slo_tpot_ms or 0.0) * owed) / 1e3


def in_slack(req, t0: float, now: float) -> bool:
    """True while a budgeted request served since ``t0`` is inside its
    whole-request latency window — the *protected* state: an SLO-aware
    victim pick must not evict it while a best-effort victim exists.
    Best-effort requests are never in slack (always evictable first)."""
    budget = slo_budget_s(req)
    return budget is not None and (now - t0) < budget


class SchedPolicy:
    """Base scheduling strategy; the concrete policies override keys.

    Key contracts (all pure, host-side, evaluated at one ``now`` per
    scheduling decision so comparisons are consistent):

    * ``order_key(seq, req, enqueue_t, now)`` — admission order; the
      queued item with the *minimum* key is admitted next.  Ties fall
      back to ``seq`` (arrival order), so keys must embed it.
    * ``victim_key(req, admit_seq, t0, now)`` — preemption ranking over
      live requests; the *minimum* key is evicted first.  The leading
      element is the protection flag (0 = evictable, 1 = inside its
      deadline slack), so a protected request is only ever chosen when
      no unprotected candidate exists — the bugfix regression in
      ``tests/test_slo.py`` pins this.
    * ``starving(req, enqueue_t, now, guard_s)`` — the queue-age half of
      the dense/scan pressure signal: True once the queued request's
      remaining TTFT slack is inside the guard band.

    Flags: ``reorders`` — admission picks min(order_key) over ready
    items instead of the FIFO head (and may skip past a cooling-down
    victim); ``preempts_on_starvation`` — the cluster drivers arm the
    slot-count + queue-age pressure signal; ``slack_routes`` — budgeted
    requests route to the emptiest replica regardless of the configured
    router (best-effort traffic keeps the configured policy).
    """

    name = "fifo"
    reorders = False
    preempts_on_starvation = False
    slack_routes = False

    def order_key(self, seq: int, req, enqueue_t: float, now: float):
        return (0.0, seq)

    def victim_key(self, req, admit_seq: int, t0: float, now: float):
        # classic ranking: lowest priority, then youngest admission
        return (0, req.priority, -admit_seq)

    def starving(self, req, enqueue_t: float, now: float,
                 guard_s: float) -> bool:
        return False

    def __repr__(self):
        return f"{type(self).__name__}({self.name!r})"


class FifoPolicy(SchedPolicy):
    """Strict arrival order with head-of-line blocking — byte-for-byte
    the pre-policy scheduler (the conformance reference)."""

    name = "fifo"


class PriorityPolicy(SchedPolicy):
    """Highest ``Request.priority`` admitted first; arrival order breaks
    ties.  Victim ranking is unchanged (lowest priority evicted first),
    so priority is honored symmetrically at admission and eviction."""

    name = "priority"
    reorders = True

    def order_key(self, seq, req, enqueue_t, now):
        return (float(-req.priority), seq)


class EdfPolicy(SchedPolicy):
    """Earliest-deadline-first admission over the TTFT deadline.
    Best-effort requests (deadline +inf) stay FIFO among themselves
    behind every budgeted request; with no budgets anywhere the key
    degenerates to arrival order (≡ FIFO)."""

    name = "edf"
    reorders = True

    def order_key(self, seq, req, enqueue_t, now):
        return (ttft_deadline(req, enqueue_t), seq)


class SloAdaptivePolicy(EdfPolicy):
    """EDF admission plus the adaptive halves: deadline-aware victim
    protection, slack-aware routing, and the starvation pressure signal
    for replicas that can never raise ``PoolPressure`` (dense/scan).
    See the module doc for the full semantics."""

    name = "slo_adaptive"
    preempts_on_starvation = True
    slack_routes = True

    def victim_key(self, req, admit_seq, t0, now):
        return (int(in_slack(req, t0, now)), req.priority, -admit_seq)

    def starving(self, req, enqueue_t, now, guard_s):
        deadline = ttft_deadline(req, enqueue_t)
        return deadline < _INF and deadline - now <= guard_s


_REGISTRY = {p.name: p for p in (FifoPolicy, PriorityPolicy, EdfPolicy,
                                 SloAdaptivePolicy)}


def make_policy(policy) -> SchedPolicy:
    """Resolve ``policy`` to a :class:`SchedPolicy` instance: a name
    from ``POLICIES``, or an instance passed through (custom policies
    plug in by subclassing)."""
    if isinstance(policy, SchedPolicy):
        return policy
    if policy not in _REGISTRY:
        raise ValueError(f"policy={policy!r}: pick one of {POLICIES} "
                         "(or pass a SchedPolicy instance)")
    return _REGISTRY[policy]()
