"""Serving telemetry: request-lifecycle tracing, percentile metrics, and
Chrome-trace (Perfetto) export.

The paper's core *method* is measurement-driven bottleneck analysis: Ara2
instruments functional-unit utilization per kernel (§5-6) to pinpoint
whether the scalar core, the memories, or the vector architecture gates
throughput, and AraOS extends the same methodology to price
virtual-memory management on the vector unit.  This module gives the
serving stack the same instrument: instead of a single mean TTFT and a
final occupancy number, every request's lifecycle (enqueue -> admit ->
chunked prefill -> decode stretches -> preempt -> requeue -> finish),
every pool event (alloc/free/COW/reservation, free-block watermark), and
every replica step (dispatch vs device time) becomes a timestamped event
that can be aggregated into percentiles or opened as a timeline in
Perfetto.

Three pieces:

* :class:`Tracer` / :class:`NullTracer` - a span / instant / counter /
  flow event recorder.  ``NullTracer`` (the default everywhere) is a
  no-op whose methods exist so call sites never branch on None; hot
  paths additionally guard on ``tracer.enabled`` so the untraced decode
  step pays a single attribute check (the overhead contract in
  ``docs/observability.md``, bounded by a bench row).  ``Tracer`` is
  thread-safe (one lock around the event list) and takes an injectable
  :class:`Clock`, so the future async cluster driver can adopt it
  unchanged and tests can drive a :class:`FakeClock` for deterministic
  latency math.

* :class:`MetricsRegistry` - named counters / gauges / histograms /
  timelines.  Histograms keep raw samples, so percentiles are exact
  (nearest-rank) and registries merge losslessly - the cluster
  aggregates replica histograms instead of averaging replica means.

* :func:`Tracer.chrome_trace` / :func:`Tracer.export` - the Chrome
  trace-event JSON exporter (the ``traceEvents`` array format both
  Perfetto and chrome://tracing load): one named track per recorded
  track string (replicas, their slots, the pool, the cluster router),
  request spans as complete ("X") events that nest by containment,
  preempt -> requeue handoffs as flow ("s"/"f") arrows, pool watermarks
  as counter ("C") series.

:func:`validate_lifecycle` is the event-stream conformance check the
property suite runs over random traces: admits precede decodes, every
preempt is answered by a requeue or abort, and per-request block
acquisitions balance releases.
"""
from __future__ import annotations

import dataclasses
import json
import math
import threading
import time
from typing import Any


# ---------------------------------------------------------------------------
# Clocks.
# ---------------------------------------------------------------------------

class MonotonicClock:
    """The default wall clock (``time.perf_counter``, seconds)."""

    @staticmethod
    def now() -> float:
        return time.perf_counter()


class FakeClock:
    """Deterministic test clock: ``now()`` returns the current time and
    then advances it by ``tick`` (plus any manual ``advance`` calls), so
    latency math in tests is exact instead of sleep/flake-prone."""

    def __init__(self, start: float = 0.0, tick: float = 0.0):
        self._t = float(start)
        self.tick = float(tick)
        self._lock = threading.Lock()

    def now(self) -> float:
        with self._lock:
            t = self._t
            self._t += self.tick
            return t

    def advance(self, dt: float) -> None:
        with self._lock:
            self._t += dt


MONOTONIC = MonotonicClock()


# ---------------------------------------------------------------------------
# Tracer.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Event:
    """One recorded trace event (host-side representation; the Chrome
    JSON shape is produced at export).  ``ph`` follows the trace-event
    phase codes: "X" complete span, "i" instant, "C" counter, "s"/"f"
    flow start/finish."""
    ph: str
    track: str
    name: str
    ts: float                      # clock seconds
    dur: float = 0.0               # span length (ph == "X")
    args: dict = dataclasses.field(default_factory=dict)
    fid: str = ""                  # flow id (ph in "sf")


class _NullSpan:
    """Reusable no-op context manager (``NullTracer.span``)."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Zero-overhead default tracer: every method is a no-op.

    Hot paths (the per-step decode loop) guard on ``enabled`` so the
    untraced engine pays one attribute check per potential event; cold
    paths may call methods unconditionally.  ``events()`` returns an
    empty list so validators and exporters degrade gracefully."""

    enabled = False

    def span(self, track, name, **args):
        return _NULL_SPAN

    def complete(self, track, name, t0, t1, **args):
        pass

    def instant(self, track, name, **args):
        pass

    def counter(self, track, name, **values):
        pass

    def flow_start(self, track, name, fid):
        pass

    def flow_end(self, track, name, fid):
        pass

    def events(self):
        return []


NULL_TRACER = NullTracer()


class _Span:
    """Context manager recording one complete ("X") event on exit."""

    __slots__ = ("_tr", "_track", "_name", "_args", "_t0")

    def __init__(self, tr, track, name, args):
        self._tr = tr
        self._track = track
        self._name = name
        self._args = args

    def __enter__(self):
        self._t0 = self._tr.clock.now()
        return self

    def __exit__(self, *exc):
        self._tr.complete(self._track, self._name, self._t0,
                          self._tr.clock.now(), **self._args)
        return False


class Tracer(NullTracer):
    """Recording tracer: appends :class:`Event` rows under a lock.

    ``clock`` is injectable (defaults to the process monotonic clock);
    every timestamp an engine, cluster, or allocator records through
    this tracer comes from it, so a :class:`FakeClock` makes whole
    traces deterministic.  Thread-safe: concurrent replica threads may
    record interleaved events; export sorts by timestamp."""

    enabled = True

    def __init__(self, clock=None):
        self.clock = clock if clock is not None else MONOTONIC
        self._events: list[Event] = []
        self._lock = threading.Lock()

    def _record(self, ev: Event) -> None:
        with self._lock:
            self._events.append(ev)

    def span(self, track, name, **args):
        """Context manager: records a complete span over the ``with``
        body (host-side wall time between enter and exit)."""
        return _Span(self, track, name, args)

    def complete(self, track, name, t0, t1, **args):
        """Record a finished span ``[t0, t1]`` (explicit timestamps, for
        spans that cross call boundaries - a request's slot residency)."""
        self._record(Event("X", track, name, t0, max(t1 - t0, 0.0), args))

    def instant(self, track, name, **args):
        self._record(Event("i", track, name, self.clock.now(), 0.0, args))

    def counter(self, track, name, **values):
        """Record a counter sample (one Chrome counter track per name;
        ``values`` are the series, e.g. ``free=12, live=4``)."""
        self._record(Event("C", track, name, self.clock.now(), 0.0,
                           dict(values)))

    def flow_start(self, track, name, fid):
        """Open a flow arrow (e.g. at a preemption); ``flow_end`` with
        the same ``fid`` draws the arrow to wherever the work resumed."""
        self._record(Event("s", track, name, self.clock.now(), 0.0, {},
                           str(fid)))

    def flow_end(self, track, name, fid):
        self._record(Event("f", track, name, self.clock.now(), 0.0, {},
                           str(fid)))

    def events(self) -> list[Event]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    # -- export --------------------------------------------------------

    def chrome_trace(self) -> dict:
        """The recorded events as a Chrome trace-event JSON object
        (Perfetto-loadable).  Tracks map to threads of one process,
        named via ``thread_name`` metadata and ordered alphabetically so
        ``replicaN`` sits above its ``replicaN/slotM`` request tracks;
        timestamps are microseconds."""
        events = sorted(self.events(), key=lambda e: e.ts)
        tracks = sorted({e.track for e in events})
        tid = {t: i + 1 for i, t in enumerate(tracks)}
        out: list[dict] = []
        for t in tracks:
            out.append({"ph": "M", "pid": 1, "tid": tid[t],
                        "name": "thread_name", "args": {"name": t}})
            out.append({"ph": "M", "pid": 1, "tid": tid[t],
                        "name": "thread_sort_index",
                        "args": {"sort_index": tid[t]}})
        for e in events:
            row = {"ph": e.ph, "pid": 1, "tid": tid[e.track],
                   "name": e.name, "ts": e.ts * 1e6}
            if e.ph == "X":
                row["dur"] = e.dur * 1e6
                row["args"] = e.args
            elif e.ph == "i":
                row["s"] = "t"          # instant scope: thread
                row["args"] = e.args
            elif e.ph == "C":
                row["args"] = e.args
            elif e.ph in ("s", "f"):
                row["cat"] = "flow"
                row["id"] = e.fid
                if e.ph == "f":
                    row["bp"] = "e"     # bind to the enclosing slice
            out.append(row)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export(self, path: str) -> int:
        """Write the Chrome trace JSON to ``path``; returns the event
        count (metadata rows excluded)."""
        trace = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(trace, f)
        return sum(e["ph"] != "M" for e in trace["traceEvents"])


# ---------------------------------------------------------------------------
# Metrics registry.
# ---------------------------------------------------------------------------

def percentile(samples, q: float) -> float:
    """Nearest-rank percentile (exact over raw samples; 0.0 when empty).
    ``q`` in [0, 100]."""
    if not samples:
        return 0.0
    s = sorted(samples)
    k = max(math.ceil(q / 100.0 * len(s)), 1) - 1
    return float(s[min(k, len(s) - 1)])


class Counter:
    __slots__ = ("n", "_lock")

    def __init__(self, lock):
        self.n = 0
        self._lock = lock

    def inc(self, k: int = 1) -> None:
        with self._lock:
            self.n += k


class Gauge:
    __slots__ = ("value", "_lock")

    def __init__(self, lock):
        self.value = 0.0
        self._lock = lock

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)


class Histogram:
    """Raw-sample histogram: exact nearest-rank percentiles, lossless
    merge (the cluster concatenates replica samples instead of averaging
    replica summaries)."""

    __slots__ = ("samples", "_lock")

    def __init__(self, lock):
        self.samples: list[float] = []
        self._lock = lock

    def observe(self, v: float) -> None:
        with self._lock:
            self.samples.append(float(v))

    def values(self) -> list[float]:
        """Consistent copy of the raw samples (taken under the lock) —
        the safe way to read a histogram that is still being observed
        from another thread."""
        with self._lock:
            return list(self.samples)

    @property
    def count(self) -> int:
        with self._lock:
            return len(self.samples)

    @property
    def mean(self) -> float:
        s = self.values()
        return sum(s) / len(s) if s else 0.0

    def percentile(self, q: float) -> float:
        return percentile(self.values(), q)


class Timeline:
    """(time, value) series - occupancy and pool-utilization timelines."""

    __slots__ = ("points", "_lock")

    def __init__(self, lock):
        self.points: list[tuple[float, float]] = []
        self._lock = lock

    def record(self, t: float, v: float) -> None:
        with self._lock:
            self.points.append((float(t), float(v)))


class MetricsRegistry:
    """Named metric instruments, get-or-create, one lock shared by every
    instrument (serving-scale traffic; contention is not the bottleneck
    here and one lock keeps ``merge`` trivially consistent)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        self._timelines: dict[str, Timeline] = {}

    def _get(self, table: dict, name: str, cls):
        inst = table.get(name)
        if inst is None:
            with self._lock:
                inst = table.setdefault(name, cls(self._lock))
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(self._counters, name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(self._gauges, name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(self._histograms, name, Histogram)

    def timeline(self, name: str) -> Timeline:
        return self._get(self._timelines, name, Timeline)

    def merge(self, other: "MetricsRegistry") -> None:
        """Fold ``other`` into this registry: counters add, histogram
        samples and timeline points concatenate (timelines re-sorted by
        time), gauges take the other's latest value.

        Safe against a *live* ``other`` (exactly what a mid-session
        metrics poll of a threaded cluster does): both registries' locks
        are held for the whole fold, acquired in a stable id-order so two
        threads cross-merging each other's registries cannot deadlock,
        and every sample list is read under them — never torn state."""
        if other is self:
            return
        first, second = sorted((self, other), key=id)
        with first._lock, second._lock:
            # mutate tables directly: the instrument methods re-acquire
            # self._lock (non-reentrant), so they must not be called here
            for name, c in other._counters.items():
                mine = self._counters.setdefault(name, Counter(self._lock))
                mine.n += c.n
            for name, h in other._histograms.items():
                mine = self._histograms.setdefault(name,
                                                   Histogram(self._lock))
                mine.samples.extend(h.samples)
            for name, t in other._timelines.items():
                mine = self._timelines.setdefault(name,
                                                  Timeline(self._lock))
                mine.points.extend(t.points)
                mine.points.sort()
            for name, g in other._gauges.items():
                mine = self._gauges.setdefault(name, Gauge(self._lock))
                mine.value = g.value

    def snapshot(self) -> dict:
        """Plain-dict view: counters/gauges verbatim, histograms as
        count/mean/p50/p90/p99, timelines as point counts (the raw
        series stay on the instruments).  The whole snapshot is copied
        out under the registry lock, so a poll taken while worker
        threads are still observing summarizes one consistent state."""
        out: dict[str, Any] = {}
        with self._lock:
            counters = {n: c.n for n, c in self._counters.items()}
            gauges = {n: g.value for n, g in self._gauges.items()}
            hists = {n: list(h.samples)
                     for n, h in self._histograms.items()}
            points = {n: len(t.points) for n, t in self._timelines.items()}
        out.update(counters)
        out.update(gauges)
        for name, s in hists.items():
            out[name] = {"count": len(s),
                         "mean": sum(s) / len(s) if s else 0.0,
                         "p50": percentile(s, 50), "p90": percentile(s, 90),
                         "p99": percentile(s, 99)}
        for name, n in points.items():
            out[name] = {"points": n}
        return out


# ---------------------------------------------------------------------------
# Lifecycle conformance validation (the event-stream well-formedness the
# property suite asserts over random traces).
# ---------------------------------------------------------------------------

def validate_lifecycle(events: list[Event]) -> None:
    """Assert a recorded event stream is well-formed:

    * every span has non-negative duration;
    * every request that appears was admitted, and its admission count is
      1 + its requeue count (every re-admission was a requeue);
    * a request's first decode span starts at/after its first admission;
    * every ``preempt`` is answered by a ``requeue`` or an ``abort``, and
      each preemption's flow arrow is closed by a matching flow end;
    * per request, KV block acquisitions (prefix references, lazy
      allocations, COW copies) balance releases (COW reference drops,
      the release at finish/preempt) - the event-stream mirror of the
      allocator's conservation invariant.

    Raises AssertionError naming the first violated rule.
    """
    per: dict[Any, dict] = {}

    def rec(rid):
        return per.setdefault(rid, {
            "admits": [], "decodes": [], "finishes": 0, "preempts": 0,
            "requeues": 0, "aborts": 0, "readmits": 0,
            "acquired": 0, "released": 0})

    flows: dict[str, int] = {}
    for e in events:
        assert e.dur >= 0.0, f"negative span duration: {e}"
        if e.ph in ("s", "f"):
            flows[e.fid] = flows.get(e.fid, 0) + (1 if e.ph == "s" else -1)
            continue
        rid = e.args.get("rid")
        if rid is None:
            continue
        r = rec(rid)
        if e.name == "admit":
            r["admits"].append(e.ts)
            r["readmits"] += bool(e.args.get("readmit"))
        elif e.name == "decode":
            r["decodes"].append(e.ts)
        elif e.name == "finish":
            r["finishes"] += 1
        elif e.name == "preempt":
            r["preempts"] += 1
        elif e.name == "requeue":
            r["requeues"] += 1
        elif e.name == "abort":
            r["aborts"] += 1
        elif e.name == "kv_ref":
            r["acquired"] += e.args.get("n", 0)
        elif e.name == "kv_alloc":
            r["acquired"] += e.args.get("n", 0)
        elif e.name == "kv_cow":
            r["acquired"] += e.args.get("alloc", 0)
            r["released"] += e.args.get("freed", 0)
        elif e.name == "kv_free":
            r["released"] += e.args.get("n", 0)
    for rid, r in per.items():
        assert r["admits"], f"rid={rid}: events without an admission"
        assert len(r["admits"]) == 1 + r["readmits"], (
            f"rid={rid}: {len(r['admits'])} admits but "
            f"{r['readmits']} re-admissions")
        if r["decodes"]:
            assert min(r["decodes"]) >= min(r["admits"]), (
                f"rid={rid}: decode at {min(r['decodes'])} precedes "
                f"first admit at {min(r['admits'])}")
        assert r["preempts"] == r["requeues"] + r["aborts"], (
            f"rid={rid}: {r['preempts']} preempts vs {r['requeues']} "
            f"requeues + {r['aborts']} aborts")
        assert r["finishes"] <= 1, f"rid={rid}: finished twice"
        if r["finishes"] and not r["aborts"]:
            assert r["acquired"] == r["released"], (
                f"rid={rid}: {r['acquired']} blocks acquired vs "
                f"{r['released']} released")
    for fid, bal in flows.items():
        assert bal == 0, f"flow {fid!r}: unbalanced start/finish"
