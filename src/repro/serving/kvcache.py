"""Paged KV-cache subsystem: block allocator + device-side table helpers.

The serving analog of Ara2's memory-subsystem finding (bottleneck analysis:
memory organization, not raw FPU count, gates utilization): the dense slot
pool reserves ``cache_len`` KV positions per slot no matter how short the
request, so admission is bounded by worst-case reservation.  Paging (vLLM's
PagedAttention, Kwon et al. SOSP 2023) splits the KV cache into fixed-size
blocks drawn from one global pool:

* ``BlockAllocator`` - a host-side free list over ``n_blocks`` pool blocks.
  Block 0 is reserved as the *null block*: freed/idle decode slots point
  every block-table entry at it, so their stale one-token writes land in a
  scratch block instead of corrupting a live request's KV.

  The allocator is a first-class object that can be *shared*: a
  multi-replica cluster (``repro.serving.cluster``) constructs one pool and
  passes it to every ``ServeEngine`` replica, the serving analog of Ara2's
  multi-core clusters sharing one L2 - each core (replica) issues its own
  stream but draws from common memory.  Two features support sharing:

  - **per-owner accounting**: every live block is tagged with the owner id
    passed to ``alloc``/``alloc_n`` (a replica index), so the cluster can
    see which replica holds what (``live_by_owner``).
  - **pool-level reservations**: engines running ``admission="reserve"``
    promise worst-case blocks at admit time via ``reserve``/``unreserve``;
    the reservation count lives here (not per engine) so co-tenant engines
    see each other's promises and lazy growth can never fail.  Allocations
    that convert a standing promise into a live block pass
    ``from_reservation=True``; every *other* allocation (an atomic
    ``alloc_n``, an overcommit growth) gates on ``n_avail`` - the free
    blocks **not** spoken for - so it can never eat another request's
    promised blocks.  Engines running ``admission="overcommit"`` skip
    reservations; their lazy growth *can* find the pool empty, which
    surfaces as ``PoolPressure`` and is resolved by the cluster preempting
    a victim request.

* **refcounted sharing + prefix index** (prefix caching): a block may be
  held by several requests at once (``incref``/``refcount``); ``free``
  decrements and only a block whose last reference drops actually leaves
  the live set.  Full prompt-prefix blocks are *registered* under an
  exact chain key - ``(parent_key, tuple(span_token_ids))``, nested so a
  block's identity covers every token before it, with no integer-hash
  collisions by construction - and a later admission with the same
  prefix ``lookup``s resident blocks and re-references them instead of
  re-prefilling.  A registered block whose refcount drops to 0 is not
  returned to the free list immediately: it parks in a **cached** LRU
  set, still indexed (a future hit revives it via ``incref``) but also
  still *evictable* - ``alloc`` falls back to evicting the
  least-recently-used cached block once the raw free list is empty, so
  caching never shrinks the pool: ``n_free`` counts free + cached and
  the conservation invariant stays exact.  Because each replica writes
  its own device-side pool arrays (see ``repro.serving.cluster``), index
  entries are tagged with the *writer* owner and ``lookup`` only returns
  blocks whose bytes live where the reader can gather them.

* per-request **block tables** - ordered rows of block ids mapping logical
  KV positions ``[i * block_size, (i+1) * block_size)`` to pool blocks.
  Rows live in the device cache (``pcache["bt"]``) so the decode kernel can
  gather them; ownership/accounting lives here on the host.

The pool layout itself ((n_layers, n_blocks, n_kv_heads, block_size,
head_dim)) is built by the model family (``model.paged_cache_init``); this
module only manages block ownership and the layout-agnostic table/position
updates shared by every paged family (including ``pool_copy_block``, the
device-side block copy backing copy-on-write divergence).

**Conservation invariants** (asserted by the stateful allocator property
in ``tests/test_kvcache.py`` and after every run of the conformance
suite in ``tests/test_serving_props.py``): a block is never handed out
twice, never freed below refcount 0, never freed by a non-holder;
``n_live + n_free == capacity`` at all times (``n_free`` counting cached
blocks); ``sum(refcounts) >= n_live``; reservations never exceed
unreserved-free blocks; ``free`` is atomic (a rejected list mutates
nothing); and after any ``generate`` — including one aborted by an
exception — the pool drains to ``n_live == 0``, ``n_reserved == 0``,
``n_free == capacity``.

**Thread safety**: every public method and property takes the
allocator's internal re-entrant lock, so concurrent replicas (the
threaded cluster driver steps each replica in its own worker thread)
can alloc/free/register/lookup against the shared pool without torn
state; ``check_integrity`` holds the same lock, so it always sees a
consistent snapshot.  Compound check-then-act sequences (resolve prefix
hits, reserve, then apply the hits) are made atomic by holding
``allocator.lock`` across the whole sequence — the lock is re-entrant
precisely so callers can wrap multiple calls.  Asserted by the
multi-threaded stress variant of the allocator rule machine in
``tests/test_kvcache.py``.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any

import jax.numpy as jnp

from .telemetry import NULL_TRACER

NULL_BLOCK = 0


class PoolPressure(MemoryError):
    """Lazy block growth found the (shared) pool empty under overcommit
    admission.  Carries the requesting owner and decode slot so a cluster
    scheduler can pick a preemption victim and retry the step."""

    def __init__(self, owner, slot: int):
        super().__init__(
            f"KV block pool exhausted under overcommit (owner={owner}, "
            f"slot={slot}): preempt a request or grow the pool")
        self.owner = owner
        self.slot = slot


def blocks_needed(n_positions: int, block_size: int) -> int:
    """Number of KV blocks covering ``n_positions`` cache positions."""
    return -(-n_positions // block_size)


def prefix_chain_keys(tokens, block_size: int) -> list:
    """Exact chain keys for every *full* ``block_size`` span of ``tokens``.

    Key ``i`` is ``(key_{i-1}, tuple(span_i))`` (root parent ``None``), so
    a block's key covers every token before it and equal keys imply equal
    full prefixes - token-exact, no integer-hash collision class (the
    historic prefix-cache corruption bug category)."""
    keys = []
    parent = None
    for i in range(len(tokens) // block_size):
        span = tuple(tokens[i * block_size:(i + 1) * block_size])
        parent = (parent, span)
        keys.append(parent)
    return keys


@dataclasses.dataclass(frozen=True)
class BlockPoolStats:
    n_blocks: int                  # pool size including the null block
    block_size: int
    capacity: int                  # allocatable blocks (null excluded)
    n_live: int
    n_free: int                    # free-list + cached (reusable) blocks
    peak_live: int
    utilization: float             # n_live / capacity
    peak_utilization: float        # peak_live / capacity
    n_reserved: int = 0            # worst-case blocks promised, not yet live
    n_cached: int = 0              # refcount-0 blocks still prefix-indexed


class BlockAllocator:
    """Free-list allocator over a global pool of fixed-size KV blocks.

    Freed blocks are reused LIFO (most recently freed first), which keeps
    hot pool regions hot; refcount-0 *registered* blocks are evicted
    LRU-last, only after the raw free list is empty.  Block 0
    (``NULL_BLOCK``) is never handed out.
    """

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 2:
            raise ValueError(
                f"n_blocks={n_blocks}: need at least the null block plus "
                "one allocatable block")
        if block_size < 1:
            raise ValueError(f"block_size={block_size} must be >= 1")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._policy: str | None = None
        self._tracer = NULL_TRACER
        # Re-entrant: public methods call each other (alloc -> unreserve,
        # alloc_n -> alloc, take_cached -> unreserve) and engines hold it
        # across compound admission sequences.
        self._lock = threading.RLock()
        self.reset()

    @property
    def lock(self) -> threading.RLock:
        """The allocator's re-entrant lock.  Hold it across compound
        check-then-act sequences (e.g. prefix-hit resolution followed by
        ``reserve`` + ``take_cached``/``incref``) that must be atomic
        against co-tenant engines in other threads."""
        return self._lock

    # -- telemetry -----------------------------------------------------

    def set_tracer(self, tracer) -> None:
        """Attach a tracer: pool mutations emit a ``blocks`` counter
        series (free / live / reserved / cached — the free-block
        watermark timeline in the trace) and reservation instants on the
        ``pool`` track.  Host-side only; no device state involved."""
        self._tracer = tracer if tracer is not None else NULL_TRACER

    def _trace_watermark(self) -> None:
        if self._tracer.enabled:
            self._tracer.counter("pool", "blocks", free=len(self._free),
                                 live=self.n_live, reserved=self._reserved,
                                 cached=self.n_cached)

    def claim_policy(self, policy: str) -> None:
        """Engines sharing this pool must agree on one admission policy:
        overcommit growth spends free blocks without consulting
        reservations, so mixing it with a reserve-admission co-tenant
        would break the latter's growth-never-fails guarantee."""
        if self._policy is None:
            self._policy = policy
        elif self._policy != policy:
            raise ValueError(
                f"pool already serves admission={self._policy!r} engines; "
                f"a co-tenant requested admission={policy!r} (mixed "
                "policies would let overcommit growth eat reserved blocks)")

    # -- lifecycle -----------------------------------------------------

    def reset(self) -> None:
        """Return every block to the free list and clear stats + index."""
        with self._lock:
            # stacked so that pop() hands out 1, 2, 3, ... on a fresh pool
            self._free = list(range(self.n_blocks - 1, 0, -1))
            self._live: dict[int, list] = {}  # block id -> owners (multiset)
            self._reserved = 0
            self._peak = 0
            # prefix cache: chain key -> (block id, writer owner); block id
            # -> chain key (reverse, for eviction/unregister); LRU of
            # refcount-0 registered blocks (oldest-first, still allocatable)
            self._index: dict[Any, tuple[int, Any]] = {}
            self._key_of: dict[int, Any] = {}
            self._cached: collections.OrderedDict[int, None] = \
                collections.OrderedDict()

    def reset_peak(self) -> None:
        with self._lock:
            self._peak = len(self._live)

    # -- alloc / free --------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.n_blocks - 1

    @property
    def n_free(self) -> int:
        """Blocks allocatable right now: the raw free list plus cached
        (refcount-0, still prefix-indexed) blocks, which ``alloc`` evicts
        LRU-first once the free list is empty."""
        with self._lock:
            return len(self._free) + len(self._cached)

    @property
    def n_live(self) -> int:
        with self._lock:
            return len(self._live)

    @property
    def n_reserved(self) -> int:
        with self._lock:
            return self._reserved

    @property
    def n_cached(self) -> int:
        """Refcount-0 blocks kept for prefix reuse (subset of n_free)."""
        with self._lock:
            return len(self._cached)

    @property
    def n_avail(self) -> int:
        """Free blocks not spoken for by a standing reservation."""
        with self._lock:
            return self.n_free - self._reserved

    def _pop_free(self) -> int:
        """Take a block off the raw free list, evicting the LRU cached
        block (dropping its index entry) when the list is empty."""
        if self._free:
            return self._free.pop()
        blk, _ = self._cached.popitem(last=False)   # LRU-first eviction
        self._drop_index(blk)
        return blk

    def _drop_index(self, blk: int) -> None:
        key = self._key_of.pop(blk, None)
        if key is not None and self._index.get(key, (None,))[0] == blk:
            del self._index[key]

    def alloc(self, owner=0, *, from_reservation: bool = False) -> int:
        """Hand out one block.  ``from_reservation=True`` converts one of
        the caller's standing promises into a live block (``reserve`` was
        already charged, so the promised block is free by construction and
        the reservation count drops here); otherwise the allocation gates
        on ``n_avail`` so it can never eat a block promised to another
        request's lazy growth."""
        with self._lock:
            budget = self.n_free if from_reservation else self.n_avail
            if budget < 1:
                raise MemoryError(
                    f"KV block pool exhausted ({self.capacity} blocks of "
                    f"{self.block_size} positions: {self.n_live} live, "
                    f"{self._reserved} reserved)")
            blk = self._pop_free()
            self._live[blk] = [owner]
            self._peak = max(self._peak, len(self._live))
            if from_reservation:
                self.unreserve(1)
            self._trace_watermark()
            return blk

    def alloc_n(self, n: int, owner=0, *,
                from_reservation: bool = False) -> list[int]:
        """Allocate ``n`` blocks atomically (all or nothing).  Gates on
        ``n_avail`` unless the caller holds a matching reservation - an
        atomic admission must not consume blocks promised to another
        request's growth."""
        with self._lock:
            budget = self.n_free if from_reservation else self.n_avail
            if n > budget:
                raise MemoryError(
                    f"KV block pool exhausted: need {n} blocks, "
                    f"{budget}/{self.capacity} "
                    + ("free" if from_reservation else "unreserved-free"))
            return [self.alloc(owner, from_reservation=from_reservation)
                    for _ in range(n)]

    def free(self, blocks, owner=0) -> None:
        """Drop one reference per listed block, atomically: the whole list
        is validated against the live set (and this owner's holdings)
        before any mutation, so a rejected call leaves the pool exactly as
        it was.  A block whose last reference drops returns to the free
        list - unless it is prefix-registered, in which case it parks in
        the cached LRU (still indexed, still allocatable)."""
        blocks = list(blocks)
        with self._lock:
            pending = collections.Counter()
            for blk in blocks:
                if blk not in self._live:
                    raise ValueError(
                        f"free of block {blk} which is not live "
                        "(double free or foreign id)")
                pending[blk] += 1
                if pending[blk] > self._live[blk].count(owner):
                    raise ValueError(
                        f"free of block {blk} by owner {owner!r} which "
                        f"holds {self._live[blk].count(owner)} of its "
                        f"{len(self._live[blk])} references")
            for blk in blocks:
                self._live[blk].remove(owner)
                if self._live[blk]:
                    continue                  # other holders remain
                del self._live[blk]
                if blk in self._key_of:
                    self._cached[blk] = None  # newest = evicted last
                    self._cached.move_to_end(blk)
                else:
                    self._free.append(blk)
            self._trace_watermark()

    # -- prefix index (refcounted content-addressed blocks) ------------

    def incref(self, blk: int, owner=0) -> None:
        """Add a reference to an already-live block (prefix-cache hit on a
        block another request currently holds)."""
        with self._lock:
            if blk not in self._live:
                raise ValueError(f"incref of block {blk} which is not live")
            self._live[blk].append(owner)

    def refcount(self, blk: int) -> int:
        with self._lock:
            return len(self._live.get(blk, ()))

    def is_cached(self, blk: int) -> bool:
        """True for a refcount-0 block parked in the cached LRU (a hit on
        it must ``take_cached`` rather than ``incref``)."""
        with self._lock:
            return blk in self._cached

    def register(self, key, blk: int, owner=0) -> None:
        """Publish live block ``blk`` under prefix chain ``key``.  Last
        writer wins (two requests racing the same cold prefix both write
        correct bytes; the index just points at one of them).  The entry
        is tagged with the *writer* owner: device pools are per-replica,
        so only readers whose gathers address the writer's pool may hit."""
        with self._lock:
            if blk not in self._live:
                raise ValueError(
                    f"register of block {blk} which is not live")
            prev = self._index.get(key)
            if prev is not None and prev[0] != blk:
                self._key_of.pop(prev[0], None)
                if prev[0] in self._cached:   # superseded cached copy:
                    self._cached.pop(prev[0])  # plain free block again
                    self._free.append(prev[0])
            stale = self._key_of.get(blk)
            if stale is not None and stale != key:
                # block re-used for different content (COW rewrite of a
                # refcount-1 block): the old chain entry is dead
                if self._index.get(stale, (None,))[0] == blk:
                    del self._index[stale]
            self._index[key] = (blk, owner)
            self._key_of[blk] = key

    def lookup(self, key, owner=0):
        """Resolve a prefix chain key to a resident block id, or None.
        Only blocks *written* by ``owner`` hit (per-replica device pools);
        a cached (refcount-0) block is a valid hit - ``incref`` it via
        ``take_cached`` to revive it."""
        with self._lock:
            ent = self._index.get(key)
            if ent is None or ent[1] != owner:
                return None
            blk = ent[0]
            if blk in self._live or blk in self._cached:
                return blk
            return None

    def take_cached(self, blk: int, owner=0, *,
                    from_reservation: bool = False) -> None:
        """Revive a cached (refcount-0) block into the live set for a hit.
        Costs one allocatable block, so it follows ``alloc``'s gating:
        reservation-backed revivals spend a promise, others spend
        ``n_avail``."""
        with self._lock:
            if blk not in self._cached:
                raise ValueError(f"block {blk} is not cached")
            budget = self.n_free if from_reservation else self.n_avail
            if budget < 1:
                raise MemoryError(
                    f"KV block pool exhausted ({self.capacity} blocks: "
                    f"{self.n_live} live, {self._reserved} reserved)")
            self._cached.pop(blk)
            self._live[blk] = [owner]
            self._peak = max(self._peak, len(self._live))
            if from_reservation:
                self.unreserve(1)
            self._trace_watermark()

    def flush_index(self, owner=None) -> int:
        """Drop prefix-index entries (all, or one writer's) - cached
        blocks return to the raw free list, live blocks stay live but
        stop being discoverable.  Used when a writer's device pool is
        torn down (its registered bytes no longer exist).  Returns the
        number of entries dropped."""
        with self._lock:
            keys = [k for k, (_, o) in self._index.items()
                    if owner is None or o == owner]
            for k in keys:
                blk, _ = self._index.pop(k)
                self._key_of.pop(blk, None)
                if blk in self._cached:
                    self._cached.pop(blk)
                    self._free.append(blk)
            return len(keys)

    def check_integrity(self) -> None:
        """Assert the conservation invariants (test hook; cheap enough for
        per-step use in property suites).  Holds the allocator lock, so
        the snapshot it checks is consistent even mid-traffic."""
        with self._lock:
            assert not (set(self._live) & set(self._free)), "live∩free"
            assert not (set(self._live) & set(self._cached)), "live∩cached"
            assert not (set(self._cached) & set(self._free)), "cached∩free"
            assert NULL_BLOCK not in self._live and \
                NULL_BLOCK not in self._free and \
                NULL_BLOCK not in self._cached, "null block escaped"
            total = len(self._live) + len(self._free) + len(self._cached)
            assert total == self.capacity, \
                f"conservation: {len(self._live)} live + " \
                f"{len(self._free)} free + {len(self._cached)} cached " \
                f"!= {self.capacity}"
            assert all(len(o) >= 1 for o in self._live.values()), \
                "live block with no holders"
            assert sum(len(o) for o in self._live.values()) >= \
                self.n_live, "sum(refs) < n_live"
            assert self._reserved >= 0
            assert self._reserved <= self.n_free, \
                "reservations exceed free"
            for blk in self._cached:
                assert blk in self._key_of, \
                    "cached block lost its index key"
            for key, (blk, _) in self._index.items():
                assert self._key_of.get(blk) == key, \
                    "index/key_of mismatch"
            if self._tracer.enabled:
                self._tracer.instant("pool", "integrity_ok",
                                     live=self.n_live, free=self.n_free,
                                     reserved=self._reserved)

    # -- reservations (worst-case admission promises) ------------------

    def reserve(self, n: int) -> None:
        """Promise ``n`` free blocks to an admitted request's future lazy
        growth.  Pool-level so co-tenant engines see each other's promises;
        ``n_avail`` is what admission may still spend."""
        with self._lock:
            if n > self.n_avail:
                raise MemoryError(
                    f"cannot reserve {n} blocks: only {self.n_avail} of "
                    f"{self.capacity} unreserved-free")
            self._reserved += n
            if self._tracer.enabled and n:
                self._tracer.instant("pool", "reserve", n=n)
            self._trace_watermark()

    def unreserve(self, n: int) -> None:
        """Release reservations (a promised block became live, or its
        request finished / was preempted)."""
        with self._lock:
            if n > self._reserved:
                raise ValueError(
                    f"unreserve({n}) exceeds standing reservations "
                    f"({self._reserved})")
            self._reserved -= n
            if n:
                self._trace_watermark()

    # -- accounting ----------------------------------------------------

    def live_by_owner(self) -> dict:
        """Live block-reference counts per owner (a cluster's per-replica
        view; a shared block counts once per holding owner)."""
        with self._lock:
            counts: dict = {}
            for owners in self._live.values():
                for owner in owners:
                    counts[owner] = counts.get(owner, 0) + 1
            return counts

    def owner_of(self, blk: int):
        """First holder of a live block (sole holder for unshared blocks)."""
        with self._lock:
            return self._live[blk][0]

    def stats(self) -> BlockPoolStats:
        with self._lock:
            cap = self.capacity
            return BlockPoolStats(
                self.n_blocks, self.block_size, cap, self.n_live,
                self.n_free, self._peak, self.n_live / cap,
                self._peak / cap, n_reserved=self._reserved,
                n_cached=self.n_cached)


# ---------------------------------------------------------------------------
# Device-side block-table updates (layout-agnostic, jittable).
#
# Every paged cache dict carries "bt" (B, max_blocks) int32 block tables and
# "pos" (B,) int32 per-slot positions next to its model-specific pools.
# ---------------------------------------------------------------------------

def bt_set_entry(pcache: dict, slot, idx, block) -> dict:
    """Install pool block ``block`` as entry ``idx`` of ``slot``'s block
    table (lazy growth: called when a slot's position enters a new block)."""
    return dict(pcache, bt=pcache["bt"].at[slot, idx].set(
        jnp.asarray(block, jnp.int32)))


def slot_release(pcache: dict, slot) -> dict:
    """Point a freed slot's whole block table at the null block and reset
    its position, so idle decode writes land in scratch, never in a block
    that has been recycled to another request."""
    return dict(
        pcache,
        bt=pcache["bt"].at[slot].set(jnp.int32(NULL_BLOCK)),
        pos=pcache["pos"].at[slot].set(jnp.int32(0)))


def pool_copy_block(pcache: dict, dst, src) -> dict:
    """Copy pool block ``src``'s bytes into block ``dst`` in every pool
    leaf (copy-on-write divergence: a request sharing a prefix block that
    must now write into it gets a private copy first).  Pool leaves are
    ``(..., n_blocks, ...)`` with the block axis at position 1
    (``(L, n_blocks, Hkv, bs, hd)``); the host-side ``bt``/``pos`` tables
    are left untouched."""
    dst = jnp.asarray(dst, jnp.int32)
    src = jnp.asarray(src, jnp.int32)
    out = dict(pcache)
    for name, leaf in pcache.items():
        if name in ("bt", "pos"):
            continue
        out[name] = leaf.at[:, dst].set(leaf[:, src])
    return out
