"""Paged KV-cache subsystem: block allocator + device-side table helpers.

The serving analog of Ara2's memory-subsystem finding (bottleneck analysis:
memory organization, not raw FPU count, gates utilization): the dense slot
pool reserves ``cache_len`` KV positions per slot no matter how short the
request, so admission is bounded by worst-case reservation.  Paging (vLLM's
PagedAttention, Kwon et al. SOSP 2023) splits the KV cache into fixed-size
blocks drawn from one global pool:

* ``BlockAllocator`` - a host-side free list over ``n_blocks`` pool blocks.
  Block 0 is reserved as the *null block*: freed/idle decode slots point
  every block-table entry at it, so their stale one-token writes land in a
  scratch block instead of corrupting a live request's KV.

  The allocator is a first-class object that can be *shared*: a
  multi-replica cluster (``repro.serving.cluster``) constructs one pool and
  passes it to every ``ServeEngine`` replica, the serving analog of Ara2's
  multi-core clusters sharing one L2 - each core (replica) issues its own
  stream but draws from common memory.  Two features support sharing:

  - **per-owner accounting**: every live block is tagged with the owner id
    passed to ``alloc``/``alloc_n`` (a replica index), so the cluster can
    see which replica holds what (``live_by_owner``).
  - **pool-level reservations**: engines running ``admission="reserve"``
    promise worst-case blocks at admit time via ``reserve``/``unreserve``;
    the reservation count lives here (not per engine) so co-tenant engines
    see each other's promises and lazy growth can never fail.  Engines
    running ``admission="overcommit"`` skip reservations; their lazy
    growth *can* find the pool empty, which surfaces as ``PoolPressure``
    and is resolved by the cluster preempting a victim request.
* per-request **block tables** - ordered rows of block ids mapping logical
  KV positions ``[i * block_size, (i+1) * block_size)`` to pool blocks.
  Rows live in the device cache (``pcache["bt"]``) so the decode kernel can
  gather them; ownership/accounting lives here on the host.

The pool layout itself ((n_layers, n_blocks, n_kv_heads, block_size,
head_dim)) is built by the model family (``model.paged_cache_init``); this
module only manages block ownership and the layout-agnostic table/position
updates shared by every paged family.

**Conservation invariants** (asserted by the stateful allocator property
in ``tests/test_kvcache.py`` and after every run of the conformance
suite in ``tests/test_serving_props.py``): a block is never handed out
twice, never freed twice, never freed by a non-owner path; ``n_live +
n_free == capacity`` at all times; reservations never exceed free
blocks; and after any ``generate`` — including one aborted by an
exception — the pool drains to ``n_live == 0``, ``n_reserved == 0``,
``n_free == capacity``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

NULL_BLOCK = 0


class PoolPressure(MemoryError):
    """Lazy block growth found the (shared) pool empty under overcommit
    admission.  Carries the requesting owner and decode slot so a cluster
    scheduler can pick a preemption victim and retry the step."""

    def __init__(self, owner, slot: int):
        super().__init__(
            f"KV block pool exhausted under overcommit (owner={owner}, "
            f"slot={slot}): preempt a request or grow the pool")
        self.owner = owner
        self.slot = slot


def blocks_needed(n_positions: int, block_size: int) -> int:
    """Number of KV blocks covering ``n_positions`` cache positions."""
    return -(-n_positions // block_size)


@dataclasses.dataclass(frozen=True)
class BlockPoolStats:
    n_blocks: int                  # pool size including the null block
    block_size: int
    capacity: int                  # allocatable blocks (null excluded)
    n_live: int
    n_free: int
    peak_live: int
    utilization: float             # n_live / capacity
    peak_utilization: float        # peak_live / capacity
    n_reserved: int = 0            # worst-case blocks promised, not yet live


class BlockAllocator:
    """Free-list allocator over a global pool of fixed-size KV blocks.

    Freed blocks are reused LIFO (most recently freed first), which keeps
    hot pool regions hot.  Block 0 (``NULL_BLOCK``) is never handed out.
    """

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 2:
            raise ValueError(
                f"n_blocks={n_blocks}: need at least the null block plus "
                "one allocatable block")
        if block_size < 1:
            raise ValueError(f"block_size={block_size} must be >= 1")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._policy: str | None = None
        self.reset()

    def claim_policy(self, policy: str) -> None:
        """Engines sharing this pool must agree on one admission policy:
        overcommit growth spends free blocks without consulting
        reservations, so mixing it with a reserve-admission co-tenant
        would break the latter's growth-never-fails guarantee."""
        if self._policy is None:
            self._policy = policy
        elif self._policy != policy:
            raise ValueError(
                f"pool already serves admission={self._policy!r} engines; "
                f"a co-tenant requested admission={policy!r} (mixed "
                "policies would let overcommit growth eat reserved blocks)")

    # -- lifecycle -----------------------------------------------------

    def reset(self) -> None:
        """Return every block to the free list and clear stats."""
        # stacked so that pop() hands out 1, 2, 3, ... on a fresh pool
        self._free = list(range(self.n_blocks - 1, 0, -1))
        self._live: dict[int, Any] = {}      # block id -> owner
        self._reserved = 0
        self._peak = 0

    def reset_peak(self) -> None:
        self._peak = len(self._live)

    # -- alloc / free --------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.n_blocks - 1

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return len(self._live)

    @property
    def n_reserved(self) -> int:
        return self._reserved

    @property
    def n_avail(self) -> int:
        """Free blocks not spoken for by a standing reservation."""
        return len(self._free) - self._reserved

    def alloc(self, owner=0) -> int:
        if not self._free:
            raise MemoryError(
                f"KV block pool exhausted ({self.capacity} blocks of "
                f"{self.block_size} positions, all live)")
        blk = self._free.pop()
        self._live[blk] = owner
        self._peak = max(self._peak, len(self._live))
        return blk

    def alloc_n(self, n: int, owner=0) -> list[int]:
        """Allocate ``n`` blocks atomically (all or nothing)."""
        if n > self.n_free:
            raise MemoryError(
                f"KV block pool exhausted: need {n} blocks, "
                f"{self.n_free}/{self.capacity} free")
        return [self.alloc(owner) for _ in range(n)]

    def free(self, blocks) -> None:
        for blk in blocks:
            if blk not in self._live:
                raise ValueError(
                    f"free of block {blk} which is not live "
                    "(double free or foreign id)")
            del self._live[blk]
            self._free.append(blk)

    # -- reservations (worst-case admission promises) ------------------

    def reserve(self, n: int) -> None:
        """Promise ``n`` free blocks to an admitted request's future lazy
        growth.  Pool-level so co-tenant engines see each other's promises;
        ``n_avail`` is what admission may still spend."""
        if n > self.n_avail:
            raise MemoryError(
                f"cannot reserve {n} blocks: only {self.n_avail} of "
                f"{self.capacity} unreserved-free")
        self._reserved += n

    def unreserve(self, n: int) -> None:
        """Release reservations (a promised block became live, or its
        request finished / was preempted)."""
        if n > self._reserved:
            raise ValueError(
                f"unreserve({n}) exceeds standing reservations "
                f"({self._reserved})")
        self._reserved -= n

    # -- accounting ----------------------------------------------------

    def live_by_owner(self) -> dict:
        """Live block counts per owner (a cluster's per-replica view)."""
        counts: dict = {}
        for owner in self._live.values():
            counts[owner] = counts.get(owner, 0) + 1
        return counts

    def owner_of(self, blk: int):
        return self._live[blk]

    def stats(self) -> BlockPoolStats:
        cap = self.capacity
        return BlockPoolStats(
            self.n_blocks, self.block_size, cap, self.n_live, self.n_free,
            self._peak, self.n_live / cap, self._peak / cap,
            n_reserved=self._reserved)


# ---------------------------------------------------------------------------
# Device-side block-table updates (layout-agnostic, jittable).
#
# Every paged cache dict carries "bt" (B, max_blocks) int32 block tables and
# "pos" (B,) int32 per-slot positions next to its model-specific pools.
# ---------------------------------------------------------------------------

def bt_set_entry(pcache: dict, slot, idx, block) -> dict:
    """Install pool block ``block`` as entry ``idx`` of ``slot``'s block
    table (lazy growth: called when a slot's position enters a new block)."""
    return dict(pcache, bt=pcache["bt"].at[slot, idx].set(
        jnp.asarray(block, jnp.int32)))


def slot_release(pcache: dict, slot) -> dict:
    """Point a freed slot's whole block table at the null block and reset
    its position, so idle decode writes land in scratch, never in a block
    that has been recycled to another request."""
    return dict(
        pcache,
        bt=pcache["bt"].at[slot].set(jnp.int32(NULL_BLOCK)),
        pos=pcache["pos"].at[slot].set(jnp.int32(0)))
