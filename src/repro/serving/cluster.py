"""Multi-replica serving cluster: router + shared KV pool + preemption.

Ara2's headline multi-core result (§7) is that eight 2-lane cores with 16
FPUs beat one 16-lane core with the same 16 FPUs by >3x on matmul: many
small issue streams overcome the single scalar-core issue-rate bound, and
the cluster scales physically because each core only talks to its slice
of the memory system.  The serving analog built here:

* ``ClusterEngine`` owns N ``ServeEngine`` replicas, each with
  ``max_batch = total_slots / N`` decode slots - the "cores".  A wide
  single engine pays its full fixed-shape decode width on every step even
  when most slots idle (the drain tail of short-request traffic); narrow
  replicas strand at most their own width, and a fully drained replica
  skips its step entirely.

* a **router** admits from one global FIFO queue into whichever replica
  has a free slot.  Three policies pick among candidates:

  - ``round_robin``   - cyclic over replicas (the paper's static
                        interleaving of elements over cores),
  - ``least_loaded``  - fewest busy slots,
  - ``shortest_queue`` - smallest outstanding decode-token backlog.

  Greedy outputs are policy-independent (asserted in tests): placement
  only changes *when* a request runs, and sampling streams are keyed by
  request id, not slot or replica (see ``engine._sample_rows``).

* the transformer families serve through the **paged** KV layout; the
  scan families (ssm/hybrid/encdec) have no block pool to share — their
  per-slot recurrent state is O(1) per request — so their replicas run
  the **dense slot layout** (``kv_layout`` resolves per family).  The
  router, global FIFO queue, and occupancy accounting are identical;
  only the pool-pressure/preemption machinery below is paged-specific
  (a dense scan replica can never raise ``PoolPressure``: its state
  budget is fixed at admission).

* paged replicas draw KV blocks from one **shared**
  :class:`repro.serving.kvcache.BlockAllocator` (per-owner accounting:
  owner = replica index) under ``admission="overcommit"``: a request is
  admitted as soon as its *prefill* fits, instead of reserving its worst
  case.  When a replica's lazy block growth then finds the pool empty
  (:class:`repro.serving.kvcache.PoolPressure`), the cluster **preempts**
  the lowest-priority / youngest-admitted request anywhere in the
  cluster: its blocks are freed, and it is re-queued carrying its
  generated prefix (``Request.done``) for re-prefill on a later
  admission.  Request-id-keyed sampling makes the resumed stream
  identical to the uninterrupted one, so preemption is invisible in the
  output (asserted in tests/benches).  Chunked paged prefill makes a
  *mid-prefill* request preemptable too (its ``done`` is simply
  unchanged), and pressure raised by a long prompt's own prefill growth
  resolves the same way.  ``admission="reserve"`` is also accepted for a
  no-preemption cluster.

* with ``prefix_cache=True`` (paged only) every replica registers and
  resolves prompt-prefix blocks in the **shared** allocator-level index.
  Entries are tagged with the writer replica and ``lookup`` is scoped to
  it: block *accounting* is pool-global but the device-side pool arrays
  are per-replica (see the device-memory caveat below), so only the
  replica whose pool holds the bytes may admit by reference.  Preempting
  a request that holds shared blocks only drops its references — a block
  another request reads stays live, and a registered block whose last
  reference drops parks in the allocator's cached LRU instead of being
  recycled, so the victim's prefix survives for its re-admission.

* requeued victims re-enter behind a **preemption hysteresis**
  (``preempt_hysteresis`` scheduler rounds, waived when the cluster is
  idle): the raw FIFO requeue could re-admit a victim straight back into
  the pressure that evicted it, thrashing admit → preempt → admit with a
  wasted re-prefill per bounce.

Device-memory caveat: each replica's device-side block pool is sized to
the full shared pool so that the shared allocator's block ids index it
directly; block *accounting* (capacity, admission, preemption, the
benchmark's fixed 512-position budget) is pool-global, but the device
arrays themselves are per-replica.  Folding them into one donated buffer
threaded through the replicas' jitted decode steps is an open item.
"""
from __future__ import annotations

import collections

import jax

from ..models.model import Model
from .engine import EngineStats, Request, Result, ServeEngine
from .kvcache import BlockAllocator, PoolPressure, blocks_needed
from .telemetry import MONOTONIC, NULL_TRACER, MetricsRegistry

ROUTER_POLICIES = ("round_robin", "least_loaded", "shortest_queue")


class ClusterEngine:
    """N narrow ServeEngine replicas behind a router, sharing one KV block
    pool.

    replicas / total_slots: replica count and the summed slot budget
    (``total_slots % replicas == 0``); each replica runs the continuous
    scheduler.  kv_layout: "auto" (paged when the family has paged hooks,
    else the dense slot layout — the scan families), "paged", or "dense".
    block_size / n_blocks size the shared pool (paged only) - n_blocks
    defaults to the dense footprint of the whole cluster
    (total_slots * cache_len positions) plus the null block.
    router: one of ``ROUTER_POLICIES``.  admission: "overcommit"
    (default; preemption resolves pool pressure) or "reserve"; ignored
    by the dense layout, which has no pool to overcommit.  ``pool`` is
    the shared BlockAllocator (None for dense clusters).

    preempt_hysteresis: anti-thrash guard — a preempted request is not
    re-admissible before ``k`` scheduler rounds have passed since its
    eviction.  The raw FIFO requeue (k=0) can bounce a victim straight
    back into the same pressure (admit → grow → preempt → re-admit …),
    paying a re-prefill per bounce while the pool stays saturated;
    holding it out a few rounds lets the survivors that caused the
    pressure retire some tokens (or finish) first.  Head-of-line blocking
    is preserved — nothing skips past a cooling-down victim — and the
    hysteresis is waived while the whole cluster is idle (an empty
    cluster cannot be under pressure, so waiting would only stall).

    prefix_cache: paged clusters only — replicas admit shared prompt
    prefixes by referencing resident pool blocks through the shared
    allocator's writer-scoped index (see the module doc; rejected for
    dense scan-family clusters).

    ``generate`` mirrors ``ServeEngine.generate``; ``last_stats`` is the
    cluster-level aggregate (mode="cluster", ``router_policy`` set,
    percentiles from the *merged* replica histograms — exact cluster-wide
    p50/p99 TTFT+TPOT, not an average of replica means) and
    ``replica_stats`` keeps the per-replica EngineStats.

    tracer / clock / track: telemetry (``docs/observability.md``).  The
    tracer cascades to every replica (track ``replica{i}``) and to the
    shared pool; router decisions, victim picks, requeues, and
    hysteresis waits land on the ``cluster`` track.
    """

    def __init__(self, model: Model, params, *, replicas: int = 2,
                 total_slots: int = 8, cache_len: int = 1024,
                 router: str = "round_robin", kv_layout: str = "auto",
                 block_size: int = 16,
                 n_blocks: int | None = None,
                 bucket: str | int | None = None,
                 extra_inputs: dict | None = None,
                 admission: str = "overcommit",
                 preempt_hysteresis: int = 4,
                 prefix_cache: bool = False,
                 tracer=None, clock=None, attribution=None):
        if router not in ROUTER_POLICIES:
            raise ValueError(f"router={router!r}: pick one of "
                             f"{ROUTER_POLICIES}")
        if replicas < 1 or total_slots % replicas:
            raise ValueError(
                f"total_slots={total_slots} must be a positive multiple of "
                f"replicas={replicas}")
        if kv_layout not in ("auto", "paged", "dense"):
            raise ValueError(f"kv_layout={kv_layout!r}")
        if kv_layout == "auto":
            kv_layout = "paged" if model.decode_paged is not None else "dense"
        if kv_layout == "paged" and model.decode_paged is None:
            raise ValueError(
                f"kv_layout='paged': family {model.cfg.family!r} has no "
                "paged cache hooks (scan families cluster on the dense "
                "slot layout)")
        if preempt_hysteresis < 0:
            raise ValueError(
                f"preempt_hysteresis={preempt_hysteresis} must be >= 0")
        self.router = router
        self.total_slots = total_slots
        self.kv_layout = kv_layout
        self.preempt_hysteresis = preempt_hysteresis
        if kv_layout == "paged":
            if n_blocks is None:
                n_blocks = (total_slots * blocks_needed(cache_len,
                                                        block_size) + 1)
            self.pool = BlockAllocator(n_blocks, block_size)
            layout_kw = dict(kv_layout="paged", allocator=self.pool,
                             admission=admission,
                             prefix_cache=prefix_cache)
        else:
            # scan families: per-slot recurrent state, no shared pool, no
            # pool pressure - admission is bounded by free slots alone
            if prefix_cache:
                raise ValueError(
                    "prefix_cache=True requires the paged layout (dense "
                    "scan-family replicas have no blocks to share)")
            self.pool = None
            layout_kw = dict(kv_layout="dense")
        self.engines = [
            ServeEngine(model, params, max_batch=total_slots // replicas,
                        cache_len=cache_len, extra_inputs=extra_inputs,
                        mode="continuous", bucket=bucket, owner=i,
                        track=f"replica{i}", **layout_kw)
            for i in range(replicas)]
        self.last_stats: EngineStats | None = None
        self.replica_stats: list[EngineStats] = []
        self.last_metrics = MetricsRegistry()
        self._rr = 0
        self.tracer = NULL_TRACER
        self.clock = MONOTONIC
        if tracer is not None:
            self.set_tracer(tracer)
        if clock is not None:
            self.clock = clock
            for e in self.engines:
                e.clock = clock
        if attribution is not None:
            self.set_attributor(attribution)

    def set_attributor(self, attributor) -> None:
        """Attach (or detach, with None) one utilization attributor to
        every replica (``ServeEngine.set_attributor``).  Sharing one
        attributor is deliberate: its cost memo is shape-keyed, so N
        identical replicas lower each executable once, and the rollup
        needs no extra plumbing — replicas record raw ``attr_*`` samples
        into their own registries and ``_aggregate``'s lossless merge
        derives the cluster-wide utilization from the union."""
        for e in self.engines:
            e.set_attributor(attributor)

    def set_tracer(self, tracer) -> None:
        """Attach (or detach, with None) a tracer, cascading it to every
        replica and the shared pool; the cluster adopts an enabled
        tracer's clock (like ``ServeEngine.set_tracer``)."""
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if self.tracer.enabled:
            self.clock = self.tracer.clock
        for e in self.engines:
            e.set_tracer(tracer)
        if self.pool is not None:
            self.pool.set_tracer(self.tracer)

    # ------------------------------------------------------------------
    # Routing.
    # ------------------------------------------------------------------

    def _route(self, r: Request) -> ServeEngine | None:
        """Pick the replica to admit ``r`` into, or None when no replica
        has both a free slot and pool headroom (head-of-line blocking:
        admission is strictly FIFO over the global queue)."""
        cands = [e for e in self.engines
                 if e.session_free_slot() is not None
                 and e.session_can_admit(r)]
        if not cands:
            return None
        if self.router == "round_robin":
            n = len(self.engines)
            for off in range(n):
                e = self.engines[(self._rr + off) % n]
                if e in cands:
                    self._rr = (self._rr + off + 1) % n
                    return e
        if self.router == "least_loaded":
            return min(cands, key=lambda e: (e.session_active,
                                             self.engines.index(e)))
        return min(cands, key=lambda e: (e.session_backlog(),
                                         self.engines.index(e)))

    # ------------------------------------------------------------------
    # Preemption.
    # ------------------------------------------------------------------

    def _pick_victim(self, excl_engine, excl_slot):
        """Lowest-priority, then youngest-admitted live request anywhere in
        the cluster, excluding the slot whose growth raised the pressure
        (preempting the requester would just redo its own work)."""
        cands = []
        for e in self.engines:
            if e.session_active == 0:
                continue
            for i, s in e.session_slots():
                if e is excl_engine and i == excl_slot:
                    continue
                cands.append((s.req.priority, -s.admit_seq, e, i))
        if not cands:
            return None
        _, _, e, i = min(cands, key=lambda c: (c[0], c[1]))
        return e, i

    def _requeue(self, queue, item) -> None:
        """Insert a preempted request back into the global queue keeping it
        sorted by submission order (a preempted request was admitted before
        anything still queued, so FIFO fairness puts it first - but two
        preemptions can land out of order).  Queue items are
        (seq, order, request, ready_round); seq is unique, so the sort
        never compares requests."""
        queue.append(item)
        ordered = sorted(queue, key=lambda it: it[0])
        queue.clear()
        queue.extend(ordered)

    # ------------------------------------------------------------------
    # Public API.
    # ------------------------------------------------------------------

    def generate(self, requests: list[Request], key=None) -> list[Result]:
        key = key if key is not None else jax.random.key(0)
        requests = list(requests)
        todo = [(i, r) for i, r in enumerate(requests)
                if r.max_new_tokens - len(r.done) > 0]
        results = [Result(r.rid, list(r.done)) for r in requests]
        if not todo:
            self.replica_stats = []
            self.last_stats = self._aggregate(0.0, [])
            return results
        for _, r in todo:
            self.engines[0].check_request(r)
        if self.pool is not None:
            self.pool.reset_peak()
        # every replica gets the same base key: sampling streams are keyed
        # by request id, so placement cannot change sampled outputs
        for e in self.engines:
            e.begin_session(key)
        tr = self.tracer
        t_start = self.clock.now()
        # cluster-level metrics (merged with the replicas' at aggregate):
        # scheduler-loop counters the engines cannot see
        cm = MetricsRegistry()
        queue = collections.deque(
            (seq, order, r, 0, t_start) for seq, (order, r)
            in enumerate(todo))
        out: list[Result | None] = [None] * len(todo)
        admit_seq = 0
        rounds = 0
        try:
            while queue or any(e.session_active for e in self.engines):
                # route: FIFO head into a replica with slot + pool headroom
                while queue:
                    seq, order, r, ready, enq_t = queue[0]
                    if ready > rounds and any(e.session_active
                                              for e in self.engines):
                        # anti-thrash hysteresis: a fresh victim waits out
                        # its cool-down (head-of-line: nothing skips it);
                        # waived when the cluster is idle — no live request
                        # can be causing pressure then
                        cm.counter("hysteresis_wait_rounds").inc()
                        if tr.enabled:
                            tr.instant("cluster", "hysteresis_wait",
                                       rid=r.rid,
                                       rounds_left=ready - rounds)
                        break
                    e = self._route(r)
                    if e is None:
                        break
                    queue.popleft()
                    if tr.enabled:
                        tr.instant("cluster", "route", rid=r.rid,
                                   replica=e.owner, policy=self.router)
                    # paged admission always defers to session_step, but a
                    # dense (scan-family) admission runs the prefill here
                    # and can satisfy a 1-token budget on the spot
                    res = e.session_admit(r, tag=seq, extra_row=order,
                                          admit_seq=admit_seq,
                                          enqueue_t=enq_t)
                    if res is not None:
                        out[seq] = res
                    admit_seq += 1
                stepped = False
                for e in self.engines:
                    if e.session_active == 0:
                        continue      # a drained replica skips its step
                    while True:
                        try:
                            finished = e.session_step()
                            break
                        except PoolPressure as p:
                            victim = self._pick_victim(e, p.slot)
                            if victim is None:
                                raise   # nothing to evict: genuine OOM
                            ve, vi = victim
                            tag, r2 = ve.session_preempt(vi)
                            if tr.enabled:
                                tr.instant("cluster", "preempt_pick",
                                           rid=r2.rid, replica=ve.owner,
                                           slot=vi,
                                           pressured=e.owner)
                                tr.instant("cluster", "requeue",
                                           rid=r2.rid,
                                           ready_round=(
                                               rounds
                                               + self.preempt_hysteresis))
                            self._requeue(
                                queue,
                                (tag, todo[tag][0], r2,
                                 rounds + self.preempt_hysteresis,
                                 self.clock.now()))
                    for tag, res in finished:
                        out[tag] = res
                    stepped = True
                rounds += 1
                if not stepped and queue:
                    # no replica active and the head cannot be admitted:
                    # impossible once check_request passed (an idle cluster
                    # has every block free and waives the hysteresis), so
                    # fail loudly over spinning
                    raise RuntimeError(
                        "cluster stalled with a non-empty queue")
        except BaseException:
            for e in self.engines:
                e.session_abort()
            raise
        wall = self.clock.now() - t_start
        self.replica_stats = [e.end_session() for e in self.engines]
        self.last_stats = self._aggregate(
            wall, [e.last_metrics for e in self.engines], cm)
        for (i, _), res in zip(todo, out):
            results[i] = res
        return results

    def _aggregate(self, wall: float, registries,
                   extra: MetricsRegistry | None = None) -> EngineStats:
        """Cluster-level EngineStats: *merge* the replicas' metric
        registries (counters add; busy/offered slot-steps give the
        capacity-weighted occupancy — a drained replica stops offering
        lanes) and derive the view from the merged registry, so the
        TTFT/TPOT percentiles are exact over the union of every
        replica's raw samples rather than an average of replica means.
        ``extra`` carries the cluster's own scheduler-loop counters."""
        merged = MetricsRegistry()
        for m in registries:
            merged.merge(m)
        if extra is not None:
            merged.merge(extra)
        self.last_metrics = merged
        reps = self.replica_stats
        return EngineStats.from_registry(
            merged, mode="cluster", wall_s=wall,
            kv_layout=self.kv_layout,
            prefill_compiles=sum(s.prefill_compiles for s in reps),
            block_util_peak=(self.pool.stats().peak_utilization
                             if self.pool is not None else 0.0),
            router_policy=self.router)
