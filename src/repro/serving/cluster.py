"""Multi-replica serving cluster: router + shared KV pool + preemption.

Ara2's headline multi-core result (§7) is that eight 2-lane cores with 16
FPUs beat one 16-lane core with the same 16 FPUs by >3x on matmul: many
small issue streams overcome the single scalar-core issue-rate bound, and
the cluster scales physically because each core only talks to its slice
of the memory system.  The serving analog built here:

* ``ClusterEngine`` owns N ``ServeEngine`` replicas, each with
  ``max_batch = total_slots / N`` decode slots - the "cores".  A wide
  single engine pays its full fixed-shape decode width on every step even
  when most slots idle (the drain tail of short-request traffic); narrow
  replicas strand at most their own width, and a fully drained replica
  skips its step entirely.

* a **router** admits from one global FIFO queue into whichever replica
  has a free slot.  Three policies pick among candidates:

  - ``round_robin``   - cyclic over replicas (the paper's static
                        interleaving of elements over cores),
  - ``least_loaded``  - fewest busy slots,
  - ``shortest_queue`` - smallest outstanding decode-token backlog.

  Greedy outputs are policy-independent (asserted in tests): placement
  only changes *when* a request runs, and sampling streams are keyed by
  request id, not slot or replica (see ``engine._sample_rows``).

* the transformer families serve through the **paged** KV layout; the
  scan families (ssm/hybrid/encdec) have no block pool to share — their
  per-slot recurrent state is O(1) per request — so their replicas run
  the **dense slot layout** (``kv_layout`` resolves per family).  The
  router, global FIFO queue, and occupancy accounting are identical;
  only the pool-pressure/preemption machinery below is paged-specific
  (a dense scan replica can never raise ``PoolPressure``: its state
  budget is fixed at admission).

* paged replicas draw KV blocks from one **shared**
  :class:`repro.serving.kvcache.BlockAllocator` (per-owner accounting:
  owner = replica index) under ``admission="overcommit"``: a request is
  admitted as soon as its *prefill* fits, instead of reserving its worst
  case.  When a replica's lazy block growth then finds the pool empty
  (:class:`repro.serving.kvcache.PoolPressure`), the cluster **preempts**
  the lowest-priority / youngest-admitted request anywhere in the
  cluster: its blocks are freed, and it is re-queued carrying its
  generated prefix (``Request.done``) for re-prefill on a later
  admission.  Request-id-keyed sampling makes the resumed stream
  identical to the uninterrupted one, so preemption is invisible in the
  output (asserted in tests/benches).  Chunked paged prefill makes a
  *mid-prefill* request preemptable too (its ``done`` is simply
  unchanged), and pressure raised by a long prompt's own prefill growth
  resolves the same way.  ``admission="reserve"`` is also accepted for a
  no-preemption cluster.

* with ``prefix_cache=True`` (paged only) every replica registers and
  resolves prompt-prefix blocks in the **shared** allocator-level index.
  Entries are tagged with the writer replica and ``lookup`` is scoped to
  it: block *accounting* is pool-global but the device-side pool arrays
  are per-replica (see the device-memory caveat below), so only the
  replica whose pool holds the bytes may admit by reference.  Preempting
  a request that holds shared blocks only drops its references — a block
  another request reads stays live, and a registered block whose last
  reference drops parks in the allocator's cached LRU instead of being
  recycled, so the victim's prefix survives for its re-admission.

* requeued victims re-enter behind a **preemption hysteresis**
  (``preempt_hysteresis`` scheduler rounds, waived when the cluster is
  idle): the raw FIFO requeue could re-admit a victim straight back into
  the pressure that evicted it, thrashing admit → preempt → admit with a
  wasted re-prefill per bounce.

* two **drivers** execute the schedule.  ``driver="sequential"``
  (default) steps the replicas round-robin in one Python loop — fully
  deterministic, the reference the conformance suite gates on.
  ``driver="threaded"`` runs each replica in its own worker thread:
  JAX dispatch releases the GIL, so N independent ``session_step``
  launches overlap — the serving twin of the paper's N concurrent
  issue streams (§7: eight 2-lane cores beat one 16-lane core because
  issue is parallel).  A coordinator (the calling thread) owns the
  global FIFO queue and all routing/preemption decisions; workers own
  *all* session mutation on their replica (thread affinity — see
  ``engine.py``'s session-API notes) and talk to the coordinator over
  per-replica command queues + one shared event queue.  ``PoolPressure``
  is surfaced to the coordinator as an event (victim picking needs a
  consistent cluster view) and resolved by a targeted preempt command;
  the pressured worker blocks until the coordinator confirms the blocks
  are freed.  Because sampling is request-id-keyed, the two drivers are
  **byte-identical** (asserted across the conformance matrix) — only
  wall-clock and timing telemetry differ.

Device-memory caveat: each replica's device-side block pool is sized to
the full shared pool so that the shared allocator's block ids index it
directly; block *accounting* (capacity, admission, preemption, the
benchmark's fixed 512-position budget) is pool-global, but the device
arrays themselves are per-replica.  Folding them into one donated buffer
threaded through the replicas' jitted decode steps is an open item.
"""
from __future__ import annotations

import collections
import queue as queue_mod
import threading

import jax

from ..models.model import Model
from .engine import (EngineStats, Request, Result, ServeEngine,
                     _stream_events)
from .kvcache import BlockAllocator, PoolPressure, blocks_needed
from .slo import make_policy, slo_budget_s
from .telemetry import MONOTONIC, NULL_TRACER, MetricsRegistry

ROUTER_POLICIES = ("round_robin", "least_loaded", "shortest_queue")
DRIVERS = ("sequential", "threaded")

#: Coordinator-side guard against a wedged worker: no worker event for
#: this long means a protocol bug (a healthy step, even a first-call
#: compile, lands well inside it) - fail loudly instead of hanging CI.
_EVENT_TIMEOUT_S = 300.0


class ClusterEngine:
    """N narrow ServeEngine replicas behind a router, sharing one KV block
    pool.

    replicas / total_slots: replica count and the summed slot budget
    (``total_slots % replicas == 0``); each replica runs the continuous
    scheduler.  kv_layout: "auto" (paged when the family has paged hooks,
    else the dense slot layout — the scan families), "paged", or "dense".
    block_size / n_blocks size the shared pool (paged only) - n_blocks
    defaults to the dense footprint of the whole cluster
    (total_slots * cache_len positions) plus the null block.
    router: one of ``ROUTER_POLICIES``.  admission: "overcommit"
    (default; preemption resolves pool pressure) or "reserve"; ignored
    by the dense layout, which has no pool to overcommit.  ``pool`` is
    the shared BlockAllocator (None for dense clusters).

    driver: one of ``DRIVERS`` — "sequential" (default) steps replicas
    in one deterministic loop; "threaded" overlaps them on worker
    threads (module doc).  Tokens are byte-identical either way;
    ``generate``/``stream`` take a per-call override.  Under the
    threaded driver ``preempt_hysteresis`` counts *cluster-wide step
    completions* rather than scheduler rounds — with N active replicas
    the cool-down elapses ~N× faster in wall terms, which preserves its
    anti-thrash intent (the survivors retire work meanwhile) without a
    cross-thread round barrier.

    preempt_hysteresis: anti-thrash guard — a preempted request is not
    re-admissible before ``k`` scheduler rounds have passed since its
    eviction.  The raw FIFO requeue (k=0) can bounce a victim straight
    back into the same pressure (admit → grow → preempt → re-admit …),
    paying a re-prefill per bounce while the pool stays saturated;
    holding it out a few rounds lets the survivors that caused the
    pressure retire some tokens (or finish) first.  Head-of-line blocking
    is preserved — nothing skips past a cooling-down victim — and the
    hysteresis is waived while the whole cluster is idle (an empty
    cluster cannot be under pressure, so waiting would only stall).

    policy: scheduling policy name from ``repro.serving.slo.POLICIES``
    (or a ``SchedPolicy`` instance), threaded to every replica.  fifo
    (default) is byte-for-byte the legacy scheduler; priority/edf
    reorder admission; slo_adaptive adds slack-aware routing,
    deadline-protected victim picks, and the starvation pressure
    signal: when a ready queued request's remaining TTFT slack falls
    inside ``slo_guard_ms`` and no replica has a free slot, the cluster
    preempts one *unprotected* victim — the only pressure a dense
    scan-family replica (no block pool, no ``PoolPressure``) can feel.
    With no budgets set every policy degenerates to FIFO order and
    tokens are byte-identical (request-keyed sampling).

    prefix_cache: paged clusters only — replicas admit shared prompt
    prefixes by referencing resident pool blocks through the shared
    allocator's writer-scoped index (see the module doc; rejected for
    dense scan-family clusters).

    ``generate`` mirrors ``ServeEngine.generate``; ``last_stats`` is the
    cluster-level aggregate (mode="cluster", ``router_policy`` set,
    percentiles from the *merged* replica histograms — exact cluster-wide
    p50/p99 TTFT+TPOT, not an average of replica means) and
    ``replica_stats`` keeps the per-replica EngineStats.

    tracer / clock / track: telemetry (``docs/observability.md``).  The
    tracer cascades to every replica (track ``replica{i}``) and to the
    shared pool; router decisions, victim picks, requeues, and
    hysteresis waits land on the ``cluster`` track.
    """

    def __init__(self, model: Model, params, *, replicas: int = 2,
                 total_slots: int = 8, cache_len: int = 1024,
                 router: str = "round_robin", kv_layout: str = "auto",
                 block_size: int = 16,
                 n_blocks: int | None = None,
                 bucket: str | int | None = None,
                 extra_inputs: dict | None = None,
                 admission: str = "overcommit",
                 preempt_hysteresis: int = 4,
                 prefix_cache: bool = False,
                 driver: str = "sequential",
                 policy="fifo", slo_guard_ms: float = 50.0,
                 tracer=None, clock=None, attribution=None):
        if router not in ROUTER_POLICIES:
            raise ValueError(f"router={router!r}: pick one of "
                             f"{ROUTER_POLICIES}")
        if driver not in DRIVERS:
            raise ValueError(f"driver={driver!r}: pick one of {DRIVERS}")
        if replicas < 1 or total_slots % replicas:
            raise ValueError(
                f"total_slots={total_slots} must be a positive multiple of "
                f"replicas={replicas}")
        if kv_layout not in ("auto", "paged", "dense"):
            raise ValueError(f"kv_layout={kv_layout!r}")
        if kv_layout == "auto":
            kv_layout = "paged" if model.decode_paged is not None else "dense"
        if kv_layout == "paged" and model.decode_paged is None:
            raise ValueError(
                f"kv_layout='paged': family {model.cfg.family!r} has no "
                "paged cache hooks (scan families cluster on the dense "
                "slot layout)")
        if preempt_hysteresis < 0:
            raise ValueError(
                f"preempt_hysteresis={preempt_hysteresis} must be >= 0")
        if slo_guard_ms < 0:
            raise ValueError(f"slo_guard_ms={slo_guard_ms} must be >= 0")
        self.router = router
        self.driver = driver
        self.total_slots = total_slots
        self.kv_layout = kv_layout
        self.preempt_hysteresis = preempt_hysteresis
        self.policy = make_policy(policy)
        self.slo_guard_ms = slo_guard_ms
        if kv_layout == "paged":
            if n_blocks is None:
                n_blocks = (total_slots * blocks_needed(cache_len,
                                                        block_size) + 1)
            self.pool = BlockAllocator(n_blocks, block_size)
            layout_kw = dict(kv_layout="paged", allocator=self.pool,
                             admission=admission,
                             prefix_cache=prefix_cache)
        else:
            # scan families: per-slot recurrent state, no shared pool, no
            # pool pressure - admission is bounded by free slots alone
            if prefix_cache:
                raise ValueError(
                    "prefix_cache=True requires the paged layout (dense "
                    "scan-family replicas have no blocks to share)")
            self.pool = None
            layout_kw = dict(kv_layout="dense")
        self.engines = [
            ServeEngine(model, params, max_batch=total_slots // replicas,
                        cache_len=cache_len, extra_inputs=extra_inputs,
                        mode="continuous", bucket=bucket, owner=i,
                        track=f"replica{i}", policy=self.policy,
                        **layout_kw)
            for i in range(replicas)]
        self.last_stats: EngineStats | None = None
        self.replica_stats: list[EngineStats] = []
        self.last_metrics = MetricsRegistry()
        self._rr = 0
        self.tracer = NULL_TRACER
        self.clock = MONOTONIC
        if tracer is not None:
            self.set_tracer(tracer)
        if clock is not None:
            self.clock = clock
            for e in self.engines:
                e.clock = clock
        if attribution is not None:
            self.set_attributor(attribution)

    def set_attributor(self, attributor) -> None:
        """Attach (or detach, with None) one utilization attributor to
        every replica (``ServeEngine.set_attributor``).  Sharing one
        attributor is deliberate: its cost memo is shape-keyed, so N
        identical replicas lower each executable once, and the rollup
        needs no extra plumbing — replicas record raw ``attr_*`` samples
        into their own registries and ``_aggregate``'s lossless merge
        derives the cluster-wide utilization from the union."""
        for e in self.engines:
            e.set_attributor(attributor)

    def set_tracer(self, tracer) -> None:
        """Attach (or detach, with None) a tracer, cascading it to every
        replica and the shared pool; the cluster adopts an enabled
        tracer's clock (like ``ServeEngine.set_tracer``)."""
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if self.tracer.enabled:
            self.clock = self.tracer.clock
        for e in self.engines:
            e.set_tracer(tracer)
        if self.pool is not None:
            self.pool.set_tracer(self.tracer)

    # ------------------------------------------------------------------
    # Routing.
    # ------------------------------------------------------------------

    def _route(self, r: Request) -> ServeEngine | None:
        """Pick the replica to admit ``r`` into, or None when no replica
        has both a free slot and pool headroom.  A slack-routing policy
        (``slo_adaptive``) sends *budgeted* requests to the emptiest
        replica regardless of the configured router — the shortest path
        to their first token — while best-effort traffic keeps the
        configured policy (so with no budgets routing is untouched)."""
        cands = [e for e in self.engines
                 if e.session_free_slot() is not None
                 and e.session_can_admit(r)]
        if not cands:
            return None
        if self.policy.slack_routes and slo_budget_s(r) is not None:
            return min(cands, key=lambda e: (e.session_active,
                                             self.engines.index(e)))
        if self.router == "round_robin":
            n = len(self.engines)
            for off in range(n):
                e = self.engines[(self._rr + off) % n]
                if e in cands:
                    self._rr = (self._rr + off + 1) % n
                    return e
            raise AssertionError(
                "round_robin scanned every replica without hitting a "
                "candidate despite cands being non-empty - routing "
                "invariant broken")
        if self.router == "least_loaded":
            return min(cands, key=lambda e: (e.session_active,
                                             self.engines.index(e)))
        return min(cands, key=lambda e: (e.session_backlog(),
                                         self.engines.index(e)))

    # ------------------------------------------------------------------
    # Preemption.
    # ------------------------------------------------------------------

    def _pick_victim(self, excl_engine, excl_slot, now: float | None = None,
                     require_unprotected: bool = False):
        """Policy-ranked victim pick across the cluster (the minimum
        ``victim_key`` anywhere), excluding the slot whose growth raised
        the pressure (preempting the requester would just redo its own
        work).  The fifo/priority/edf key is the classic
        (priority, -admit_seq) — lowest priority, then youngest
        admission; ``slo_adaptive`` prepends the protection flag, so a
        budgeted request inside its deadline slack is never chosen while
        any unprotected (best-effort or already-late) victim exists.
        ``require_unprotected=True`` (the starvation-pressure path)
        additionally refuses protected victims outright — evicting one
        in-slack request to rescue another would just trade misses."""
        now = self.clock.now() if now is None else now
        cands = []
        for e in self.engines:
            if e.session_active == 0:
                continue
            for key, i in e.session_victims(now):
                if e is excl_engine and i == excl_slot:
                    continue
                if require_unprotected and key[0]:
                    continue
                cands.append((key, e.owner, e, i))
        if not cands:
            return None
        _, _, e, i = min(cands, key=lambda c: (c[0], c[1]))
        return e, i

    def _requeue(self, queue, item) -> None:
        """Insert a preempted request back into the global queue keeping it
        sorted by submission order (a preempted request was admitted before
        anything still queued, so FIFO fairness puts it first - but two
        preemptions can land out of order).  Queue items are
        (seq, order, request, ready_round, enqueue_t); seq is unique, so
        the sort never compares requests."""
        queue.append(item)
        ordered = sorted(queue, key=lambda it: it[0])
        queue.clear()
        queue.extend(ordered)

    def _hysteresis_wait(self, cm, tr, r, rounds_left: int) -> None:
        cm.counter("hysteresis_wait_rounds").inc()
        if tr.enabled:
            tr.instant("cluster", "hysteresis_wait", rid=r.rid,
                       rounds_left=rounds_left)

    def _next_item(self, queue, rounds: int, busy: bool, cm, tr):
        """Pick the next admission candidate from the global queue,
        honoring the preemption hysteresis.  The fifo policy keeps
        today's head-of-line semantics byte-for-byte: the head blocks,
        nothing skips past a cooling-down victim, and the cool-down is
        waived when the cluster is idle.  Reordering policies take the
        minimum ``order_key`` over *ready* items instead — a cooling
        victim no longer blocks urgent traffic behind it (that is the
        point of deadline scheduling), but it still cannot be admitted
        before its own cool-down (unless the cluster is idle).  Returns
        the queue item, or None when nothing is admissible now."""
        if not queue:
            return None
        if not self.policy.reorders:
            item = queue[0]
            if item[3] > rounds and busy:
                self._hysteresis_wait(cm, tr, item[2], item[3] - rounds)
                return None
            return item
        eligible = [it for it in queue if it[3] <= rounds]
        if not eligible:
            if busy:
                self._hysteresis_wait(cm, tr, queue[0][2],
                                      queue[0][3] - rounds)
                return None
            eligible = list(queue)   # idle cluster waives the cool-down
        now = self.clock.now()
        return min(eligible, key=lambda it: self.policy.order_key(
            it[0], it[2], it[4], now))

    def _starving_item(self, queue, rounds: int):
        """The dense/scan pressure signal (``slo_adaptive`` only): the
        most urgent *ready* queued request whose remaining TTFT slack
        has fallen inside the guard band.  The caller pairs this
        queue-age half with the slot-count half (no replica can admit
        it) before preempting — replicas without a block pool never
        raise ``PoolPressure``, so this is the only pressure they can
        feel."""
        if not (self.policy.preempts_on_starvation and queue):
            return None
        eligible = [it for it in queue if it[3] <= rounds]
        if not eligible:
            return None
        now = self.clock.now()
        item = min(eligible, key=lambda it: self.policy.order_key(
            it[0], it[2], it[4], now))
        if not self.policy.starving(item[2], item[4], now,
                                    self.slo_guard_ms / 1e3):
            return None
        return item

    # ------------------------------------------------------------------
    # Public API.
    # ------------------------------------------------------------------

    def generate(self, requests: list[Request], key=None, on_token=None,
                 driver: str | None = None) -> list[Result]:
        """Run ``requests`` to completion across the cluster.

        ``on_token`` streams every sampled token as a
        :class:`repro.serving.engine.TokenEvent` the moment it exists;
        under the threaded driver the callback fires from replica worker
        threads (possibly concurrently), so it must be thread-safe —
        ``stream`` wraps this in a queue for the common case.  ``driver``
        overrides the constructor's choice for this call ("sequential"
        or "threaded"); tokens are byte-identical either way."""
        driver = self.driver if driver is None else driver
        if driver not in DRIVERS:
            raise ValueError(f"driver={driver!r}: pick one of {DRIVERS}")
        key = key if key is not None else jax.random.key(0)
        requests = list(requests)
        todo = [(i, r) for i, r in enumerate(requests)
                if r.max_new_tokens - len(r.done) > 0]
        results = [Result(r.rid, list(r.done)) for r in requests]
        if not todo:
            self.replica_stats = []
            self.last_stats = self._aggregate(0.0, [])
            return results
        for _, r in todo:
            self.engines[0].check_request(r)
        if self.pool is not None:
            self.pool.reset_peak()
        # every replica gets the same base key: sampling streams are keyed
        # by request id, so placement cannot change sampled outputs
        for e in self.engines:
            e.begin_session(key, on_token)
        t_start = self.clock.now()
        # cluster-level metrics (merged with the replicas' at aggregate):
        # scheduler-loop counters the engines cannot see
        cm = MetricsRegistry()
        out: list[Result | None] = [None] * len(todo)
        try:
            if driver == "threaded":
                self._drive_threaded(todo, out, cm, t_start)
            else:
                self._drive_sequential(todo, out, cm, t_start)
        except BaseException:
            for e in self.engines:
                e.session_abort()
            raise
        wall = self.clock.now() - t_start
        self.replica_stats = [e.end_session() for e in self.engines]
        self.last_stats = self._aggregate(
            wall, [e.last_metrics for e in self.engines], cm)
        for (i, _), res in zip(todo, out):
            results[i] = res
        return results

    def stream(self, requests: list[Request], key=None,
               driver: str | None = None):
        """Streaming ``generate``: a generator yielding
        :class:`repro.serving.engine.TokenEvent` rows as replicas sample
        them.  Per-rid events arrive in index order; cross-request
        interleaving follows the schedule (and, under the threaded
        driver, thread timing).  The underlying ``generate`` runs on a
        background thread; exhaust the generator (or let an exception
        propagate) before reusing the engine."""
        return _stream_events(
            lambda cb: self.generate(requests, key=key, on_token=cb,
                                     driver=driver))

    # ------------------------------------------------------------------
    # Sequential driver: replicas stepped round-robin in one loop.
    # ------------------------------------------------------------------

    def _drive_sequential(self, todo, out, cm, t_start) -> None:
        tr = self.tracer
        queue = collections.deque(
            (seq, order, r, 0, t_start) for seq, (order, r)
            in enumerate(todo))
        admit_seq = 0
        rounds = 0
        while queue or any(e.session_active for e in self.engines):
            # route: the policy's next pick into a replica with slot +
            # pool headroom (fifo: the FIFO head, head-of-line blocking)
            while queue:
                busy = any(e.session_active for e in self.engines)
                item = self._next_item(queue, rounds, busy, cm, tr)
                if item is None:
                    break
                seq, order, r, ready, enq_t = item
                e = self._route(r)
                if e is None:
                    break
                queue.remove(item)
                if tr.enabled:
                    tr.instant("cluster", "route", rid=r.rid,
                               replica=e.owner, policy=self.router)
                # paged admission always defers to session_step, but a
                # dense (scan-family) admission runs the prefill here
                # and can satisfy a 1-token budget on the spot
                res = e.session_admit(r, tag=seq, extra_row=order,
                                      admit_seq=admit_seq,
                                      enqueue_t=enq_t)
                if res is not None:
                    out[seq] = res
                admit_seq += 1
            # starvation pressure (slo_adaptive): a ready queued request
            # is about to miss its TTFT deadline and no replica can take
            # it — preempt one unprotected victim so the next round's
            # admission pass can place it.  This is how dense/scan
            # replicas (no pool, no PoolPressure) feel pressure at all.
            stepped = False
            starving = self._starving_item(queue, rounds)
            # slot-count probe without _route: routing round_robin
            # advances self._rr even when the pick is discarded
            if starving is not None and not any(
                    e.session_free_slot() is not None
                    and e.session_can_admit(starving[2])
                    for e in self.engines):
                victim = self._pick_victim(None, None,
                                           require_unprotected=True)
                if victim is not None:
                    ve, vi = victim
                    tag, r2 = ve.session_preempt(vi)
                    cm.counter("slo_starve_preempts").inc()
                    ready_rnd = rounds + self.preempt_hysteresis
                    if tr.enabled:
                        tr.instant("cluster", "preempt_pick", rid=r2.rid,
                                   replica=ve.owner, slot=vi,
                                   starved=starving[2].rid)
                        tr.instant("cluster", "requeue", rid=r2.rid,
                                   ready_round=ready_rnd)
                    self._requeue(queue, (tag, todo[tag][0], r2,
                                          ready_rnd, self.clock.now()))
                    stepped = True   # progress: the freed slot admits
                    #                  the starving request next round
            for e in self.engines:
                if e.session_active == 0:
                    continue      # a drained replica skips its step
                while True:
                    try:
                        finished = e.session_step()
                        break
                    except PoolPressure as p:
                        victim = self._pick_victim(e, p.slot)
                        if victim is None:
                            raise   # nothing to evict: genuine OOM
                        ve, vi = victim
                        tag, r2 = ve.session_preempt(vi)
                        if tr.enabled:
                            tr.instant("cluster", "preempt_pick",
                                       rid=r2.rid, replica=ve.owner,
                                       slot=vi,
                                       pressured=e.owner)
                            tr.instant("cluster", "requeue",
                                       rid=r2.rid,
                                       ready_round=(
                                           rounds
                                           + self.preempt_hysteresis))
                        self._requeue(
                            queue,
                            (tag, todo[tag][0], r2,
                             rounds + self.preempt_hysteresis,
                             self.clock.now()))
                for tag, res in finished:
                    out[tag] = res
                stepped = True
            rounds += 1
            if not stepped and queue:
                # no replica active and the head cannot be admitted:
                # impossible once check_request passed (an idle cluster
                # has every block free and waives the hysteresis), so
                # fail loudly over spinning
                raise RuntimeError(
                    "cluster stalled with a non-empty queue")

    # ------------------------------------------------------------------
    # Threaded driver: one worker thread per replica + a coordinator.
    #
    # Protocol.  The coordinator (the calling thread) owns the global
    # FIFO queue, all routing decisions, and all victim picks; workers
    # own every session mutation on their replica (thread affinity).
    # Commands flow coordinator -> worker over per-replica inboxes:
    #
    #   ("admit", item, admit_seq)  admit the queue item
    #   ("preempt", rid)            evict rid if it is live here
    #   ("resume",)                 retry the step after a pressure stop
    #   ("stop",)                   drain and exit
    #
    # and events flow worker -> coordinator over one shared queue:
    #
    #   ("admitted", i, seq, rid, res)  admit done (res: dense instant
    #                                   finish)
    #   ("admit_retry", i, item, rid)   reserve lost a pool race
    #                                   (MemoryError) - requeue it
    #   ("step_done", i, finished, backlog)  one step retired
    #   ("pressure", i, slot, rid)      PoolPressure: worker now blocks
    #                                   on its inbox until "resume"
    #   ("preempted", i, tag, req)      a "preempt" hit - blocks freed
    #   ("preempt_miss", i, rid)        rid no longer live (finished in
    #                                   flight) - coordinator re-picks
    #   ("error", i, exc)               worker died; exc re-raises
    #   ("stopped", i)                  worker exited
    #
    # The coordinator tracks slots_used per replica itself (+1 on admit
    # dispatch, -1 on finish/instant-result/retry/preempt) so it never
    # over-admits no matter how far a worker lags; engine.session_* reads
    # from the coordinator are advisory only.  Pool races the tracking
    # cannot see resolve through the protocol: a lost reserve returns as
    # admit_retry, a lost block-grow as pressure -> coordinator-picked
    # preempt -> resume.  Pressures are serviced one preempt at a time
    # (outstanding_preempt) so each "preempted" event unambiguously
    # resolves the pressure at the head of the pending deque.
    # ------------------------------------------------------------------

    def _drive_threaded(self, todo, out, cm, t_start) -> None:
        tr = self.tracer
        n = len(self.engines)
        per_replica = self.total_slots // n
        events: queue_mod.SimpleQueue = queue_mod.SimpleQueue()
        inboxes = [queue_mod.SimpleQueue() for _ in range(n)]
        workers = [
            threading.Thread(target=self._replica_worker,
                             name=f"cluster-replica{i}",
                             args=(i, self.engines[i], inboxes[i], events),
                             daemon=True)
            for i in range(n)]
        queue = collections.deque(
            (seq, order, r, 0, t_start) for seq, (order, r)
            in enumerate(todo))
        slots_used = [0] * n      # admits dispatched minus retirements
        backlog = [0] * n         # advisory decode-token backlog
        # rid -> (replica, request, admit_seq, dispatch time): the
        # victim-pick view (the request + clock base feed the policy's
        # victim_key, e.g. slo_adaptive's deadline-slack protection)
        assignment: dict[int, tuple[int, Request, int, float]] = {}
        pending = collections.deque()   # unresolved (replica, slot, rid)
        state = {"admit_seq": 0, "inflight": 0, "rounds": 0, "done": 0,
                 "outstanding": None}
        # outstanding: (victim_rid, replica, kind) - kind "pressure"
        # (resolves pending[0] and resumes the blocked worker) or
        # "starve" (starvation preempt: nothing to resume)

        def victim_cands(now, exclude=(), unprotected_only=False):
            """Policy-ranked victim candidates over the coordinator's
            assignment view (min = preferred victim; ties by rid)."""
            return [(self.policy.victim_key(req, aseq, t0, now), rid, vi)
                    for rid, (vi, req, aseq, t0) in assignment.items()
                    if rid not in exclude
                    and not (unprotected_only
                             and self.policy.victim_key(req, aseq, t0,
                                                        now)[0])]

        def service_pressure():
            """Issue the next preempt for the pressure at the head of
            ``pending`` (one at a time: each "preempted" event then
            unambiguously resolves the head)."""
            if state["outstanding"] is not None or not pending:
                return
            req_i, _slot, grow_rid = pending[0]
            # never evict a request whose own growth is blocked waiting
            # on us - preempting a requester just redoes its own work
            growers = {p[2] for p in pending}
            cands = victim_cands(self.clock.now(), exclude=growers)
            if not cands:
                raise RuntimeError(
                    "pool pressure with nothing preemptible: genuine "
                    "OOM (check_request should have made this "
                    "impossible)")
            _, vrid, vi = min(cands)
            if tr.enabled:
                tr.instant("cluster", "preempt_pick", rid=vrid,
                           replica=vi, pressured=req_i)
            state["outstanding"] = (vrid, vi, "pressure")
            inboxes[vi].put(("preempt", vrid))

        def service_starvation():
            """The dense/scan pressure signal, threaded-driver side: a
            ready queued request inside its TTFT guard band that no
            replica can take triggers one preempt of an unprotected
            victim.  Deferred while any pool pressure is in flight —
            resolving real OOM comes first."""
            if (state["outstanding"] is not None or pending
                    or not assignment):
                return
            item = self._starving_item(queue, state["rounds"])
            # slot-count probe (not _route_threaded: round_robin would
            # advance self._rr on a discarded pick)
            if item is None or any(
                    slots_used[i] < per_replica
                    and e.session_can_admit(item[2])
                    for i, e in enumerate(self.engines)):
                return
            cands = victim_cands(self.clock.now(), unprotected_only=True)
            if not cands:
                return
            _, vrid, vi = min(cands)
            cm.counter("slo_starve_preempts").inc()
            if tr.enabled:
                tr.instant("cluster", "preempt_pick", rid=vrid,
                           replica=vi, starved=item[2].rid)
            state["outstanding"] = (vrid, vi, "starve")
            inboxes[vi].put(("preempt", vrid))

        def handle(ev):
            kind = ev[0]
            if kind == "admitted":
                _, i, seq, rid, res = ev
                state["inflight"] -= 1
                if res is not None:
                    # dense instant finish: the slot was never occupied
                    out[seq] = res
                    state["done"] += 1
                    slots_used[i] -= 1
                    assignment.pop(rid, None)
            elif kind == "admit_retry":
                _, i, item, rid = ev
                state["inflight"] -= 1
                slots_used[i] -= 1
                backlog[i] -= (item[2].max_new_tokens
                               - len(item[2].done))
                assignment.pop(rid, None)
                self._requeue(queue, item)
            elif kind == "step_done":
                _, i, finished, bk = ev
                state["rounds"] += 1
                backlog[i] = bk
                for tag, res in finished:
                    out[tag] = res
                    state["done"] += 1
                    slots_used[i] -= 1
                    assignment.pop(res.rid, None)
            elif kind == "pressure":
                _, i, slot, rid = ev
                pending.append((i, slot, rid))
            elif kind == "preempted":
                _, vi, tag, r2 = ev
                slots_used[vi] -= 1
                assignment.pop(r2.rid, None)
                _vrid, _vrepl, why = state["outstanding"]
                state["outstanding"] = None
                ready = state["rounds"] + self.preempt_hysteresis
                if tr.enabled:
                    tr.instant("cluster", "requeue", rid=r2.rid,
                               ready_round=ready)
                self._requeue(queue, (tag, todo[tag][0], r2, ready,
                                      self.clock.now()))
                if why == "pressure":
                    req_i, _slot, _rid = pending.popleft()
                    inboxes[req_i].put(("resume",))
                # "starve": no pressured worker is blocked - the freed
                # slot simply admits the starving request next pass
            elif kind == "preempt_miss":
                # the pick finished in flight; its step_done was queued
                # before this miss, so the re-pick sees it retired
                state["outstanding"] = None
            elif kind == "error":
                raise ev[2]
            # "stopped" outside shutdown: error event preceded it

        try:
            for w in workers:
                w.start()
            while state["done"] < len(todo):
                # admission dispatch (mirrors the sequential head loop)
                while queue:
                    busy = state["inflight"] > 0 or any(slots_used)
                    item = self._next_item(queue, state["rounds"], busy,
                                           cm, tr)
                    if item is None:
                        break
                    seq, order, r, ready, enq_t = item
                    i = self._route_threaded(r, slots_used, backlog,
                                             per_replica)
                    if i is None:
                        break
                    queue.remove(item)
                    if tr.enabled:
                        tr.instant("cluster", "route", rid=r.rid,
                                   replica=i, policy=self.router)
                    slots_used[i] += 1
                    backlog[i] += r.max_new_tokens - len(r.done)
                    state["inflight"] += 1
                    assignment[r.rid] = (i, r, state["admit_seq"],
                                         self.clock.now())
                    inboxes[i].put(("admit", (seq, order, r, ready,
                                              enq_t),
                                    state["admit_seq"]))
                    state["admit_seq"] += 1
                service_pressure()
                service_starvation()
                if (queue and state["inflight"] == 0
                        and not any(slots_used) and not pending):
                    raise RuntimeError(
                        "cluster stalled with a non-empty queue")
                try:
                    ev = events.get(timeout=_EVENT_TIMEOUT_S)
                except queue_mod.Empty:
                    raise RuntimeError(
                        f"threaded driver: no worker event for "
                        f"{_EVENT_TIMEOUT_S:.0f}s - worker wedged?")
                handle(ev)
                while True:
                    try:
                        handle(events.get_nowait())
                    except queue_mod.Empty:
                        break
        finally:
            for ib in inboxes:
                ib.put(("stop",))
            for w in workers:
                w.join(timeout=60.0)

    def _route_threaded(self, r: Request, slots_used, backlog,
                        per_replica: int) -> int | None:
        """Threaded-driver routing over the coordinator's *tracked* slot
        counts (a worker may not have processed a dispatched admit yet,
        so the engines' own slot views lag); ``session_can_admit`` is
        the pool-headroom test, safe to read cross-thread (the allocator
        is locked) and advisory - a lost race surfaces as admit_retry or
        pressure, never as corruption."""
        cands = [i for i, e in enumerate(self.engines)
                 if slots_used[i] < per_replica
                 and e.session_can_admit(r)]
        if not cands:
            return None
        if self.router == "round_robin":
            n = len(self.engines)
            for off in range(n):
                i = (self._rr + off) % n
                if i in cands:
                    self._rr = (self._rr + off + 1) % n
                    return i
            raise AssertionError(
                "round_robin scanned every replica without hitting a "
                "candidate despite cands being non-empty - routing "
                "invariant broken")
        if self.router == "least_loaded":
            return min(cands, key=lambda i: (slots_used[i], i))
        return min(cands, key=lambda i: (backlog[i], i))

    def _replica_worker(self, i: int, engine: ServeEngine, inbox,
                        events) -> None:
        """Worker loop: the single thread that mutates replica ``i``'s
        session.  Blocks on the inbox while drained; while live, drains
        commands then steps.  PoolPressure turns into a ("pressure")
        event plus an inbox wait — the coordinator preempts a victim
        somewhere (possibly here, handled in the wait loop) and sends
        ("resume",) once blocks are freed."""
        stop = False
        try:
            while not stop:
                cmds = []
                if engine.session_active == 0:
                    cmds.append(inbox.get())
                while True:
                    try:
                        cmds.append(inbox.get_nowait())
                    except queue_mod.Empty:
                        break
                for cmd in cmds:
                    stop = self._worker_cmd(engine, i, cmd, events) or stop
                if stop or engine.session_active == 0:
                    continue
                while True:
                    try:
                        finished = engine.session_step()
                        break
                    except PoolPressure as p:
                        rid = next((s.req.rid for j, s
                                    in engine.session_slots()
                                    if j == p.slot), -1)
                        events.put(("pressure", i, p.slot, rid))
                        while True:
                            cmd = inbox.get()
                            if cmd[0] == "resume":
                                break
                            stop = (self._worker_cmd(engine, i, cmd,
                                                     events) or stop)
                            if stop:
                                break
                        if stop:
                            break
                if stop:
                    continue
                events.put(("step_done", i, finished,
                            engine.session_backlog()))
        except BaseException as e:
            events.put(("error", i, e))
        finally:
            events.put(("stopped", i))

    def _worker_cmd(self, engine: ServeEngine, i: int, cmd,
                    events) -> bool:
        """Execute one coordinator command on the worker thread; returns
        True on ("stop",)."""
        kind = cmd[0]
        if kind == "stop":
            return True
        if kind == "admit":
            _, item, aseq = cmd
            seq, order, r, _ready, enq_t = item
            try:
                res = engine.session_admit(r, tag=seq, extra_row=order,
                                           admit_seq=aseq,
                                           enqueue_t=enq_t)
            except MemoryError:
                # reserve-mode admission lost a pool race between the
                # coordinator's headroom check and now; bounce it back
                events.put(("admit_retry", i, item, r.rid))
            else:
                events.put(("admitted", i, seq, r.rid, res))
        elif kind == "preempt":
            _, rid = cmd
            slot = next((j for j, s in engine.session_slots()
                         if s.req.rid == rid), None)
            if slot is None:
                events.put(("preempt_miss", i, rid))
            else:
                tag, r2 = engine.session_preempt(slot)
                events.put(("preempted", i, tag, r2))
        # ("resume",) outside a pressure wait: stale, ignore
        return False

    def _aggregate(self, wall: float, registries,
                   extra: MetricsRegistry | None = None) -> EngineStats:
        """Cluster-level EngineStats: *merge* the replicas' metric
        registries (counters add; busy/offered slot-steps give the
        capacity-weighted occupancy — a drained replica stops offering
        lanes) and derive the view from the merged registry, so the
        TTFT/TPOT percentiles are exact over the union of every
        replica's raw samples rather than an average of replica means.
        ``extra`` carries the cluster's own scheduler-loop counters."""
        merged = MetricsRegistry()
        for m in registries:
            merged.merge(m)
        if extra is not None:
            merged.merge(extra)
        self.last_metrics = merged
        reps = self.replica_stats
        return EngineStats.from_registry(
            merged, mode="cluster", wall_s=wall,
            kv_layout=self.kv_layout,
            prefill_compiles=sum(s.prefill_compiles for s in reps),
            block_util_peak=(self.pool.stats().peak_utilization
                             if self.pool is not None else 0.0),
            router_policy=self.router, sched_policy=self.policy.name)
