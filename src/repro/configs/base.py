"""Unified architecture config + the assigned input-shape sets."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    # attention
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    local_window: int | None = None      # sliding-window size for local layers
    local_per_global: int = 0            # gemma3: 5 local : 1 global
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_conv: int = 4
    # hybrid (zamba2): one shared attention block applied every k layers
    shared_attn_every: int = 0
    # xlstm
    slstm_every: int = 0
    # enc-dec
    n_enc_layers: int = 0
    # vlm stub
    n_patches: int = 0
    patch_embed_dim: int = 1024
    # misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    logit_softcap: float | None = None

    @property
    def head_dim_resolved(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Megatron-style vocab padding so embedding/lm-head shard evenly
        over the model axis (e.g. granite's 49155 -> 49408).  Logits beyond
        ``vocab_size`` are masked in the loss and sliced off at serving."""
        return -(-self.vocab_size // 256) * 256

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k cell (per-token cost independent of
        context length)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs decode (whisper via its decoder)

    def layer_window(self, layer_idx: int) -> int | None:
        """Sliding window for a given layer (gemma3 5:1 pattern)."""
        if not self.local_window:
            return None
        if self.local_per_global and \
                (layer_idx + 1) % (self.local_per_global + 1) == 0:
            return None  # global layer
        return self.local_window


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch x shape) dry-run cell."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full-attention arch: long_500k needs sub-quadratic " \
                      "attention (DESIGN.md shape-applicability)"
    return True, ""
