"""Architecture registry: ``get_config(arch)`` / ``smoke_config(arch)``.

Arch ids match the assignment table; hyphens/dots normalize to underscores.
"""
from importlib import import_module

from .base import SHAPES, ModelConfig, ShapeConfig, cell_applicable

ARCHS = {
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "zamba2-1.2b": "zamba2_1_2b",
    "qwen3-0.6b": "qwen3_0_6b",
    "yi-6b": "yi_6b",
    "gemma3-27b": "gemma3_27b",
    "qwen2.5-3b": "qwen2_5_3b",
    "xlstm-350m": "xlstm_350m",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "whisper-base": "whisper_base",
}


def _norm(name: str) -> str:
    if name in ARCHS:
        return ARCHS[name]
    alt = name.replace("-", "_").replace(".", "_")
    if alt in ARCHS.values():
        return alt
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")


def get_config(name: str) -> ModelConfig:
    return import_module(f".{_norm(name)}", __package__).CONFIG


def smoke_config(name: str) -> ModelConfig:
    return import_module(f".{_norm(name)}", __package__).SMOKE


def list_archs():
    return sorted(ARCHS)
