from .base import ModelConfig
# qwen3-moe-235b-a22b [moe]: 94L, 128 experts top-8, 1536/expert.
# [hf:Qwen/Qwen3-30B-A3B; hf]
CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1e6,
    n_experts=128, top_k=8,
)
SMOKE = ModelConfig(
    name="qwen3-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=32, vocab_size=256, head_dim=16, qk_norm=True,
    n_experts=8, top_k=2, capacity_factor=8.0,  # cf>=E/k: no drops
)
