from .base import ModelConfig
# gemma3-27b [dense]: 62L, 5:1 local(1024):global attention, 128k context.
# [hf:google/gemma-3-1b-pt; unverified]
CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
    d_ff=21504, vocab_size=262144, head_dim=128,
    qk_norm=True, rope_theta=1e6,
    local_window=1024, local_per_global=5,
    tie_embeddings=True, logit_softcap=30.0,
)
SMOKE = ModelConfig(
    name="gemma3-smoke", family="dense",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    qk_norm=True, local_window=16, local_per_global=5,
    logit_softcap=30.0,
)
