from .base import ModelConfig
# zamba2-1.2b [hybrid]: Mamba2 backbone + one shared attention block
# applied every 6 layers.  [arXiv:2411.15242; hf]
CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    shared_attn_every=6,
    # the shared block's attention at 500k decode uses a sliding-window
    # cache (DESIGN.md arch-applicability)
    local_window=4096,
)
SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, head_dim=16,
    ssm_state=8, ssm_head_dim=16, ssm_expand=2, ssm_groups=1,
    shared_attn_every=2, local_window=64,
)
