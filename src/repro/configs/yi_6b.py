from .base import ModelConfig
# yi-6b [dense]: llama-arch GQA 32/4.  [arXiv:2403.04652; hf]
CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab_size=64000, head_dim=128,
    rope_theta=5e6,
)
SMOKE = ModelConfig(
    name="yi-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=8,
)
