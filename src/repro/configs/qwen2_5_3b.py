from .base import ModelConfig
# qwen2.5-3b [dense]: GQA 16/2, QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]
CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    d_ff=11008, vocab_size=151936, head_dim=128,
    qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
)
SMOKE = ModelConfig(
    name="qwen2.5-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16, qkv_bias=True,
)
