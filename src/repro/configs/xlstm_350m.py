from .base import ModelConfig
# xlstm-350m [ssm]: mLSTM blocks with sLSTM every 6th layer.
# d_ff=0: no separate FFN (projection factor 2 inside the mLSTM block).
# [arXiv:2405.04517; unverified]
CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, slstm_every=6,
)
SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
    d_ff=0, vocab_size=256, slstm_every=2,
)
