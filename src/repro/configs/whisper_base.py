from .base import ModelConfig
# whisper-base [audio]: enc-dec, conv frontend stubbed (input_specs provides
# precomputed frame embeddings).  [arXiv:2212.04356; unverified]
CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51865, head_dim=64,
    rope_theta=0.0,  # learned/sinusoidal positions, no RoPE
)
SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, head_dim=16, rope_theta=0.0,
)
