from .base import ModelConfig
# granite-moe-1b-a400m [moe]: 24L, 32 experts top-8, 512/expert.
# [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab_size=49155, head_dim=64,
    n_experts=32, top_k=8, tie_embeddings=True,
)
SMOKE = ModelConfig(
    name="granite-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=32, vocab_size=256, head_dim=16,
    n_experts=4, top_k=2, capacity_factor=8.0,  # cf>=E/k: no drops
)
