from .base import ModelConfig
# phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP frontend (stubbed).
# [hf:microsoft/Phi-3-vision-128k-instruct; hf]
CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064, head_dim=96,
    rope_theta=10000.0, n_patches=576, patch_embed_dim=1024,
)
SMOKE = ModelConfig(
    name="phi-3-vision-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, head_dim=16,
    n_patches=8, patch_embed_dim=32,
)
