from .pipeline import MMapTokens, Prefetcher, SyntheticTokens
