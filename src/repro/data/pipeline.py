"""Data pipeline: synthetic + memory-mapped token streams, sequence packing,
background prefetch, and restart-determinism (batch i is a pure function of
(seed, i), so resuming from a checkpoint step replays the exact stream -
the fault-tolerance contract).
"""
from __future__ import annotations

import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticTokens:
    """Deterministic synthetic LM batches: Zipf-ish token draws; labels are
    next-token shifted.  Batch ``i`` depends only on (seed, i)."""

    def __init__(self, cfg, batch_size: int, seq_len: int, seed: int = 0):
        self.cfg = cfg
        self.batch = batch_size
        self.seq = seq_len
        self.seed = seed

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        v = self.cfg.vocab_size
        # Zipf-like marginal: realistic softmax-xent magnitudes
        probs = 1.0 / np.arange(1, v + 1) ** 1.1
        probs /= probs.sum()
        if self.cfg.family == "vlm":
            s_text = self.seq - self.cfg.n_patches
            toks = rng.choice(v, size=(self.batch, s_text + 1), p=probs)
            out = {"tokens": toks[:, :-1].astype(np.int32),
                   "labels": toks[:, 1:].astype(np.int32),
                   "patches": rng.standard_normal(
                       (self.batch, self.cfg.n_patches,
                        self.cfg.patch_embed_dim)).astype(np.float32)}
        elif self.cfg.family == "encdec":
            toks = rng.choice(v, size=(self.batch, self.seq + 1), p=probs)
            out = {"tokens": toks[:, :-1].astype(np.int32),
                   "labels": toks[:, 1:].astype(np.int32),
                   "frames": rng.standard_normal(
                       (self.batch, self.seq, self.cfg.d_model)
                   ).astype(np.float32)}
        else:
            toks = rng.choice(v, size=(self.batch, self.seq + 1), p=probs)
            out = {"tokens": toks[:, :-1].astype(np.int32),
                   "labels": toks[:, 1:].astype(np.int32)}
        return out


class MMapTokens:
    """Packed sequences from a flat token file (np.memmap).  Shuffling is a
    step-seeded permutation over window starts - stateless and resumable."""

    def __init__(self, path: str, cfg, batch_size: int, seq_len: int,
                 dtype=np.uint16, seed: int = 0):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.cfg = cfg
        self.batch = batch_size
        self.seq = seq_len
        self.seed = seed
        self.n_windows = (len(self.data) - 1) // seq_len
        if self.n_windows < batch_size:
            raise ValueError("token file too small for one batch")

    def __call__(self, step: int) -> dict:
        epoch = (step * self.batch) // self.n_windows
        rng = np.random.default_rng((self.seed << 20) ^ epoch)
        perm = rng.permutation(self.n_windows)
        idx = [(step * self.batch + j) % self.n_windows
               for j in range(self.batch)]
        starts = perm[idx] * self.seq
        toks = np.stack([self.data[s:s + self.seq + 1] for s in starts])
        toks = np.minimum(toks.astype(np.int32), self.cfg.vocab_size - 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Prefetcher:
    """Background-thread double buffering: host batch -> device."""

    def __init__(self, source, start_step: int = 0, depth: int = 2,
                 shardings=None):
        self.source = source
        self.shardings = shardings
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _put_device(self, batch):
        if self.shardings is not None:
            return {k: jax.device_put(v, self.shardings[k])
                    for k, v in batch.items()}
        return jax.tree_util.tree_map(jnp.asarray, batch)

    def _run(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source(step)
            payload = (step, self._put_device(batch))
            while not self._stop.is_set():
                try:
                    self.q.put(payload, timeout=0.5)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
