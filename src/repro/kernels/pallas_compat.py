"""Pallas API-drift compatibility layer.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and
back-compat shims vary by release), so kernels never touch the class
directly - they build their compiler params through
``tpu_compiler_params(...)``, which resolves whichever spelling the
installed jax provides.  Kept free of intra-package imports so both
``ops`` and the kernel modules can use it without import cycles.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams", None)


def tpu_compiler_params(**kwargs):
    """Build a Pallas TPU CompilerParams across jax versions.

    Accepts the keyword arguments common to both spellings (notably
    ``dimension_semantics``); unknown keywords for the resolved class are
    dropped rather than raised so newer call sites degrade gracefully on
    older jax.
    """
    if _COMPILER_PARAMS_CLS is None:  # pragma: no cover - ancient jax
        raise AttributeError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams")
    fields = getattr(_COMPILER_PARAMS_CLS, "__dataclass_fields__", None)
    if fields is not None:
        kwargs = {k: v for k, v in kwargs.items() if k in fields}
    return _COMPILER_PARAMS_CLS(**kwargs)
