"""Paged-attention kernels (GQA over a blocked KV pool): decode + prefill.

KV lives in a global pool of fixed-size blocks — k_pool/v_pool:
``(n_blocks, n_kv_heads, block_size, head_dim)`` — and each request owns an
ordered *block table* row ``(max_blocks,)`` mapping its logical KV positions
``[i * block_size, (i+1) * block_size)`` to pool block ids (vLLM's
PagedAttention, Kwon et al. SOSP 2023).  Valid positions are a prefix:
``kv_len[b]`` masks everything at or beyond the current length, so trailing
table entries may point anywhere (the serving engine points them at the
null block).

Two implementations:

* ``pallas`` - scalar-prefetched block-table gather: the grid walks
  (batch, kv-head, block) and the k/v BlockSpec index_maps read the
  prefetched block table, so each grid step DMAs exactly the one pool block
  it needs; a flash-style online softmax accumulates across a request's
  blocks.  No (B, S, D) contiguous KV is ever materialized.
* ``xla`` - pure-jnp gather (``jnp.take`` of pool rows by block table)
  followed by the dense masked decode attention.  Runs anywhere (CPU /
  interpret) and serves as the correctness oracle in tests.

The **prefill** kernel (``paged_prefill_attention_*``) runs one
``block_size`` chunk of a prompt: causal self-attention of the chunk's
queries over every block the request has written so far — earlier chunks'
blocks plus the chunk's own, all reached through the block table.  The
serving engine writes each chunk's K/V straight into its pool block and
then calls this, so a prompt is prefilled without ever materializing a
dense ``(Hkv, prompt_len, D)`` cache:

* ``pallas`` - same scalar-prefetched gather as decode, walking
  (batch, kv-head, block) with a flash-style online softmax; blocks past
  the chunk (``j * bs > q_start + Sq - 1``) are skipped entirely, so a
  chunk at position p only pays for the ceil((p + Sq) / bs) blocks below
  its causal frontier.
* ``xla`` - a scan over table entries gathering *one* pool block per step
  (``jnp.take`` of a (B,) id vector) folded into an online softmax — the
  CPU production path, O(block) memory, never a whole-table gather.  The
  full-gather oracle lives in ``repro.kernels.ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .attention import NEG_INF, decode_attention_xla
from .pallas_compat import tpu_compiler_params


# ---------------------------------------------------------------------------
# Pallas kernel.
# ---------------------------------------------------------------------------

def _paged_kernel(bt_ref, kvlen_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale: float, bs: int, g: int,
                  n_steps: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = kvlen_ref[b]

    # valid positions are a prefix, so blocks at or past kv_len contribute
    # nothing — skip their compute entirely
    @pl.when(j * bs < kv_len)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)            # (g, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bs, d)
        logits = jnp.dot(q, k.T,
                         preferred_element_type=jnp.float32) * scale
        kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (g, bs), 1)
        logits = jnp.where(kpos < kv_len, logits, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(logits, axis=-1)[:, None]      # (g, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)[:, None]
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_steps - 1)
    def _flush():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention_pallas(q, k_pool, v_pool, block_table, kv_len, *,
                                  scale=None, interpret=False):
    """q: (B, Hq, 1, D); k_pool/v_pool: (N, Hkv, bs, D);
    block_table: (B, M) int32; kv_len: (B,) int32.  Returns (B, Hq, 1, D)."""
    b, hq, _, d = q.shape
    _, hkv, bs, _ = k_pool.shape
    g = hq // hkv
    m = block_table.shape[1]
    scale = float(scale if scale is not None else 1.0 / np.sqrt(d))
    # q-heads are grouped by kv head (consecutive g q-heads share a kv head)
    q4 = q[:, :, 0, :].reshape(b, hkv, g, d)
    kern = functools.partial(_paged_kernel, scale=scale, bs=bs, g=g,
                             n_steps=m)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, m),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda b_, h, j, bt, kl: (b_, h, 0, 0)),
            # the block-table gather: grid step (b, h, j) pulls pool block
            # bt[b, j] for kv head h
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h, j, bt, kl: (bt[b_, j], h, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h, j, bt, kl: (bt[b_, j], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b_, h, j, bt, kl: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table.astype(jnp.int32), kv_len.astype(jnp.int32),
      q4, k_pool, v_pool)
    return out.reshape(b, hq, 1, d)


# ---------------------------------------------------------------------------
# Pure-JAX reference (CPU production path + correctness oracle).
# ---------------------------------------------------------------------------

def paged_decode_attention_xla(q, k_pool, v_pool, block_table, kv_len, *,
                               scale=None, window=None):
    """Gather each request's blocks into contiguous (B, Hkv, M*bs, D) KV
    and run the dense masked decode attention.  Bitwise-identical math to
    the dense layout when M*bs equals the dense cache length (positions at
    or past kv_len are exact zeros in the softmax either way)."""
    b = q.shape[0]
    _, hkv, bs, d = k_pool.shape
    m = block_table.shape[1]
    k = jnp.take(k_pool, block_table, axis=0)      # (B, M, Hkv, bs, D)
    v = jnp.take(v_pool, block_table, axis=0)
    k = k.transpose(0, 2, 1, 3, 4).reshape(b, hkv, m * bs, d)
    v = v.transpose(0, 2, 1, 3, 4).reshape(b, hkv, m * bs, d)
    return decode_attention_xla(q, k, v, kv_len, scale=scale, window=window)


# ---------------------------------------------------------------------------
# Prefill: one prompt chunk's causal attention over previously-written
# blocks (chunked prefill — the engine scatters the chunk's K/V into its
# pool block first, then every block <= the causal frontier is read back
# through the table).
# ---------------------------------------------------------------------------

def _paged_prefill_kernel(bt_ref, qstart_ref, q_ref, k_ref, v_ref, o_ref,
                          m_ref, l_ref, acc_ref, *, scale: float, bs: int,
                          g: int, sq: int, n_steps: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qstart_ref[b]

    # block j holds positions [j*bs, (j+1)*bs); the chunk's last query sits
    # at q_start + sq - 1, so later blocks are all-masked — skip them
    @pl.when(j * bs <= q_start + sq - 1)
    def _block():
        d = q_ref.shape[-1]
        q = q_ref[0, 0].astype(jnp.float32).reshape(g * sq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bs, d)
        logits = jnp.dot(q, k.T,
                         preferred_element_type=jnp.float32) * scale
        # row r is query position q_start + (r % sq) of head r // sq
        qpos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (g * sq, bs), 0) % sq
        kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (g * sq, bs), 1)
        logits = jnp.where(kpos <= qpos, logits, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(logits, axis=-1)[:, None]      # (g*sq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)[:, None]
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_steps - 1)
    def _flush():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                       ).reshape(g, sq, -1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_prefill_attention_pallas(q, k_pool, v_pool, block_table, q_start,
                                   *, scale=None, interpret=False):
    """q: (B, Hq, Sq, D) chunk queries starting at absolute position
    q_start[b]; k_pool/v_pool: (N, Hkv, bs, D); block_table: (B, M) int32;
    q_start: (B,) int32.  Returns (B, Hq, Sq, D).  Position 0 must be
    attendable (q_start >= 0 and causal), so block 0 always contributes —
    the online-softmax init never sees an all-masked first block."""
    b, hq, sq, d = q.shape
    _, hkv, bs, _ = k_pool.shape
    g = hq // hkv
    m = block_table.shape[1]
    scale = float(scale if scale is not None else 1.0 / np.sqrt(d))
    # q-heads are grouped by kv head (consecutive g q-heads share a kv head)
    q5 = q.reshape(b, hkv, g, sq, d)
    kern = functools.partial(_paged_prefill_kernel, scale=scale, bs=bs, g=g,
                             sq=sq, n_steps=m)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, m),
        in_specs=[
            pl.BlockSpec((1, 1, g, sq, d),
                         lambda b_, h, j, bt, qs: (b_, h, 0, 0, 0)),
            # the block-table gather: grid step (b, h, j) pulls pool block
            # bt[b, j] for kv head h
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h, j, bt, qs: (bt[b_, j], h, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h, j, bt, qs: (bt[b_, j], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, sq, d),
                               lambda b_, h, j, bt, qs: (b_, h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g * sq, 1), jnp.float32),
            pltpu.VMEM((g * sq, 1), jnp.float32),
            pltpu.VMEM((g * sq, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, sq, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table.astype(jnp.int32), q_start.astype(jnp.int32),
      q5, k_pool, v_pool)
    return out.reshape(b, hq, sq, d)


def paged_prefill_attention_xla(q, k_pool, v_pool, block_table, q_start, *,
                                scale=None, window=None):
    """CPU production path: walk the block table gathering one pool block
    per step ((B, Hkv, bs, D) via ``jnp.take``) and fold it into a
    flash-style online softmax.  Peak KV-side temp is a single block — the
    whole-table dense gather only exists in the ``ref`` oracle — and the
    walk stops at the batch's furthest causal frontier instead of paying
    for every (fully-masked) trailing table entry."""
    b, hq, sq, d = q.shape
    _, hkv, bs, _ = k_pool.shape
    m = block_table.shape[1]
    g = hq // hkv
    scale = float(scale if scale is not None else 1.0 / np.sqrt(d))
    qpos = q_start[:, None] + jnp.arange(sq)[None, :]            # (B, Sq)
    qf = q.astype(jnp.float32).reshape(b, hkv, g, sq, d) * scale

    def kv_step(j, carry):
        m_prev, l_prev, acc = carry
        ids = jax.lax.dynamic_index_in_dim(block_table, j, 1,
                                           keepdims=False)       # (B,)
        kb = jnp.take(k_pool, ids, axis=0).astype(jnp.float32)
        vb = jnp.take(v_pool, ids, axis=0).astype(jnp.float32)
        kpos = j * bs + jnp.arange(bs)
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kb)
        mask = kpos[None, None, :] <= qpos[:, :, None]           # (B, Sq, bs)
        if window is not None:
            mask &= kpos[None, None, :] > qpos[:, :, None] - window
        logits = jnp.where(mask[:, None, None], logits, NEG_INF)
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p, vb)
        return (m_new, l_new, acc)

    m0 = jnp.full((b, hkv, g, sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    # blocks past the last query position contribute exact zeros — stop
    # there (traced bound: fori_loop lowers to while_loop; inference-only)
    n_live = jnp.minimum((jnp.max(q_start) + sq - 1) // bs + 1, m)
    (_, l, acc) = jax.lax.fori_loop(0, n_live, kv_step, (m0, l0, a0))
    out = acc / jnp.where(l == 0.0, 1.0, l)
    return out.reshape(b, hq, sq, d).astype(q.dtype)
