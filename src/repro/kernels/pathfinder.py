"""Pathfinder DP kernel (paper pool, RiVec suite).

dst[j] = w[i][j] + min(src[j-1], src[j], src[j+1]) row by row.  The row
recurrence runs on the sequential grid axis with the running costs in VMEM
scratch; the j+-1 neighbor access is a slide-by-1 (C2's cheapest config).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import tpu_compiler_params

_BIG = 3.0e38  # python float: jnp scalars would be captured as kernel consts


def _shift_with(row, fill, direction):
    if direction > 0:
        return jnp.concatenate([jnp.full((1, 1), fill, row.dtype), row[:, :-1]],
                               axis=1)
    return jnp.concatenate([row[:, 1:], jnp.full((1, 1), fill, row.dtype)],
                           axis=1)


def _pathfinder_kernel(w_ref, o_ref, src_ref, *, rows: int):
    i = pl.program_id(0)
    w = w_ref[...].astype(jnp.float32)        # (1, cols)

    @pl.when(i == 0)
    def _init():
        src_ref[...] = w

    @pl.when(i > 0)
    def _step():
        src = src_ref[...]
        left = _shift_with(src, _BIG, +1)
        right = _shift_with(src, _BIG, -1)
        src_ref[...] = w + jnp.minimum(src, jnp.minimum(left, right))

    @pl.when(i == rows - 1)
    def _flush():
        o_ref[...] = src_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pathfinder_pallas(w, *, interpret=False):
    rows, cols = w.shape
    return pl.pallas_call(
        functools.partial(_pathfinder_kernel, rows=rows),
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, cols), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, cols), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, cols), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(w)[0]


def pathfinder_xla(w):
    from .ref import pathfinder_ref
    return pathfinder_ref(w)
