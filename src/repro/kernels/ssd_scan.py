"""Mamba2 SSD (state-space dual) chunked scan.

The TPU adaptation story (DESIGN.md §2): the sequence is chunked so that the
intra-chunk work becomes MXU matmuls (the SSD insight) and the inter-chunk
recurrence is a short scan — the same intra-lane / inter-lane split as the
paper's 3-step reduction (C3).  When the sequence axis is sharded, the chunk
boundary hand-off is a slide-by-1 (C2's cheapest configuration).

Semantics (oracle: ``ref.ssd_ref``): per head h with A = -exp(a_log):
  h_t = exp(dt_t * A) h_{t-1} + dt_t * x_t outer B_t ;   y_t = C_t . h_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import tpu_compiler_params


def _chunk_body(h_in, xc, dtc, a, bc, cc):
    """One chunk, all heads vectorized.

    xc: (Q, H, P), dtc: (Q, H), a: (H,), bc/cc: (Q, H, N), h_in: (H, P, N).
    Returns (y (Q, H, P), h_out)."""
    dA = dtc * a                                   # (Q, H)
    s = jnp.cumsum(dA, axis=0)                     # inclusive log-decay
    st = s.T                                       # (H, Q)
    # intra-chunk: scores[h, i, j] = (C_i . B_j) * exp(s_i - s_j), j <= i
    cb = jnp.einsum("ihn,jhn->hij", cc, bc)
    ii = jnp.arange(s.shape[0])
    causal = (ii[:, None] >= ii[None, :])[None]
    decay = jnp.exp(st[:, :, None] - st[:, None, :])
    scores = jnp.where(causal, cb * decay, 0.0)
    dtx = dtc[..., None] * xc                      # (Q, H, P)
    y = jnp.einsum("hij,jhp->ihp", scores, dtx)
    # inter-chunk: contribution of the carried state
    y = y + jnp.exp(st).T[..., None] * jnp.einsum("ihn,hpn->ihp", cc, h_in)
    # state update
    decay_out = jnp.exp(st[:, -1:] - st)           # (H, Q)
    dh = jnp.einsum("hj,jhp,jhn->hpn", decay_out, dtx, bc)
    h_out = jnp.exp(st[:, -1])[:, None, None] * h_in + dh
    return y, h_out


def ssd_xla(x, dt, a_log, b_mat, c_mat, *, d_skip=None, h0=None, chunk=64):
    """Chunked SSD scan in pure jnp (production path; differentiable).

    x: (B, S, H, P), dt: (B, S, H), a_log: (H,), b_mat/c_mat: (B, S, G, N).
    Returns (y, h_final (B, H, P, N))."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2:]
    rep = h // g
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))

    f32 = jnp.float32
    xc = jnp.moveaxis(x.astype(f32).reshape(bsz, nc, chunk, h, p), 1, 0)
    dtc = jnp.moveaxis(dt.astype(f32).reshape(bsz, nc, chunk, h), 1, 0)
    bc = jnp.moveaxis(b_mat.astype(f32).reshape(bsz, nc, chunk, g, n), 1, 0)
    cc = jnp.moveaxis(c_mat.astype(f32).reshape(bsz, nc, chunk, g, n), 1, 0)

    body = jax.vmap(_chunk_body, in_axes=(0, 0, 0, None, 0, 0))

    # checkpoint per chunk: backward re-materializes the (B,H,Q,Q)
    # decay/score blocks instead of saving all nc of them (zamba2 train_4k
    # held ~17 GB/device of them before this; see EXPERIMENTS.md §Perf)
    @jax.checkpoint
    def step(h_state, inputs):
        xb, dtb, bb, cb_ = inputs
        bb = jnp.repeat(bb, rep, axis=2)           # (B, Q, H, N)
        cb_ = jnp.repeat(cb_, rep, axis=2)
        y, h_state = body(h_state, xb, dtb, a, bb, cb_)
        return h_state, y

    h_state = (jnp.zeros((bsz, h, p, n), f32) if h0 is None
               else h0.astype(f32))
    h_final, ys = jax.lax.scan(step, h_state, (xc, dtc, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)
    if d_skip is not None:
        y = y + d_skip[None, None, :, None] * x.astype(f32)
    return y.astype(x.dtype), h_final


def ssd_step_xla(h_state, xt, dtt, a_log, bt, ct, *, d_skip=None):
    """Single-token recurrent step (decode path, O(1) per token).

    h_state: (B, H, P, N), xt: (B, H, P), dtt: (B, H), bt/ct: (B, G, N)."""
    h = xt.shape[1]
    rep = h // bt.shape[1]
    a = -jnp.exp(a_log.astype(jnp.float32))
    bt = jnp.repeat(bt.astype(jnp.float32), rep, axis=1)
    ct = jnp.repeat(ct.astype(jnp.float32), rep, axis=1)
    decay = jnp.exp(dtt.astype(jnp.float32) * a)
    dx = dtt[..., None].astype(jnp.float32) * xt.astype(jnp.float32)
    h_state = (decay[..., None, None] * h_state
               + dx[..., None] * bt[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", h_state, ct)
    if d_skip is not None:
        y = y + d_skip[None, :, None] * xt.astype(jnp.float32)
    return y.astype(xt.dtype), h_state


# ---------------------------------------------------------------------------
# Pallas kernel: grid (B, H, n_chunks), state carried in VMEM scratch across
# the sequential chunk axis.
# ---------------------------------------------------------------------------

def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref,
                *, nc: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xc = x_ref[0, :, 0, :].astype(jnp.float32)     # (Q, P)
    dtc = dt_ref[0, :, 0].astype(jnp.float32)      # (Q,)
    a = a_ref[0].astype(jnp.float32)               # scalar
    bc = b_ref[0, :, 0, :].astype(jnp.float32)     # (Q, N)
    cc = c_ref[0, :, 0, :].astype(jnp.float32)     # (Q, N)

    dA = dtc * a
    s = jnp.cumsum(dA)
    cb = jnp.dot(cc, bc.T, preferred_element_type=jnp.float32)   # (Q, Q)
    q = s.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    scores = jnp.where(ii >= jj, cb * jnp.exp(s[:, None] - s[None, :]), 0.0)
    dtx = dtc[:, None] * xc                         # (Q, P)
    h_in = h_ref[...]                               # (P, N)
    y = jnp.dot(scores, dtx, preferred_element_type=jnp.float32)
    y = y + jnp.exp(s)[:, None] * jnp.dot(cc, h_in.T,
                                          preferred_element_type=jnp.float32)
    decay_out = jnp.exp(s[-1] - s)                  # (Q,)
    dh = jnp.dot((decay_out[:, None] * dtx).T, bc,
                 preferred_element_type=jnp.float32)
    h_new = jnp.exp(s[-1]) * h_in + dh
    h_ref[...] = h_new
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(c_idx == nc - 1)
    def _flush():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x, dt, a_log, b_mat, c_mat, *, chunk=64, interpret=False):
    """Pallas SSD (TPU target).  Same contract as ``ssd_xla`` minus
    d_skip/h0 (applied by the wrapper)."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2:]
    rep = h // g
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))
    grid = (bsz, h, nc)
    y, h_final = pl.pallas_call(
        functools.partial(_ssd_kernel, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b, hh, c: (b, c, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, hh, c: (b, c, hh)),
            pl.BlockSpec((1,), lambda b, hh, c: (hh,)),
            pl.BlockSpec((1, chunk, 1, n), lambda b, hh, c, r=rep: (b, c, hh // r, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda b, hh, c, r=rep: (b, c, hh // r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b, hh, c: (b, c, hh, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, hh, c: (b, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a, b_mat, c_mat)
    return y, h_final
