"""Radix-2 Stockham FFT kernel (paper pool).

The paper's fft buffers all samples in the VRF (<= 128*L inputs) to avoid
memory round-trips; here the whole signal stays in VMEM across all log2(n)
stages.  The Stockham autosort formulation needs no bit-reversal gather -
every stage is reshape + butterfly + twiddle, i.e. the power-of-two data
movement the optimized SLDU supports natively (C2).

Stage s (l = n >> (s+1), m = 1 << s):
  view X as (2, l, m): a, b = X[0], X[1]
  top = a + b ; bot = w_l * (a - b),  w_l[j] = exp(-2*pi*i*j / 2l)
  X <- stack([top, bot], axis=1)  # (l, 2, m)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _twiddles(n: int) -> np.ndarray:
    """(stages, n/2) complex twiddle table; row s holds w_l for l = n>>(s+1),
    padded with zeros."""
    t = int(np.log2(n))
    tw = np.zeros((t, n // 2), np.complex64)
    for s in range(t):
        l = n >> (s + 1)
        tw[s, :l] = np.exp(-2j * np.pi * np.arange(l) / (2 * l))
    return tw


def _fft_stages(xr, xi, twr, twi, n: int):
    t = int(np.log2(n))
    for s in range(t):
        l, m = n >> (s + 1), 1 << s
        ar, ai = xr.reshape(2, l, m)[0], xi.reshape(2, l, m)[0]
        br, bi = xr.reshape(2, l, m)[1], xi.reshape(2, l, m)[1]
        wr = twr[s, :l].reshape(l, 1)
        wi = twi[s, :l].reshape(l, 1)
        tr, ti = ar + br, ai + bi
        dr, di = ar - br, ai - bi
        botr = wr * dr - wi * di
        boti = wr * di + wi * dr
        xr = jnp.stack([tr, botr], axis=1).reshape(n)
        xi = jnp.stack([ti, boti], axis=1).reshape(n)
    return xr, xi


def _fft_kernel(xr_ref, xi_ref, twr_ref, twi_ref, or_ref, oi_ref, *, n: int):
    xr = xr_ref[...].astype(jnp.float32)
    xi = xi_ref[...].astype(jnp.float32)
    yr, yi = _fft_stages(xr, xi, twr_ref[...], twi_ref[...], n)
    or_ref[...] = yr.astype(or_ref.dtype)
    oi_ref[...] = yi.astype(oi_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fft_pallas(x_re, x_im, *, interpret=False):
    (n,) = x_re.shape
    assert n & (n - 1) == 0 and n >= 2, f"n={n} must be a power of two"
    tw = _twiddles(n)
    twr = jnp.asarray(tw.real)
    twi = jnp.asarray(tw.imag)
    t = tw.shape[0]
    return pl.pallas_call(
        functools.partial(_fft_kernel, n=n),
        grid=(1,),
        in_specs=[pl.BlockSpec((n,), lambda i: (0,)),
                  pl.BlockSpec((n,), lambda i: (0,)),
                  pl.BlockSpec((t, n // 2), lambda i: (0, 0)),
                  pl.BlockSpec((t, n // 2), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((n,), lambda i: (0,)),
                   pl.BlockSpec((n,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32),
                   jax.ShapeDtypeStruct((n,), jnp.float32)],
        interpret=interpret,
    )(x_re, x_im, twr, twi)


def fft_xla(x_re, x_im):
    """Same Stockham schedule, lowered through XLA (production CPU path)."""
    (n,) = x_re.shape
    tw = _twiddles(n)
    return _fft_stages(x_re.astype(jnp.float32), x_im.astype(jnp.float32),
                       jnp.asarray(tw.real), jnp.asarray(tw.imag), n)
