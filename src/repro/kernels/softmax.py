"""Row softmax kernel (paper pool; the ML 'final attention score' kernel).

One row block per grid step, full row resident in VMEM (rows up to a few K
columns; attention-scale softmax goes through the flash kernel instead).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def softmax_pallas(x, *, block_rows=8, interpret=False):
    r, c = x.shape
    block_rows = min(block_rows, r)
    assert r % block_rows == 0
    return pl.pallas_call(
        _softmax_kernel,
        grid=(r // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), x.dtype),
        interpret=interpret,
    )(x)


def softmax_xla(x):
    return jax.nn.softmax(x.astype(jnp.float32), axis=-1).astype(x.dtype)
