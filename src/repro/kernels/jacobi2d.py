"""Jacobi 2-D stencil kernel (paper pool).

One sweep of the 5-point stencil on the interior; halo rows come from a
dynamic slice of the VMEM-resident input (at mesh scale the halo is a
slide-by-1 exchange - ``core.slide.mesh_halo_exchange``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _jacobi_kernel(x_ref, o_ref, *, br: int):
    i = pl.program_id(0)
    w = x_ref.shape[1]
    rows = x_ref[pl.dslice(i * br, br + 2), :]        # (br+2, W)
    out = 0.2 * (rows[1:-1, 1:-1] + rows[:-2, 1:-1] + rows[2:, 1:-1]
                 + rows[1:-1, :-2] + rows[1:-1, 2:])
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def jacobi2d_pallas(x, *, block_rows=8, interpret=False):
    """One interior sweep: returns the full array with boundary preserved."""
    h, w = x.shape
    hi, wi = h - 2, w - 2
    br = min(block_rows, hi)
    assert hi % br == 0, (hi, br)
    inner = pl.pallas_call(
        functools.partial(_jacobi_kernel, br=br),
        grid=(hi // br,),
        in_specs=[pl.BlockSpec((h, w), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, wi), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((hi, wi), x.dtype),
        interpret=interpret,
    )(x)
    return x.at[1:-1, 1:-1].set(inner)


def jacobi2d_xla(x, steps=1):
    from .ref import jacobi2d_ref
    return jacobi2d_ref(x, steps)
