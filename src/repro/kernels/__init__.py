"""Pallas TPU kernels (validated in interpret mode on CPU) + XLA production
paths + pure-jnp oracles.  See ops.py for the dispatch contract."""
from . import ops, ref
from .ops import (attention, conv2d, decode_attention, default_impl,
                  dotproduct, dropout, dwt_haar, exp, fft, impl_scope,
                  jacobi2d, matmul, pathfinder, roi_align, set_impl, softmax,
                  ssd_scan, ssd_step)
