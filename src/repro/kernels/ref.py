"""Pure-jnp oracles for every kernel (the ground truth everywhere).

These are deliberately naive: full-materialization attention, sequential SSM
recurrence, direct convolution.  Tests assert the Pallas kernels (interpret
mode) and the ``xla`` production impls against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def matmul_ref(x, w, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)).astype(out_dtype)


def dotproduct_ref(x, y):
    return jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))


def softmax_ref(x, axis=-1):
    x32 = x.astype(jnp.float32)
    m = jnp.max(x32, axis=axis, keepdims=True)
    e = jnp.exp(x32 - m)
    return (e / jnp.sum(e, axis=axis, keepdims=True)).astype(x.dtype)


def exp_ref(x):
    return jnp.exp(x)


def dropout_ref(x, bits, rate):
    """``bits``: uint32 random bits, same shape as x (precomputed; the Ara2
    kernel also streams its mask from memory)."""
    keep = (bits.astype(jnp.float32) / np.float32(2 ** 32)) >= rate
    return jnp.where(keep, x / (1.0 - rate), 0.0).astype(x.dtype)


def conv2d_ref(x, w):
    """x: (C, H, W), w: (C, K, K) -> (H-K+1, W-K+1); the paper's 3x7x7
    single-output-channel convolution."""
    c, h, ww = x.shape
    _, k, _ = w.shape
    out = jnp.zeros((h - k + 1, ww - k + 1), jnp.float32)
    for ci in range(c):
        for ki in range(k):
            for kj in range(k):
                out = out + w[ci, ki, kj] * x[ci, ki:h - k + 1 + ki, kj:ww - k + 1 + kj]
    return out


def jacobi2d_ref(x, steps=1):
    """5-point Jacobi sweeps on the interior; boundary kept."""
    for _ in range(steps):
        inner = 0.2 * (x[1:-1, 1:-1] + x[:-2, 1:-1] + x[2:, 1:-1]
                       + x[1:-1, :-2] + x[1:-1, 2:])
        x = x.at[1:-1, 1:-1].set(inner)
    return x


def dwt_haar_ref(x, levels=1):
    """1-D Haar DWT, in-place layout [approx | detail | detail ...]."""
    n = x.shape[-1]
    out = x.astype(jnp.float32)
    s = 1.0 / np.sqrt(2.0).astype(np.float32)
    length = n
    for _ in range(levels):
        even, odd = out[..., 0:length:2], out[..., 1:length:2]
        lo, hi = (even + odd) * s, (even - odd) * s
        out = out.at[..., :length // 2].set(lo).at[..., length // 2:length].set(hi)
        length //= 2
    return out


def pathfinder_ref(w):
    """w: (rows, cols) costs; returns min-path cost per column (the RiVec
    pathfinder DP: dst[j] = w[i,j] + min(src[j-1], src[j], src[j+1]))."""
    rows, cols = w.shape
    src = w[0]
    big = jnp.asarray(jnp.finfo(jnp.float32).max, jnp.float32)
    for i in range(1, rows):
        left = jnp.concatenate([jnp.array([big]), src[:-1]])
        right = jnp.concatenate([src[1:], jnp.array([big])])
        src = w[i] + jnp.minimum(src, jnp.minimum(left, right))
    return src


def fft_ref(x_re, x_im):
    v = jnp.fft.fft(x_re.astype(jnp.complex64) + 1j * x_im.astype(jnp.complex64))
    return jnp.real(v).astype(jnp.float32), jnp.imag(v).astype(jnp.float32)


def roi_align_ref(feat, rois, out_size=7, sampling=2):
    """feat: (C, H, W); rois: (R, 4) [y0, x0, y1, x1] in pixel coords.
    Returns (R, C, out_size, out_size) via average-pooled bilinear samples."""
    c, h, w = feat.shape

    def bilinear(y, x):
        y = jnp.clip(y, 0.0, h - 1.0)
        x = jnp.clip(x, 0.0, w - 1.0)
        y0 = jnp.clip(jnp.floor(y).astype(jnp.int32), 0, h - 2)
        x0 = jnp.clip(jnp.floor(x).astype(jnp.int32), 0, w - 2)
        dy, dx = y - y0, x - x0
        v00 = feat[:, y0, x0]
        v01 = feat[:, y0, x0 + 1]
        v10 = feat[:, y0 + 1, x0]
        v11 = feat[:, y0 + 1, x0 + 1]
        return (v00 * (1 - dy) * (1 - dx) + v01 * (1 - dy) * dx
                + v10 * dy * (1 - dx) + v11 * dy * dx)

    def one_roi(roi):
        y0, x0, y1, x1 = roi
        bin_h = (y1 - y0) / out_size
        bin_w = (x1 - x0) / out_size
        out = []
        for oy in range(out_size):
            row = []
            for ox in range(out_size):
                acc = 0.0
                for sy in range(sampling):
                    for sx in range(sampling):
                        y = y0 + (oy + (sy + 0.5) / sampling) * bin_h
                        x = x0 + (ox + (sx + 0.5) / sampling) * bin_w
                        acc = acc + bilinear(y, x)
                row.append(acc / (sampling * sampling))
            out.append(jnp.stack(row, axis=-1))
        return jnp.stack(out, axis=-2)

    return jax.vmap(one_roi)(rois)


# ---------------------------------------------------------------------------
# Attention / SSM oracles.
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, *, causal=True, window=None, scale=None,
                  kv_len=None):
    """q: (B, Hq, Sq, D), k/v: (B, Hkv, Sk, D); GQA by head broadcast.
    ``window``: sliding-window size (None = full); ``kv_len``: effective kv
    length per batch for decode (positions >= kv_len masked)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + (sk - sq)   # right-aligned query block
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask = mask[None] & (kpos[None] < kv_len[:, None, None])
        mask = mask[:, None]
    else:
        mask = mask[None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def paged_prefill_attention_ref(q, k_pool, v_pool, block_table, q_start, *,
                                scale=None, window=None):
    """Causal chunk attention against a paged KV pool, fully materialized.

    q: (B, Hq, Sq, D) — one prompt chunk per batch row, whose first query
    sits at absolute position ``q_start[b]``; k_pool/v_pool:
    (n_blocks, Hkv, bs, D); block_table: (B, M) pool block ids mapping
    logical positions ``[j*bs, (j+1)*bs)``.  Query ``q_start + i`` attends
    every pool position ``<= q_start + i`` (the blocks written by earlier
    chunks plus this chunk's own block).  Gathers the whole table into a
    dense (B, Hkv, M*bs, D) cache — the deliberately naive oracle the
    production paths are tested against."""
    b, hq, sq, d = q.shape
    _, hkv, bs, _ = k_pool.shape
    m = block_table.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    k = jnp.take(k_pool, block_table, axis=0)      # (B, M, Hkv, bs, D)
    v = jnp.take(v_pool, block_table, axis=0)
    k = k.transpose(0, 2, 1, 3, 4).reshape(b, hkv, m * bs, d)
    v = v.transpose(0, 2, 1, 3, 4).reshape(b, hkv, m * bs, d)
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = q_start[:, None] + jnp.arange(sq)[None, :]        # (B, Sq)
    kpos = jnp.arange(m * bs)[None, None, :]                 # (1, 1, M*bs)
    mask = kpos <= qpos[:, :, None]
    if window is not None:
        mask &= kpos > qpos[:, :, None] - window
    logits = jnp.where(mask[:, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x, dt, a_log, b_mat, c_mat, *, d_skip=None, h0=None):
    """Mamba2 SSD, exact sequential recurrence (the oracle).

    x: (B, S, H, P), dt: (B, S, H), a_log: (H,) (A = -exp(a_log) < 0),
    b_mat/c_mat: (B, S, G, N) with H % G == 0, optional d_skip: (H,),
    h0: (B, H, P, N) initial state.  Returns (y, h_final).
    """
    bsz, s, h, p = x.shape
    _, _, g, n = b_mat.shape
    rep = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))

    def step(h_state, inputs):
        xt, dtt, bt, ct = inputs  # (B,H,P), (B,H), (B,G,N), (B,G,N)
        decay = jnp.exp(dtt * a)                       # (B,H)
        bt_h = jnp.repeat(bt, rep, axis=1)             # (B,H,N)
        ct_h = jnp.repeat(ct, rep, axis=1)
        dx = (dtt[..., None] * xt)                     # (B,H,P)
        h_state = decay[..., None, None] * h_state + dx[..., None] * bt_h[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h_state, ct_h)
        return h_state, y

    h_state = jnp.zeros((bsz, h, p, n), jnp.float32) if h0 is None else h0
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(b_mat.astype(jnp.float32), 1, 0),
          jnp.moveaxis(c_mat.astype(jnp.float32), 1, 0))
    h_final, ys = jax.lax.scan(step, h_state, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,H,P)
    if d_skip is not None:
        y = y + d_skip[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), h_final
