"""Dot-product kernel with the paper's 3-step hierarchical reduction (C3).

The VMEM accumulator tile (8, 128) plays the role of the per-lane FPU
pipeline-register accumulators (§3: "the internal pipeline registers of the
FPU are used as accumulators"): the streaming phase accumulates block
partials into it at full throughput, and only the final grid step pays the
log-tree drain - exactly the paper's intra-lane -> inter-lane -> SIMD split.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import tpu_compiler_params

LANES = (8, 128)  # VPU-shaped accumulator tile
BLOCK = LANES[0] * LANES[1]


def _dot_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_steps: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32).reshape(LANES)
    y = y_ref[...].astype(jnp.float32).reshape(LANES)
    acc_ref[...] += x * y   # phase 1: streaming accumulate (intra-lane)

    @pl.when(i == n_steps - 1)
    def _drain():
        acc = acc_ref[...]
        # phase 2: inter-lane log tree (across sublanes)
        while acc.shape[0] > 1:
            h = acc.shape[0] // 2
            acc = acc[:h] + acc[h:]
        # phase 3: SIMD log tree (within the 128-wide word)
        row = acc[0]
        while row.shape[0] > 1:
            h = row.shape[0] // 2
            row = row[:h] + row[h:]
        o_ref[0, 0] = row[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dotproduct_pallas(x, y, *, interpret=False):
    (n,) = x.shape
    assert n % BLOCK == 0, f"n={n} must be a multiple of {BLOCK}"
    n_steps = n // BLOCK
    return pl.pallas_call(
        functools.partial(_dot_kernel, n_steps=n_steps),
        grid=(n_steps,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,)),
                  pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM(LANES, jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x, y)[0, 0]


def dotproduct_xla(x, y):
    return jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))
