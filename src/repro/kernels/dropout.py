"""Dropout kernel (paper pool, mask-unit exercise).

Random bits are precomputed (streamed from memory, as in the Ara2 kernel);
the kernel applies the keep-mask and the 1/(1-rate) rescale - this is the
MASKU workload of Table 2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _dropout_kernel(x_ref, bits_ref, o_ref, *, rate: float):
    x = x_ref[...]
    u = bits_ref[...].astype(jnp.float32) / np.float32(2 ** 32)
    keep = u >= rate
    o_ref[...] = jnp.where(keep, x / (1.0 - rate), 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rate", "block", "interpret"))
def dropout_pallas(x, bits, *, rate: float, block=1024, interpret=False):
    (n,) = x.shape
    block = min(block, n)
    assert n % block == 0
    return pl.pallas_call(
        functools.partial(_dropout_kernel, rate=rate),
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x, bits)


def dropout_xla(x, bits, *, rate: float):
    from .ref import dropout_ref
    return dropout_ref(x, bits, rate)
