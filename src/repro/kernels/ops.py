"""Kernel dispatch layer.

Every kernel has up to three implementations:
  * ``pallas``    - the TPU target (pl.pallas_call + BlockSpec VMEM tiling);
  * ``interpret`` - the same kernel body executed in interpret mode
    (CPU-validated against ref.py in tests);
  * ``xla``       - pure-jnp production path, used on CPU and for the
    dry-run lowering so cost_analysis() reflects clean HLO.

Default: ``xla`` on CPU hosts, ``pallas`` when a TPU backend is present.
"""
from __future__ import annotations

import contextlib
import functools
import os

import jax

from . import ref
from .attention import (attention_xla, decode_attention_xla,
                        flash_attention_pallas)
from .pallas_compat import tpu_compiler_params  # noqa: F401 (re-export)
from .conv2d import conv2d_pallas, conv2d_xla
from .dotproduct import dotproduct_pallas, dotproduct_xla
from .dropout import dropout_pallas, dropout_xla
from .dwt import dwt_haar_pallas, dwt_haar_xla
from .expk import exp_pallas, exp_xla
from .fft import fft_pallas, fft_xla
from .jacobi2d import jacobi2d_pallas, jacobi2d_xla
from .matmul import matmul_pallas, matmul_xla
from .paged_attention import (paged_decode_attention_pallas,
                              paged_decode_attention_xla,
                              paged_prefill_attention_pallas,
                              paged_prefill_attention_xla)
from .pathfinder import pathfinder_pallas, pathfinder_xla
from .roi_align import roi_align_xla
from .softmax import softmax_pallas, softmax_xla
from .ssd_scan import ssd_pallas, ssd_step_xla, ssd_xla

_IMPL: str | None = None  # resolved lazily


def default_impl() -> str:
    global _IMPL
    if _IMPL is None:
        # REPRO_KERNEL_IMPL overrides the backend default (CI runs the
        # serving/kernel suites a second time with =interpret so the
        # Pallas paged prefill/decode bodies execute on the CPU runner)
        env = os.environ.get("REPRO_KERNEL_IMPL")
        if env:
            if env not in ("pallas", "interpret", "xla"):
                raise ValueError(
                    f"REPRO_KERNEL_IMPL={env!r}: expected pallas, "
                    "interpret, or xla")
            _IMPL = env
        else:
            _IMPL = "pallas" if jax.default_backend() == "tpu" else "xla"
    return _IMPL


def set_impl(impl: str) -> None:
    global _IMPL
    assert impl in ("pallas", "interpret", "xla")
    _IMPL = impl


@contextlib.contextmanager
def impl_scope(impl: str):
    global _IMPL
    prev = _IMPL
    set_impl(impl)
    try:
        yield
    finally:
        _IMPL = prev


def _dispatch(impl, pallas_fn, xla_fn):
    impl = impl or default_impl()
    if impl == "xla" or pallas_fn is None:
        return xla_fn, {}
    return pallas_fn, {"interpret": impl == "interpret"}


# ---------------------------------------------------------------------------
# Public ops.
# ---------------------------------------------------------------------------

def matmul(x, w, *, impl=None, out_dtype=None, **kw):
    fn, extra = _dispatch(impl, matmul_pallas, matmul_xla)
    return fn(x, w, out_dtype=out_dtype, **extra, **kw)


def attention(q, k, v, *, impl=None, causal=True, window=None, scale=None,
              kv_len=None, **kw):
    impl = impl or default_impl()
    if impl == "xla" or kv_len is not None:
        # kv_len masking (serving) goes through the scan path.
        return attention_xla(q, k, v, causal=causal, window=window,
                             scale=scale, kv_len=kv_len, **kw)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  scale=scale, interpret=impl == "interpret",
                                  **kw)


def decode_attention(q, k_cache, v_cache, kv_len, *, scale=None, window=None):
    return decode_attention_xla(q, k_cache, v_cache, kv_len, scale=scale,
                                window=window)


def paged_decode_attention(q, k_pool, v_pool, block_table, kv_len, *,
                           impl=None, scale=None, window=None):
    """Single-token attention against a paged KV pool via a block table.

    Sliding windows ride the jnp gather path (the Pallas kernel keeps the
    prefix-mask fast path; traced per-layer windows would defeat its
    block-skip predicate anyway)."""
    impl = impl or default_impl()
    if impl == "xla" or window is not None:
        return paged_decode_attention_xla(q, k_pool, v_pool, block_table,
                                          kv_len, scale=scale, window=window)
    return paged_decode_attention_pallas(q, k_pool, v_pool, block_table,
                                         kv_len, scale=scale,
                                         interpret=impl == "interpret")


def paged_prefill_attention(q, k_pool, v_pool, block_table, q_start, *,
                            impl=None, scale=None, window=None):
    """One prompt chunk's causal attention against a paged KV pool (the
    chunk's K/V must already sit in its block).  Sliding windows ride the
    per-block gather path, same as decode (traced per-layer windows would
    defeat the Pallas block-skip predicate)."""
    impl = impl or default_impl()
    if impl == "xla" or window is not None:
        return paged_prefill_attention_xla(q, k_pool, v_pool, block_table,
                                           q_start, scale=scale,
                                           window=window)
    return paged_prefill_attention_pallas(q, k_pool, v_pool, block_table,
                                          q_start, scale=scale,
                                          interpret=impl == "interpret")


def ssd_scan(x, dt, a_log, b_mat, c_mat, *, impl=None, d_skip=None, h0=None,
             chunk=64):
    impl = impl or default_impl()
    if impl == "xla" or h0 is not None:
        return ssd_xla(x, dt, a_log, b_mat, c_mat, d_skip=d_skip, h0=h0,
                       chunk=chunk)
    y, h = ssd_pallas(x, dt, a_log, b_mat, c_mat, chunk=chunk,
                      interpret=impl == "interpret")
    if d_skip is not None:
        y = y + (d_skip[None, None, :, None] * x).astype(y.dtype)
    return y, h


def ssd_step(h_state, xt, dtt, a_log, bt, ct, *, d_skip=None):
    return ssd_step_xla(h_state, xt, dtt, a_log, bt, ct, d_skip=d_skip)


def dotproduct(x, y, *, impl=None):
    fn, extra = _dispatch(impl, dotproduct_pallas, dotproduct_xla)
    return fn(x, y, **extra)


def softmax(x, *, impl=None, **kw):
    fn, extra = _dispatch(impl, softmax_pallas, softmax_xla)
    return fn(x, **extra, **kw)


def exp(x, *, impl=None, **kw):
    fn, extra = _dispatch(impl, exp_pallas, exp_xla)
    return fn(x, **extra, **kw)


def dropout(x, bits, *, rate, impl=None, **kw):
    fn, extra = _dispatch(impl, dropout_pallas, dropout_xla)
    return fn(x, bits, rate=rate, **extra, **kw)


def conv2d(x, w, *, impl=None, **kw):
    fn, extra = _dispatch(impl, conv2d_pallas, conv2d_xla)
    return fn(x, w, **extra, **kw)


def jacobi2d(x, *, impl=None, **kw):
    impl = impl or default_impl()
    if impl == "xla":
        return jacobi2d_xla(x, **kw)
    return jacobi2d_pallas(x, interpret=impl == "interpret", **kw)


def dwt_haar(x, *, levels=1, impl=None, **kw):
    fn, extra = _dispatch(impl, dwt_haar_pallas, dwt_haar_xla)
    return fn(x, levels=levels, **extra, **kw)


def pathfinder(w, *, impl=None, **kw):
    fn, extra = _dispatch(impl, pathfinder_pallas, pathfinder_xla)
    return fn(w, **extra, **kw)


def fft(x_re, x_im, *, impl=None, **kw):
    fn, extra = _dispatch(impl, fft_pallas, fft_xla)
    return fn(x_re, x_im, **extra, **kw)


def roi_align(feat, rois, *, impl=None, **kw):
    # Pallas variant intentionally absent (gather-bound; see module doc).
    return roi_align_xla(feat, rois, **kw)
