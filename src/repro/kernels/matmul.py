"""Lane-tiled GEMM Pallas kernel (TPU target; paper kernel `matmul`).

Ara2 stripes the output row vector across lanes (C1); here the N dimension is
the lane axis: each grid column ``j`` is a lane-block of 128 output columns
(one MXU tile), and the VMEM accumulator plays the VRF's data-reuse role
("L0 storage ... to buffer data elements re-used multiple times close to the
PEs", §2).  K is the sequential grid axis; the fp32 accumulator lives in VMEM
scratch across K steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import tpu_compiler_params

# MXU-aligned default tiles (multiples of 128 on both matmul dims).
DEFAULT_BM, DEFAULT_BN, DEFAULT_BK = 128, 128, 128


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "interpret"))
def matmul_pallas(x, w, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK,
                  out_dtype=None, interpret=False):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    out_dtype = out_dtype or x.dtype
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"unpadded shapes {(m, n, k)} vs blocks {(bm, bn, bk)}"
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)


def matmul_xla(x, w, out_dtype=None):
    """Production XLA path (used on CPU and for dry-run lowering)."""
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(out_dtype)
