"""Elementwise exp kernel using the paper's software-polynomial scheme.

Ara2's `exp` benchmark emulates exponentiation with preloaded approximation
coefficients (§4).  We do the same: range reduction x = n*ln2 + r, a degree-6
polynomial on r, and 2^n via exponent-field bit assembly (no transcendental
hardware assumed - the VPU analogue of the paper's software exp).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LOG2E = 1.4426950408889634
LN2_HI = 0.6931471805599453
# Taylor coefficients 1/k! for k=0..6 (|r| <= ln2/2 -> ~1e-7 rel err).
_COEFFS = (1.0, 1.0, 0.5, 1.0 / 6, 1.0 / 24, 1.0 / 120, 1.0 / 720)


def _exp_poly(x):
    x = x.astype(jnp.float32)
    n = jnp.round(x * LOG2E)
    r = x - n * LN2_HI
    p = jnp.full_like(r, _COEFFS[-1])
    for c in _COEFFS[-2::-1]:
        p = p * r + c
    # 2^n via exponent bit assembly: ((n + 127) << 23).bitcast(f32)
    ni = jnp.clip(n, -126, 127).astype(jnp.int32)
    two_n = jax.lax.bitcast_convert_type((ni + 127) << 23, jnp.float32)
    return p * two_n


def _exp_kernel(x_ref, o_ref):
    o_ref[...] = _exp_poly(x_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def exp_pallas(x, *, block=1024, interpret=False):
    (n,) = x.shape
    block = min(block, n)
    assert n % block == 0
    return pl.pallas_call(
        _exp_kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x)


def exp_xla(x):
    return jnp.exp(x)
