"""1-D Haar DWT kernel (paper pool; the strided-memory-op exercise).

The even/odd deinterleave is the paper's 'misaligned strided memory access'
workload; on TPU it is a (n/2, 2) reshape in VMEM.  One level per kernel
call; the wrapper recurses on the approximation half.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_INV_SQRT2 = np.float32(1.0 / np.sqrt(2.0))


def _dwt_kernel(x_ref, lo_ref, hi_ref):
    x = x_ref[...].astype(jnp.float32).reshape(-1, 2)
    even, odd = x[:, 0], x[:, 1]
    lo_ref[...] = ((even + odd) * _INV_SQRT2).astype(lo_ref.dtype)
    hi_ref[...] = ((even - odd) * _INV_SQRT2).astype(hi_ref.dtype)


def _dwt_level_pallas(x, *, block, interpret):
    (n,) = x.shape
    bn = min(block, n // 2)
    assert (n // 2) % bn == 0
    return pl.pallas_call(
        _dwt_kernel,
        grid=(n // 2 // bn,),
        in_specs=[pl.BlockSpec((2 * bn,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((bn,), lambda i: (i,)),
                   pl.BlockSpec((bn,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n // 2,), x.dtype),
                   jax.ShapeDtypeStruct((n // 2,), x.dtype)],
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("levels", "block", "interpret"))
def dwt_haar_pallas(x, *, levels=1, block=512, interpret=False):
    (n,) = x.shape
    out = x
    parts = []
    cur = out
    for _ in range(levels):
        lo, hi = _dwt_level_pallas(cur, block=block, interpret=interpret)
        parts.insert(0, hi)
        cur = lo
    parts.insert(0, cur)
    return jnp.concatenate(parts)


def dwt_haar_xla(x, levels=1):
    from .ref import dwt_haar_ref
    return dwt_haar_ref(x, levels)
