"""2-D convolution kernel (paper pool: the 3x7x7 fconv2d).

Mirrors the Ara2 kernel's data reuse: a block of output rows stays resident
(the paper keeps 7 output vectors in the VRF per loaded input row); the 147
tap contributions are fully unrolled VPU FMAs over (rows, W) tiles.  Row
overlap between blocks is handled with a dynamic row slice from a
VMEM-resident input (benchmark-size images), not re-fetched from HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv2d_kernel(x_ref, w_ref, o_ref, *, k: int, br: int, c: int):
    i = pl.program_id(0)
    w_out = o_ref.shape[1]
    acc = jnp.zeros((br, w_out), jnp.float32)
    rows = x_ref[:, pl.dslice(i * br, br + k - 1), :]  # (C, br+k-1, W)
    for ci in range(c):
        for ki in range(k):
            for kj in range(k):
                acc += w_ref[ci, ki, kj] * rows[ci, ki:ki + br, kj:kj + w_out]
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def conv2d_pallas(x, w, *, block_rows=8, interpret=False):
    c, h, ww = x.shape
    _, k, _ = w.shape
    h_out, w_out = h - k + 1, ww - k + 1
    br = min(block_rows, h_out)
    assert h_out % br == 0, (h_out, br)
    return pl.pallas_call(
        functools.partial(_conv2d_kernel, k=k, br=br, c=c),
        grid=(h_out // br,),
        in_specs=[pl.BlockSpec((c, h, ww), lambda i: (0, 0, 0)),
                  pl.BlockSpec((c, k, k), lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((br, w_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h_out, w_out), x.dtype),
        interpret=interpret,
    )(x, w)


def conv2d_xla(x, w):
    from .ref import conv2d_ref
    return conv2d_ref(x, w)
