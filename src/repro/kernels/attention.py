"""Attention kernels: Pallas flash forward (TPU target) + chunked-scan XLA
implementation (production path on CPU / for dry-run lowering; differentiable,
O(S) memory via online softmax — never materializes the S x S score matrix).

GQA is native: q (B, Hq, S, D) against k/v (B, Hkv, S, D), Hq % Hkv == 0.
Supports causal masking, sliding windows (gemma3's 5:1 local:global pattern)
and per-batch effective kv lengths (serving).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import tpu_compiler_params

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Pallas flash-attention forward.
# ---------------------------------------------------------------------------

def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int | None,
                  bq: int, bk: int, sq: int, sk: int, kv_steps: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)           # (bk, d)
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = pl.program_id(2) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0) + (sk - sq)       # right-aligned queries
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(logits, axis=-1)[:, None]     # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)[:, None]
    v = v_ref[0, 0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...], l_ref[...] = m_new, l_new

    @pl.when(ik == kv_steps - 1)
    def _flush():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=None, scale=None,
                           bq=128, bk=128, interpret=False):
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    scale = float(scale if scale is not None else 1.0 / np.sqrt(d))
    bq, bk = min(bq, sq), min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0
    kv_steps = sk // bk
    grid = (b, hq, sq // bq, kv_steps)
    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             window=window, bq=bq, bk=bk, sq=sq, sk=sk,
                             kv_steps=kv_steps)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, iq, ik, g_=g: (b_, h // g_, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, iq, ik, g_=g: (b_, h // g_, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Chunked-scan XLA implementation (flash algorithm in pure jnp) with a
# custom-VJP flash backward: residuals are O(S) (out + logsumexp), gradients
# recompute score blocks kv-chunk-wise - the standard flash-attention
# backward, in jnp.  Without this, scan-of-softmax saves O(S^2) residuals
# and a 4k-context training step needs ~15 GB/device (measured in the
# dry-run; see EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------

def _mask_block(qpos, kpos, causal, window):
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), jnp.bool_)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    return mask


def _mask_block_f(qpos, kpos, causal, window_f):
    """Float-window variant: window rides as an f32 operand so traced
    per-layer windows (gemma3's 5:1 pattern under scan) work through the
    custom-VJP.  1e30 disables the window."""
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), jnp.bool_)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    mask &= kpos[None, :].astype(jnp.float32) \
        > qpos[:, None].astype(jnp.float32) - window_f
    return mask


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _flash(q5, kc, vc, window_f, scale, causal, q_offset, kv_chunk):
    out, _ = _flash_fwd_impl(q5, kc, vc, window_f, scale, causal, q_offset,
                             kv_chunk)
    return out


def _flash_fwd_impl(q5, kc, vc, window_f, scale, causal, q_offset, kv_chunk):
    """q5: (B, Hkv, G, Sq, D) fp32; kc/vc: (B, Hkv, Sk, D) fp32.
    Returns (out, lse) with lse: (B, Hkv, G, Sq, 1)."""
    b, hkv, g, sq, d = q5.shape
    sk = kc.shape[2]
    nk = sk // kv_chunk
    qpos = q_offset + jnp.arange(sq)
    qf = q5 * scale

    def kv_step(carry, ik):
        m_prev, l_prev, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(kc, ik * kv_chunk, kv_chunk, 2)
        vb = jax.lax.dynamic_slice_in_dim(vc, ik * kv_chunk, kv_chunk, 2)
        kpos = ik * kv_chunk + jnp.arange(kv_chunk)
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kb)
        mask = _mask_block_f(qpos, kpos, causal, window_f)[None, None, None]
        logits = jnp.where(mask, logits, NEG_INF)
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p, vb)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = acc / jnp.where(l == 0.0, 1.0, l)
    return out, lse


def _flash_fwd(q5, kc, vc, window_f, scale, causal, q_offset, kv_chunk):
    out, lse = _flash_fwd_impl(q5, kc, vc, window_f, scale, causal, q_offset,
                               kv_chunk)
    return out, (q5, kc, vc, window_f, out, lse)


def _flash_bwd(scale, causal, q_offset, kv_chunk, res, dout):
    q5, kc, vc, window_f, out, lse = res
    b, hkv, g, sq, d = q5.shape
    sk = kc.shape[2]
    nk = sk // kv_chunk
    qpos = q_offset + jnp.arange(sq)
    qf = q5 * scale
    delta = jnp.sum(dout * out, axis=-1, keepdims=True)   # (B,Hkv,G,Sq,1)

    def kv_step(dq_acc, ik):
        kb = jax.lax.dynamic_slice_in_dim(kc, ik * kv_chunk, kv_chunk, 2)
        vb = jax.lax.dynamic_slice_in_dim(vc, ik * kv_chunk, kv_chunk, 2)
        kpos = ik * kv_chunk + jnp.arange(kv_chunk)
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kb)
        mask = _mask_block_f(qpos, kpos, causal, window_f)[None, None, None]
        logits = jnp.where(mask, logits, NEG_INF)
        p = jnp.exp(logits - lse)                          # (B,Hkv,G,Sq,K)
        dv = jnp.einsum("bhgqk,bhgqd->bhkd", p, dout)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", dout, vb)
        ds = p * (dp - delta)
        dq_acc = dq_acc + jnp.einsum("bhgqk,bhkd->bhgqd", ds, kb) * scale
        dk = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qf)
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros_like(q5)
    dq, (dks, dvs) = jax.lax.scan(kv_step, dq0, jnp.arange(nk))
    dk = jnp.moveaxis(dks, 0, 2).reshape(b, hkv, sk, d)
    dv = jnp.moveaxis(dvs, 0, 2).reshape(b, hkv, sk, d)
    return dq, dk, dv, jnp.zeros((), jnp.float32)


_flash.defvjp(_flash_fwd, _flash_bwd)


def attention_xla(q, k, v, *, causal=True, window=None, scale=None,
                  kv_len=None, q_chunk=1024, kv_chunk=1024):
    """Flash attention in jnp: q-chunked outer map, custom-VJP kv-chunked
    inner scan.  O(S) residuals; peak temp = B*Hq*q_chunk*kv_chunk logits."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    scale = float(scale if scale is not None else 1.0 / np.sqrt(d))
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    assert sq % q_chunk == 0 and sk % kv_chunk == 0
    nq = sq // q_chunk

    if kv_len is not None:
        # serving path (no gradients): per-batch kv_len masking, plain scan
        return _attention_kvlen(q, k, v, causal=causal, window=window,
                                scale=scale, kv_len=kv_len,
                                kv_chunk=kv_chunk)

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # Python-unrolled q-chunk loop: q_offset stays static, which (a) keeps
    # the custom-VJP nondiff args hashable and (b) lets causal chunks skip
    # KV blocks beyond their triangle entirely (no masked-out compute).
    outs = []
    for iq in range(nq):
        q_off = iq * q_chunk + (sk - sq)
        if causal:
            kv_hi = min(sk, -(-(q_off + q_chunk) // kv_chunk) * kv_chunk)
        else:
            kv_hi = sk
        qb = q[:, :, iq * q_chunk:(iq + 1) * q_chunk]
        q5 = qb.astype(jnp.float32).reshape(b, hkv, g, q_chunk, d)
        wf = (jnp.float32(1e30) if window is None
              else jnp.asarray(window, jnp.float32))
        out = _flash(q5, kf[:, :, :kv_hi], vf[:, :, :kv_hi], wf, scale,
                     causal, q_off, kv_chunk)
        outs.append(out.reshape(b, hq, q_chunk, d).astype(q.dtype))
    return outs[0] if nq == 1 else jnp.concatenate(outs, axis=2)


def _attention_kvlen(q, k, v, *, causal, window, scale, kv_len, kv_chunk):
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    nk = sk // kv_chunk
    qpos = jnp.arange(sq) + (sk - sq)
    qf = q.astype(jnp.float32).reshape(b, hkv, g, sq, d) * scale

    def kv_step(carry, ik):
        m_prev, l_prev, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k, ik * kv_chunk, kv_chunk, 2)
        vb = jax.lax.dynamic_slice_in_dim(v, ik * kv_chunk, kv_chunk, 2)
        kpos = ik * kv_chunk + jnp.arange(kv_chunk)
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kb.astype(jnp.float32))
        mask = _mask_block(qpos, kpos, causal, window)[None, None, None]
        mask = mask & (kpos[None, None, None, None, :]
                       < kv_len[:, None, None, None, None])
        logits = jnp.where(mask, logits, NEG_INF)
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p,
                                       vb.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
    out = acc / jnp.where(l == 0.0, 1.0, l)
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def decode_attention_xla(q, k_cache, v_cache, kv_len, *, scale=None,
                         window=None):
    """Single-token GQA attention against a (B, Hkv, Smax, D) cache.
    ``kv_len``: (B,) valid lengths (the new token is at kv_len-1)."""
    b, hq, _, d = q.shape
    _, hkv, smax, _ = k_cache.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d) * scale
    logits = jnp.einsum("bhgd,bhsd->bhgs", qf, k_cache.astype(jnp.float32))
    kpos = jnp.arange(smax)[None, :]
    mask = kpos < kv_len[:, None]
    if window is not None:
        mask &= kpos > (kv_len[:, None] - 1 - window)
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, 1, d).astype(q.dtype)
