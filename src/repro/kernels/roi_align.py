"""ROI-align (paper pool).

Gather-bound, not compute-bound (Table 2: CB=N, 9/5*L OP/cycle peak from the
bilinear blend arithmetic).  The production implementation is the vectorized
XLA path; a Pallas variant would be gather-latency-bound on the MXU-less
path and is intentionally not provided (DESIGN.md §2 hardware-adaptation
notes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def roi_align_xla(feat, rois, out_size=7, sampling=2):
    """feat: (C, H, W); rois: (R, 4) [y0, x0, y1, x1].  Vectorized bilinear
    average pooling; same semantics as ``ref.roi_align_ref``."""
    c, h, w = feat.shape
    r = rois.shape[0]
    oy, ox = jnp.meshgrid(jnp.arange(out_size), jnp.arange(out_size),
                          indexing="ij")
    sy, sx = jnp.meshgrid(jnp.arange(sampling), jnp.arange(sampling),
                          indexing="ij")

    def per_roi(roi):
        y0, x0, y1, x1 = roi
        bin_h = (y1 - y0) / out_size
        bin_w = (x1 - x0) / out_size
        # sample coords: (out, out, s, s)
        y = y0 + (oy[..., None, None] + (sy + 0.5) / sampling) * bin_h
        x = x0 + (ox[..., None, None] + (sx + 0.5) / sampling) * bin_w
        y = jnp.clip(y, 0.0, h - 1.0)
        x = jnp.clip(x, 0.0, w - 1.0)
        yi = jnp.clip(jnp.floor(y).astype(jnp.int32), 0, h - 2)
        xi = jnp.clip(jnp.floor(x).astype(jnp.int32), 0, w - 2)
        dy, dx = y - yi, x - xi
        v00 = feat[:, yi, xi]
        v01 = feat[:, yi, xi + 1]
        v10 = feat[:, yi + 1, xi]
        v11 = feat[:, yi + 1, xi + 1]
        val = (v00 * (1 - dy) * (1 - dx) + v01 * (1 - dy) * dx
               + v10 * dy * (1 - dx) + v11 * dy * dx)
        return jnp.mean(val, axis=(-2, -1))  # (C, out, out)

    return jax.vmap(per_roi)(rois)
