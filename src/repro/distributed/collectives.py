"""Gradient-sync collectives: the paper's tree schedules (C3) as drop-in
alternatives to native psum, plus int8-compressed all-reduce with error
feedback (the multi-pod link is the bandwidth-scarce hop).

All functions run inside ``shard_map``.  The pjit training path gets its
gradient reduction from sharding propagation; these are used (a) by the
shard_map grad-sync benchmark comparing schedules' collective bytes and
(b) by the compressed pod-axis sync option in the trainer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from ..jax_compat import axis_size

from ..core.reduction import allreduce_hd, allreduce_rs_ag

INT8_MAX = 127.0


def psum_native(x, axis_name):
    return jax.lax.psum(x, axis_name)


def tree_allreduce(x, axis_name, *, bandwidth_optimal=True):
    """Paper C3: inter-lane log-step tree (halving/doubling)."""
    fn = allreduce_rs_ag if bandwidth_optimal else allreduce_hd
    return fn(x, axis_name)


def quantize_int8(x, *, block: int = 256):
    """Blockwise symmetric int8 quantization.  Returns (q, scales, meta)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / INT8_MAX
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -INT8_MAX, INT8_MAX
                 ).astype(jnp.int8)
    return q, scale, (x.shape, pad)


def dequantize_int8(q, scale, meta, dtype=jnp.float32):
    shape, pad = meta
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)


def compressed_allreduce(x, axis_name, *, error: jnp.ndarray | None = None,
                         block: int = 256):
    """int8 all-reduce with error feedback (two-phase, shared scale).

    Phase 1 exchanges per-block max-abs (pmax of the tiny scale vector) so
    every participant quantizes with the SAME scale - summing int8 payloads
    quantized with different scales is simply wrong (sum scale_i*q_i !=
    scale_max * sum q_i; caught by the error-feedback property test).
    Phase 2 sums the int8 payload in int32.  Link bytes: ~1/4 of fp32 plus
    the 1/BLOCK scale exchange.  Returns (mean-reduced value, new error)."""
    size = axis_size(axis_name)
    val = x if error is None else x + error
    # shared blockwise scale
    _, scale_local, meta = quantize_int8(val, block=block)
    scale = jax.lax.pmax(scale_local, axis_name)
    flat = val.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    q = jnp.clip(jnp.round(blocks / scale), -INT8_MAX, INT8_MAX
                 ).astype(jnp.int8)
    new_error = val - dequantize_int8(q, scale, meta)  # error feedback
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    summed = dequantize_int8(q_sum, scale, meta)
    return (summed / size).astype(x.dtype), new_error.astype(x.dtype)


def grad_sync(grads, axis_name, *, mode: str = "psum", error_state=None):
    """Synchronize a gradient pytree across ``axis_name``.

    mode: psum | tree_bw | tree_hd | int8.  Returns (grads, error_state)."""
    if mode == "psum":
        return jax.tree_util.tree_map(
            lambda g: jax.lax.pmean(g, axis_name), grads), error_state
    if mode in ("tree_bw", "tree_hd"):
        size = axis_size(axis_name)
        return jax.tree_util.tree_map(
            lambda g: tree_allreduce(g, axis_name,
                                     bandwidth_optimal=mode == "tree_bw")
            / size, grads), error_state
    if mode == "int8":
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        errs = (jax.tree_util.tree_leaves(error_state)
                if error_state is not None else [None] * len(leaves))
        outs, new_errs = [], []
        for g, e in zip(leaves, errs):
            o, ne = compressed_allreduce(g, axis_name, error=e)
            outs.append(o)
            new_errs.append(ne)
        return (jax.tree_util.tree_unflatten(treedef, outs),
                jax.tree_util.tree_unflatten(treedef, new_errs))
    raise ValueError(mode)
