from .act_sharding import activation_sharding, constrain
from .sharding import (ShardingPolicy, batch_shardings, cache_shardings,
                       tree_shardings)
from .mesh_policy import MeshCandidate, choose_mesh, enumerate_policies, score_policy
from . import collectives
