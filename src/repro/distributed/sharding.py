"""Sharding policies: logical-axis -> mesh-axis rules for params and
activations (DP / FSDP / TP / SP / EP + the multi-pod ``pod`` axis).

Logical param axes (from models' PT templates):
  embed   - d_model dims            -> FSDP axes (ZeRO-3) or replicated
  ffn     - MLP hidden              -> TP
  qheads  - flattened q-head dim    -> TP
  kvheads - flattened kv-head dim   -> TP (weight dim always divides; the
            *activation* head dim may not - act_sharding drops those)
  vocab   - (padded) vocabulary     -> TP
  expert  - MoE expert index        -> TP (= EP)
  dinner  - SSM/xLSTM inner dim     -> TP
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    dp_axes: tuple[str, ...] = ("data",)     # ("pod","data") multi-pod
    tp_axis: str = "model"
    fsdp: bool = True                        # shard params/opt over dp_axes
    # sequence-parallel regions: shard activations' seq dim over tp in
    # norm/elementwise regions (Megatron SP)
    sp: bool = False

    @property
    def tp_effective(self):
        """None when the model axis was absorbed into DP (pure-DP policy
        for archs too narrow to exploit TP, e.g. whisper)."""
        return None if self.tp_axis in self.dp_axes else self.tp_axis

    def param_rules(self) -> dict:
        fsdp_axes = self.dp_axes if self.fsdp else None
        tp = self.tp_effective
        return {
            "embed": fsdp_axes,
            "ffn": tp,
            "qheads": tp,
            "kvheads": tp,
            "vocab": tp,
            "expert": tp,
            "dinner": tp,
        }

    def act_rules(self) -> dict:
        batch = self.dp_axes
        tp = self.tp_effective
        seq = tp if self.sp else None
        return {
            # (B, S, D) hidden states
            "hidden": P(batch, seq, None),
            # (B, H, S, hd) attention activations
            "heads": P(batch, tp, None, None),
            # (B, chunk, V) fused-xent logits: vocab over TP
            "logits": P(batch, None, tp),
            # (B, E, C, d) MoE dispatch buffer: batch over DP, experts over TP
            "moe_dispatch": P(batch, tp, None, None),
            # (G, T_g, d) token groups at the MoE region boundary: the group
            # dim shards over dp AND tp (full EP: one ~4096-token group per
            # chip); gathers/scatters stay shard-local
            "moe_tokens": P(batch + ((tp,) if tp else ()), None, None),
            # (G, E, C, d) group-sharded dispatch buffer pre/post all-to-all
            "moe_groups": P(batch + ((tp,) if tp else ()), None, None, None),
        }

    def batch_spec(self, ndim: int = 2) -> P:
        return P(self.dp_axes, *([None] * (ndim - 1)))


def replicated(mesh):
    return NamedSharding(mesh, P())


def tree_shardings(mesh, pspec_tree):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(mesh, batch_specs, policy: ShardingPolicy):
    """Input-batch shardings: leading dim over dp axes (seq dims whole)."""
    def leaf(sds):
        nd = len(sds.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        # shard dim 0 (batch) when divisible
        dp = 1
        for a in policy.dp_axes:
            dp *= mesh.shape[a]
        if sds.shape[0] % dp == 0 and sds.shape[0] > 0:
            return NamedSharding(mesh, P(policy.dp_axes,
                                         *([None] * (nd - 1))))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map(leaf, batch_specs)


def cache_shardings(mesh, cache_specs, policy: ShardingPolicy,
                    batch_size: int | None = None):
    """Decode-cache shardings, layout-aware by key:

      attention caches  k/v/attn_k/attn_v/xk/xv: (L|G, B, Hkv, S, hd)
        -> batch over dp, cache-seq over tp (the big dims; Hkv rarely
           divides tp);
      SSM/xLSTM states  conv/ssm/m_*/s_*: (L|G[,k], B, ...)
        -> batch over dp, largest trailing dim over tp when divisible.
    """
    dp = 1
    for a in policy.dp_axes:
        dp *= mesh.shape[a]
    tp_axis = policy.tp_effective
    tp = mesh.shape[tp_axis] if tp_axis else 1

    def leaf(path, sds):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = sds.shape
        if not shape:
            return NamedSharding(mesh, P())
        spec: list = [None] * len(shape)
        is_attn = key in ("k", "v", "attn_k", "attn_v", "xk", "xv")
        # batch dim: attention layout dim 1; state layouts dim 1 or 2
        bdims = (1,) if is_attn else (1, 2)
        for bd in bdims:
            if bd < len(shape) and shape[bd] % dp == 0 and shape[bd] >= dp \
                    and (batch_size is None or shape[bd] == batch_size):
                spec[bd] = policy.dp_axes
                break
        if is_attn and len(shape) >= 5:
            sd = len(shape) - 2          # cache sequence dim
            if tp_axis and shape[sd] % tp == 0 and shape[sd] >= tp:
                spec[sd] = tp_axis
        else:
            # shard the largest trailing state dim over tp
            cands = sorted(range(1, len(shape)),
                           key=lambda i: -shape[i])
            for cand in cands:
                if tp_axis and spec[cand] is None and shape[cand] % tp == 0 \
                        and shape[cand] >= tp * 4:
                    spec[cand] = tp_axis
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf, cache_specs)
