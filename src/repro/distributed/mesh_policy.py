"""The multi-core vector-processor trade-off, transplanted (paper C4, §7).

Paper frame: at a fixed FPU budget, choose cores x lanes; many small cores
win on short vectors (second parallel dimension, higher bytes/lane), one big
core wins on long vectors.  TPU frame: at a fixed chip budget, choose
(data, model) - many small TP groups (large DP) win when per-step work per
chip is small (short sequences / small batch shards / decode), large TP
groups win when the model doesn't fit or per-chip work saturates.

``score_policy`` is the napkin-math roofline (compute/memory/collective +
the issue-overhead term that plays CVA6's role); ``choose_mesh`` ranks all
factorizations.  The analytical model here mirrors roofline/analysis.py's
measured terms and is validated against them in the benchmarks.
"""
from __future__ import annotations

import dataclasses

from ..configs.base import ModelConfig, ShapeConfig
from ..core.ppa import TPU_V5E, TpuSpec
from ..models.layers import param_count


@dataclasses.dataclass(frozen=True)
class MeshCandidate:
    dp: int
    tp: int
    # analytical per-step time terms (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    t_issue: float = 0.0
    fits: bool = True

    @property
    def t_total(self) -> float:
        # compute/memory overlap on TPU; collectives partially overlap -
        # conservative: max(compute, memory) + collective + issue
        return max(self.t_compute, self.t_memory) \
            + self.t_collective + self.t_issue

    def describe(self) -> str:
        return f"dp{self.dp}xtp{self.tp}"


# Fixed per-step overhead playing the scalar-core issue-rate role: host
# dispatch + collective alpha terms (~1.5us per hop) per layer.
ISSUE_OVERHEAD_S = 100e-6
ALPHA_PER_COLLECTIVE_S = 1.5e-6


def _model_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> float:
    try:
        from ..models.model import build_model
        return param_count(build_model(cfg).templates) * dtype_bytes
    except Exception:
        return 0.0


def _step_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS: 6*N_active*D train, 2*N_active*D decode/prefill-token."""
    n = _active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def _active_params(cfg: ModelConfig) -> float:
    n = param_count(__import__(
        "repro.models.model", fromlist=["build_model"]).build_model(cfg).templates)
    if cfg.n_experts:
        # replace full expert count by top_k active experts
        moe_params = cfg.n_layers * cfg.n_experts * 3 * cfg.d_model * cfg.d_ff
        active = cfg.n_layers * cfg.top_k * 3 * cfg.d_model * cfg.d_ff
        n = n - moe_params + active
    return float(n)


def score_policy(cfg: ModelConfig, shape: ShapeConfig, dp: int, tp: int,
                 spec: TpuSpec = TPU_V5E, grad_sync: bool = True
                 ) -> MeshCandidate:
    chips = dp * tp
    pbytes = _model_bytes(cfg)
    flops = _step_flops(cfg, shape)
    if shape.kind == "train":
        flops_eff = flops  # fwd+bwd counted by the 6x multiplier
    else:
        flops_eff = flops

    t_compute = flops_eff / (chips * spec.peak_bf16_flops)

    # memory: weights stream once per step per TP group member (decode) or
    # amortized over tokens (train); activations ~2 bytes x tokens x d x L.
    weight_bytes_per_chip = pbytes / (tp * (dp if grad_sync else 1)) \
        if shape.kind == "train" else pbytes / tp
    act_bytes = 4.0 * shape.global_batch * \
        (shape.seq_len if shape.kind != "decode" else 1) * \
        cfg.d_model * cfg.n_layers / chips
    t_memory = (weight_bytes_per_chip + act_bytes) / spec.hbm_bw

    # collectives: TP all-reduce of activations per layer (2 per layer:
    # attn-out + mlp-out) + DP gradient reduce-scatter/all-gather.
    tokens_per_dp = shape.global_batch * \
        (shape.seq_len if shape.kind != "decode" else 1) / dp
    tp_bytes = 0.0 if tp == 1 else \
        2 * cfg.n_layers * 2 * tokens_per_dp * cfg.d_model * 2 * (tp - 1) / tp
    dp_bytes = 0.0
    if shape.kind == "train" and dp > 1:
        dp_bytes = 2 * (pbytes * 2 / tp) * (dp - 1) / dp  # fp32 grads rs+ag
    t_collective = (tp_bytes / tp + dp_bytes / dp) / spec.ici_link_bw

    n_colls = cfg.n_layers * (2 if tp > 1 else 0) + (1 if dp_bytes else 0)
    t_issue = ISSUE_OVERHEAD_S + n_colls * ALPHA_PER_COLLECTIVE_S

    # capacity check: params (bf16) + optimizer (12B/param over all chips
    # when FSDP) + workspace
    if shape.kind == "train":
        state = pbytes / 2 * 14 / (dp * tp)  # fsdp: params+master+m+v
    else:
        state = pbytes / tp
    fits = state < spec.hbm_bytes * 0.85

    return MeshCandidate(dp, tp, t_compute, t_memory, t_collective, t_issue,
                         fits)


def enumerate_policies(chips: int):
    out = []
    tp = 1
    while tp <= chips:
        if chips % tp == 0:
            out.append((chips // tp, tp))
        tp *= 2
    return out


def choose_mesh(cfg: ModelConfig, shape: ShapeConfig, chips: int = 256,
                spec: TpuSpec = TPU_V5E) -> list[MeshCandidate]:
    """All candidates, best first (the Fig 13/17 ranking for this cell)."""
    cands = [score_policy(cfg, shape, dp, tp, spec)
             for dp, tp in enumerate_policies(chips)]
    return sorted(cands, key=lambda c: (not c.fits, c.t_total))
