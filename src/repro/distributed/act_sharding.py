"""Activation-sharding shim.

Models call ``constrain(x, kind)`` at layer boundaries; outside a
distribution context this is a no-op, inside one it applies
``with_sharding_constraint`` per the active policy's activation rules.
Keeping this as a context (not plumbed arguments) keeps model code free of
mesh details while still letting the launcher pin the sharding of every
major activation (GSPMD then propagates the rest).
"""
from __future__ import annotations

import contextlib
import threading

import jax

_STATE = threading.local()


def _rules():
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def activation_sharding(mesh, rules: dict):
    """rules: kind -> PartitionSpec.  Specs with axes that do not divide the
    corresponding dimension are dropped at constraint time."""
    prev_rules = getattr(_STATE, "rules", None)
    prev_mesh = getattr(_STATE, "mesh", None)
    _STATE.rules, _STATE.mesh = rules, mesh
    try:
        yield
    finally:
        _STATE.rules, _STATE.mesh = prev_rules, prev_mesh


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, (tuple, list)):
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axes]


def constrain(x, kind: str):
    rules = _rules()
    if rules is None or kind not in rules:
        return x
    mesh = _STATE.mesh
    spec = rules[kind]
    if spec is None:
        return x
    # divisibility fallback: for tuple entries, drop TRAILING axes until the
    # dim divides (e.g. 64 MoE groups under ("pod","data","model")=512 fall
    # back to ("pod","data")=32 instead of losing the constraint entirely);
    # scalar entries drop to None
    from jax.sharding import NamedSharding, PartitionSpec as P

    def fit(axes, dim_size):
        if axes is None:
            return None
        cand = (axes,) if isinstance(axes, str) else tuple(axes)
        while cand:
            size = _axis_size(mesh, cand)
            if size > 1 and dim_size % size == 0:
                return cand if len(cand) > 1 else cand[0]
            cand = cand[:-1]
        return None

    fixed = [fit(axes, x.shape[dim]) for dim, axes in
             enumerate(list(spec) + [None] * (x.ndim - len(spec)))]
    if all(a is None for a in fixed):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))
