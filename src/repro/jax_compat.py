"""jax version-drift shims used across the package.

Kept import-cycle-free (imports jax only).  Mesh construction drift is
handled in ``repro.launch.mesh.make_mesh``; Pallas CompilerParams drift in
``repro.kernels.pallas_compat``.
"""
from __future__ import annotations

import jax


def axis_size(axis_name) -> int:
    """Static size of a mapped axis inside shard_map.

    Newer jax exposes ``jax.lax.axis_size``; on older releases the
    time-honored ``psum(1, axis)`` idiom constant-folds to a Python int.
    """
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)
