"""Production meshes.  Defined as functions so importing this module never
touches jax device state (required by the dry-run contract).

``make_mesh`` doubles as the jax API-drift shim: newer jax exposes
``jax.sharding.AxisType`` and ``jax.make_mesh(..., axis_types=...)``, older
releases have neither.  All mesh construction (src, tests, examples) goes
through here so the drift is handled exactly once.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh across versions (with/without AxisType / axis_types)."""
    shape, axes = tuple(shape), tuple(axes)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def mesh_desc(mesh) -> str:
    return "x".join(f"{k}{v}" for k, v in mesh.shape.items())
