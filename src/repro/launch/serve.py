"""Serving launcher: continuous-batching generation over the Model API.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --prompts "1 2 3" "4 5" --max-new 16

Every cell of the scheduler matrix (see docs/serving.md) is reachable
from here: ``--mode`` picks the scheduler (continuous/lockstep),
``--kv-layout`` the cache layout (dense/paged; scan families — ssm,
hybrid, encdec — serve continuous on dense), ``--admission`` the paged
admission policy (reserve/overcommit), ``--bucket`` the prefill
bucketing, and ``--replicas N`` (N > 1) serves through a multi-replica
cluster instead: N narrow engines behind a ``--router`` policy — sharing
one KV block pool with preemption under pool pressure for paged
families, per-replica slot state for scan families (see
repro.serving.cluster).  ``--driver threaded`` steps the cluster's
replicas on worker threads (overlapped dispatch, byte-identical
tokens); ``--stream`` prints every token the moment it is sampled
through the streaming generator API instead of waiting for full
completions.  ``--policy`` picks the scheduling policy
(fifo/priority/edf/slo_adaptive) and ``--slo-ttft``/``--slo-tpot``
attach per-request latency budgets, printed back as SLO attainment.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax

from ..configs import get_config, list_archs, smoke_config
from ..models import build_model
from ..serving import (DRIVERS, POLICIES, ROUTER_POLICIES, Attributor,
                       ClusterEngine, Request, ServeEngine, Tracer)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompts", nargs="+", default=["1 2 3", "7 8"],
                    help="space-separated token ids per prompt")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "continuous", "lockstep"])
    ap.add_argument("--kv-layout", default="dense",
                    choices=["dense", "paged"],
                    help="slot cache layout (scan families serve on "
                         "dense; paged needs transformer block hooks)")
    ap.add_argument("--admission", default=None,
                    choices=["reserve", "overcommit"],
                    help="paged admission: worst-case reservation vs "
                         "first-chunk overcommit (default: reserve for a "
                         "single engine, overcommit + preemption for a "
                         "cluster)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged layout: KV positions per pool block")
    ap.add_argument("--n-blocks", type=int, default=None,
                    help="paged layout: pool size (default: the dense "
                         "footprint, max_batch * cache_len positions)")
    ap.add_argument("--bucket", default=None,
                    help="prefill length bucketing: 'pow2' or an integer "
                         "pad-to-multiple (default: exact lengths)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged layout: admit shared prompt prefixes by "
                         "referencing resident pool blocks (refcounted, "
                         "copy-on-write; see docs/serving.md)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through a cluster of this many engine "
                         "replicas sharing one KV pool (--max-batch is the "
                         "cluster's total slot budget)")
    ap.add_argument("--router", default="round_robin",
                    choices=list(ROUTER_POLICIES),
                    help="cluster request-routing policy (--replicas > 1)")
    ap.add_argument("--driver", default="sequential",
                    choices=list(DRIVERS),
                    help="cluster step driver (--replicas > 1): "
                         "'sequential' steps replicas in one "
                         "deterministic loop, 'threaded' overlaps them "
                         "on worker threads (same tokens either way)")
    ap.add_argument("--policy", default="fifo", choices=list(POLICIES),
                    help="scheduling policy: fifo (legacy order), "
                         "priority, edf (earliest TTFT deadline first), "
                         "or slo_adaptive (EDF + deadline-protected "
                         "victim picks + slack routing + starvation "
                         "preemption; see docs/serving.md)")
    ap.add_argument("--slo-ttft", type=float, default=None, metavar="MS",
                    help="per-request first-token latency budget in ms "
                         "(applied to every prompt; default: "
                         "best-effort)")
    ap.add_argument("--slo-tpot", type=float, default=None, metavar="MS",
                    help="per-request decode budget in ms per output "
                         "token (default: best-effort)")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are sampled (the "
                         "streaming generator API) instead of waiting "
                         "for each request to finish")
    ap.add_argument("--hysteresis", type=int, default=4,
                    help="cluster anti-thrash guard: a preempted request "
                         "is not re-admitted for this many scheduler "
                         "rounds (--replicas > 1)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record request-lifecycle telemetry and write a "
                         "Chrome-trace-event JSON (open at "
                         "https://ui.perfetto.dev; see "
                         "docs/observability.md)")
    ap.add_argument("--metrics", nargs="?", const=True, default=None,
                    metavar="OUT.json",
                    help="print the metrics-registry summary (p50/p90/p99 "
                         "TTFT+TPOT, queue age, occupancy/pool timelines); "
                         "with a file argument, also write the stats + "
                         "registry snapshot as JSON so serve runs feed "
                         "tools/bench_compare.py like the benches do")
    ap.add_argument("--attribution", action="store_true",
                    help="attach a utilization attributor: roofline-joined "
                         "per-step accounting (achieved FLOP/s vs peak, "
                         "bottleneck verdicts, fu_utilization; see "
                         "docs/observability.md)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    bucket = (int(args.bucket) if args.bucket and args.bucket != "pow2"
              else args.bucket)
    # per-request side inputs the tokenized --prompts cannot carry: stub
    # rows, one per prompt (vlm patch embeddings; encdec's conv/mel
    # frontend is a stub by assignment, so frames are synthesized too)
    extra = None
    if cfg.family == "vlm":
        import jax.numpy as jnp
        extra = {"patches": jnp.zeros(
            (len(args.prompts), cfg.n_patches, cfg.patch_embed_dim),
            jnp.bfloat16)}
    elif cfg.family == "encdec":
        import jax.numpy as jnp
        extra = {"frames": jnp.zeros((len(args.prompts), 16, cfg.d_model),
                                     jnp.bfloat16)}
    tracer = Tracer() if (args.trace or args.metrics) else None
    attribution = Attributor() if args.attribution else None
    if args.replicas > 1:
        if args.mode != "auto" or args.kv_layout != "dense":
            ap.error("--replicas > 1 always serves continuous and "
                     "resolves the KV layout per family (paged for "
                     "transformer families, dense slot state for scan "
                     "families); drop --mode/--kv-layout")
        eng = ClusterEngine(model, params, replicas=args.replicas,
                            total_slots=args.max_batch,
                            cache_len=args.cache_len, router=args.router,
                            extra_inputs=extra,
                            block_size=args.block_size,
                            n_blocks=args.n_blocks, bucket=bucket,
                            admission=args.admission or "overcommit",
                            preempt_hysteresis=args.hysteresis,
                            prefix_cache=args.prefix_cache,
                            driver=args.driver, policy=args.policy,
                            tracer=tracer, attribution=attribution)
    else:
        if args.driver != "sequential":
            ap.error("--driver threaded needs a cluster (--replicas > 1);"
                     " a single engine has nothing to overlap")
        eng = ServeEngine(model, params, max_batch=args.max_batch,
                          cache_len=args.cache_len, mode=args.mode,
                          extra_inputs=extra,
                          kv_layout=args.kv_layout,
                          block_size=args.block_size,
                          n_blocks=args.n_blocks, bucket=bucket,
                          admission=args.admission or "reserve",
                          prefix_cache=args.prefix_cache,
                          policy=args.policy,
                          tracer=tracer, attribution=attribution)
    reqs = [Request([int(t) % cfg.vocab_size for t in p.split()],
                    args.max_new, args.temperature, rid=i,
                    slo_ttft_ms=args.slo_ttft, slo_tpot_ms=args.slo_tpot)
            for i, p in enumerate(args.prompts)]
    if args.stream:
        if args.mode == "lockstep":
            ap.error("--stream needs the continuous scheduler (tokens "
                     "only exist one request at a time under lockstep)")
        # the deployment-shaped loop: consume the generator as tokens
        # land, print completions as their final token arrives
        streamed: dict[int, list[int]] = {}
        for ev in eng.stream(reqs):
            streamed.setdefault(ev.rid, []).append(ev.token)
            print(f"[stream] rid={ev.rid} i={ev.index} token={ev.token}"
                  f"{' (final)' if ev.final else ''}")
        for rid in sorted(streamed):
            print(f"[serve] rid={rid} tokens={streamed[rid]}")
    else:
        for r in eng.generate(reqs):
            print(f"[serve] rid={r.rid} ttft={r.prefill_ms:.1f}ms "
                  f"decode={r.decode_ms_per_tok:.1f}ms/tok "
                  f"tokens={r.tokens}")
    s = eng.last_stats
    paged = (f" block_util_peak={s.block_util_peak:.2f}"
             f" preempted={s.preempted} requeued={s.requeued}"
             if s.kv_layout == "paged" else "")
    if args.prefix_cache:
        paged += (f" prefix_hits={s.prefix_hits}"
                  f" prefix_reused={s.prefix_tokens_reused}")
    cluster = f" router={s.router_policy}" if s.router_policy else ""
    if args.slo_ttft is not None or args.slo_tpot is not None:
        cluster += (f" policy={s.sched_policy}"
                    f" slo_attainment={s.slo_attainment:.2f}"
                    f" (ttft {s.slo_ttft_attained}/{s.slo_ttft_total}"
                    f" tpot {s.slo_tpot_attained}/{s.slo_tpot_total})")
    print(f"[serve] mode={s.mode} kv={s.kv_layout} "
          f"tokens/s={s.tokens_per_s:.1f} "
          f"generated={s.generated_tokens} steps={s.decode_steps} "
          f"occupancy={s.occupancy:.2f} ttft_mean={s.ttft_ms_mean:.1f}ms "
          f"prefill_compiles={s.prefill_compiles}{paged}{cluster}")
    if args.metrics:
        print(f"[metrics] ttft_ms p50={s.ttft_ms_p50:.1f} "
              f"p90={s.ttft_ms_p90:.1f} p99={s.ttft_ms_p99:.1f} "
              f"mean={s.ttft_ms_mean:.1f}")
        print(f"[metrics] tpot_ms p50={s.tpot_ms_p50:.2f} "
              f"p90={s.tpot_ms_p90:.2f} p99={s.tpot_ms_p99:.2f} "
              f"mean={s.tpot_ms_mean:.2f}")
        print(f"[metrics] queue_age_ms mean={s.queue_age_ms_mean:.1f} "
              f"p99={s.queue_age_ms_p99:.1f}")
        if args.attribution:
            print(f"[metrics] attribution fu_utilization="
                  f"{s.fu_utilization:.3e} "
                  f"achieved_flops/s={s.achieved_flops_per_s:.3e} "
                  f"achieved_bytes/s={s.achieved_bytes_per_s:.3e} "
                  f"decode_ai={s.decode_ai:.2f} ridge={s.ridge_ai:.2f} "
                  f"bottleneck={s.bottleneck or '-'} "
                  f"prefill={s.prefill_bottleneck or '-'} "
                  f"verdicts={s.verdict_counts}")
        for name, val in sorted(eng.last_metrics.snapshot().items()):
            print(f"[metrics] {name}={val}")
        if isinstance(args.metrics, str):
            # machine-readable twin of the prints above: the stats view
            # plus the raw registry snapshot, in the shape
            # tools/bench_compare.py gates (stats.* / metrics.* keys)
            with open(args.metrics, "w") as f:
                json.dump({"bench": "repro.launch.serve",
                           "stats": dataclasses.asdict(s),
                           "metrics": eng.last_metrics.snapshot()},
                          f, indent=2, sort_keys=True, default=str)
            print(f"[metrics] wrote {args.metrics}")
    if args.trace:
        n = tracer.export(args.trace)
        print(f"[trace] wrote {n} events to {args.trace} "
              "(open at https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
