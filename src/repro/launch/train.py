"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real cluster: run under the production mesh (--mesh 16x16) with one
process per host; this CPU container runs 1x1.
"""
from __future__ import annotations

import argparse

import jax

from ..configs import get_config, list_archs, smoke_config
from ..data import MMapTokens, SyntheticTokens
from ..distributed.sharding import ShardingPolicy
from ..models import build_model
from ..optim import AdamW, AdamW8bit, warmup_cosine
from ..train import TrainConfig, Trainer
from .mesh import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 16x16")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--sp", action="store_true")
    ap.add_argument("--opt", default="adamw", choices=["adamw", "adamw8bit"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--data", default="synthetic",
                    help="'synthetic' or a path to a flat token file")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-step-time", type=float, default=None)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    print(f"[train] {cfg.name}: {model.n_params/1e6:.1f}M params")
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))
    policy = ShardingPolicy(fsdp=args.fsdp, sp=args.sp)
    opt_cls = {"adamw": AdamW, "adamw8bit": AdamW8bit}[args.opt]
    opt = opt_cls(lr=warmup_cosine(args.lr, args.warmup, args.steps))
    if args.data == "synthetic":
        data = SyntheticTokens(cfg, args.batch, args.seq, seed=args.seed)
    else:
        data = MMapTokens(args.data, cfg, args.batch, args.seq,
                          seed=args.seed)
    tc = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every,
                     max_step_time=args.max_step_time)
    trainer = Trainer(model, opt, policy, mesh, data, tc)
    _, log = trainer.run()
    print(f"[train] done: {log[-1]}")


if __name__ == "__main__":
    main()
