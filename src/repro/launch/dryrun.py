"""Multi-pod dry-run (deliverable e): lower + compile every assigned
(architecture x input-shape) cell on the production meshes and extract the
roofline terms (deliverable g) from the compiled artifact.

MUST be run as a module entry point: the XLA_FLAGS line below has to
execute before any other jax import in the process.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ---- only now is it safe to import jax ------------------------------------
import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import SHAPES, cell_applicable, get_config  # noqa: E402
from ..configs.base import ModelConfig, ShapeConfig  # noqa: E402
from ..distributed.act_sharding import activation_sharding  # noqa: E402
from ..distributed.sharding import (ShardingPolicy, batch_shardings,  # noqa: E402
                                    cache_shardings, tree_shardings)
from ..models.layers import PT  # noqa: E402
from ..models.model import build_model, input_specs  # noqa: E402
from ..roofline.analysis import analyze, model_flops_estimate  # noqa: E402
from .mesh import make_production_mesh, mesh_desc  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "..", "..", "..", "results", "dryrun")


def choose_policy(cfg: ModelConfig, shape: ShapeConfig, mesh
                  ) -> ShardingPolicy:
    """Baseline policy per cell (the paper-faithful starting point; §Perf
    hillclimbs from here).  Train: FSDP + TP + SP.  Serve: TP-only unless
    the model doesn't fit one TP group (qwen3-moe), then weights also shard
    over the dp axes."""
    axes = list(mesh.shape.keys())
    dp_axes = tuple(a for a in axes if a != "model")
    # C4 (the paper's multi-core insight): archs too narrow to exploit a
    # 16-wide TP axis (whisper: 8 heads, d_ff 2048) run as pure DP -
    # "many small vector cores" - with the model axis joining data.
    tp = mesh.shape["model"]
    if cfg.n_heads < 12 and cfg.d_model <= 512 \
            and shape.global_batch % mesh.size == 0:
        # pure DP only when the batch actually divides the whole mesh -
        # otherwise the unsharded batch replicates every activation
        all_dp = tuple(axes)
        return ShardingPolicy(dp_axes=all_dp, fsdp=shape.kind == "train",
                              sp=False)
    if shape.kind == "train":
        return ShardingPolicy(dp_axes=dp_axes, fsdp=True, sp=True)
    from ..models.layers import param_count
    pbytes = param_count(build_model(cfg).templates) * 2
    tp = mesh.shape["model"]
    fsdp = pbytes / tp > 0.5 * 16e9
    # SP for 32k prefill: the per-layer full-seq hidden otherwise dominates
    # (qwen3-moe: 49 GB/dev measured without it)
    return ShardingPolicy(dp_axes=dp_axes, fsdp=fsdp,
                          sp=shape.kind == "prefill")


def _opt_state_specs(model, param_sh, mesh, opt=None):
    from ..optim import AdamW8bit
    from ..optim.adamw8bit import BLOCK, padded_last

    def f32(t):
        return jax.ShapeDtypeStruct(t.shape, jnp.float32)

    tmpl = model.templates
    leaves = lambda f: jax.tree_util.tree_map(
        f, tmpl, is_leaf=lambda x: isinstance(x, PT))
    if isinstance(opt, AdamW8bit):
        def axis_size(entry):
            if entry is None:
                return 1
            entries = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in entries:
                n *= mesh.shape[a]
            return n

        def q_leaf(t, dtype):
            lead = t.shape[:-1]
            qshape = lead + (padded_last(t.shape[-1]),)
            sshape = lead + (qshape[-1] // BLOCK,)
            return {"q": jax.ShapeDtypeStruct(qshape, dtype),
                    "s": jax.ShapeDtypeStruct(sshape, jnp.float32)}

        def q_sh_leaf(t, ns):
            spec = list(ns.spec) + [None] * (len(t.shape) - len(ns.spec))
            qshape = t.shape[:-1] + (padded_last(t.shape[-1]),)
            sshape = t.shape[:-1] + (qshape[-1] // BLOCK,)

            def fit(spec_, shape_):
                out = []
                for dim, entry in enumerate(spec_):
                    ok = entry is not None and \
                        shape_[dim] % axis_size(entry) == 0
                    out.append(entry if ok else None)
                return P(*out)
            return {"q": NamedSharding(mesh, fit(spec, qshape)),
                    "s": NamedSharding(mesh, fit(spec, sshape))}

        m_specs = leaves(lambda t: q_leaf(t, jnp.int8))
        v_specs = leaves(lambda t: q_leaf(t, jnp.uint8))
        q_sh = jax.tree_util.tree_map(
            q_sh_leaf, tmpl, param_sh, is_leaf=lambda x: isinstance(x, PT))
        specs = {"master": leaves(f32), "m": m_specs, "v": v_specs,
                 "step": jax.ShapeDtypeStruct((), jnp.int32)}
        sh = {"master": param_sh, "m": q_sh, "v": q_sh,
              "step": NamedSharding(mesh, P())}
        return specs, sh
    specs = {"master": leaves(f32), "m": leaves(f32), "v": leaves(f32),
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    sh = {"master": param_sh, "m": param_sh, "v": param_sh,
          "step": NamedSharding(mesh, P())}
    return specs, sh


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (jitted_fn, arg_specs tuple) for one dry-run cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    policy = choose_policy(cfg, shape, mesh)
    rules = policy.act_rules()
    pspecs = model.pspecs(policy.param_rules(), dict(mesh.shape))
    param_sh = tree_shardings(mesh, pspecs)
    param_specs = jax.tree_util.tree_map(
        lambda t: jax.ShapeDtypeStruct(t.shape, t.dtype), model.templates,
        is_leaf=lambda x: isinstance(x, PT))
    batch = input_specs(cfg, shape)
    batch_sh = batch_shardings(mesh, batch, policy)

    if shape.kind == "train":
        from ..models.layers import param_count
        from ..optim import AdamW, AdamW8bit
        from ..train.trainer import _step_body
        n_params = param_count(model.templates)
        # state-dominated models: 8-bit m/v + microbatched grad accumulation
        big = n_params * 14 / mesh.size > 4e9
        opt = AdamW8bit(lr=3e-4) if big else AdamW(lr=3e-4)
        narrow = cfg.n_heads < 12 and cfg.d_model <= 512
        micro = 8 if big else (4 if narrow else
                               (2 if n_params > 10e9 else 1))
        state_specs, state_sh = _opt_state_specs(model, param_sh, mesh,
                                                 opt=opt)
        body = _step_body(model, opt, mesh, rules, 1.0, True,
                          microbatches=micro)
        fn = jax.jit(body, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
        return fn, (state_specs, batch)

    if shape.kind == "prefill":
        def prefill_fn(params, b):
            with activation_sharding(mesh, rules):
                return model.prefill(params, b, cache_len=shape.seq_len)
        fn = jax.jit(prefill_fn, in_shardings=(param_sh, batch_sh))
        return fn, (param_specs, batch)

    # decode
    cache_specs = model.cache_shapes(shape.global_batch, shape.seq_len)
    cache_sh = cache_shardings(mesh, cache_specs, policy,
                               batch_size=shape.global_batch)
    tok_sh = batch_shardings(mesh, batch, policy)

    def decode_fn(params, cache, tokens):
        with activation_sharding(mesh, rules):
            return model.decode(params, cache, tokens)

    fn = jax.jit(decode_fn,
                 in_shardings=(param_sh, cache_sh, tok_sh["tokens"]),
                 out_shardings=(None, cache_sh), donate_argnums=(1,))
    return fn, (param_specs, cache_specs, batch["tokens"])


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, why = cell_applicable(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    desc = mesh_desc(mesh)
    rec = {"arch": arch, "shape": shape_name, "mesh": desc,
           "chips": mesh.size}
    if not ok:
        rec.update(status="skipped", reason=why)
        print(f"[dryrun] {arch} x {shape_name} x {desc}: SKIP ({why})")
        return rec
    t0 = time.time()
    try:
        fn, arg_specs = build_cell(arch, shape_name, mesh)
        with mesh:
            lowered = fn.lower(*arg_specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mf = model_flops_estimate(cfg, shape)
        roof = analyze(compiled, arch=arch, shape=shape_name, mesh_desc=desc,
                       chips=mesh.size, model_flops=mf)
        ma = compiled.memory_analysis()
        rec.update(status="ok", t_lower_s=round(t_lower, 1),
                   t_compile_s=round(t_compile, 1),
                   memory=dict(
                       argument_bytes=ma.argument_size_in_bytes,
                       output_bytes=ma.output_size_in_bytes,
                       temp_bytes=ma.temp_size_in_bytes,
                       alias_bytes=ma.alias_size_in_bytes),
                   roofline=roof.to_dict())
        hbm = 16e9
        used = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        rec["fits_hbm"] = bool(used < hbm)
        rec["hbm_used_gb"] = round(used / 1e9, 2)
        print(f"[dryrun] {arch} x {shape_name} x {desc}: OK "
              f"({rec['hbm_used_gb']} GB/dev, dominant={roof.dominant}, "
              f"roofline_frac={roof.roofline_fraction:.3f}, "
              f"compile {t_compile:.0f}s)")
    except Exception as e:  # noqa: BLE001 - report, don't crash the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] {arch} x {shape_name} x {desc}: ERROR {e}")
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        fname = f"{arch}__{shape_name}__{desc}.json".replace("/", "_")
        with open(os.path.join(RESULTS_DIR, fname), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*SHAPES, None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    from ..configs import list_archs
    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp)
                n_err += rec["status"] == "error"
    sys.exit(1 if n_err else 0)


if __name__ == "__main__":
    main()
