"""xLSTM blocks: chunkwise-parallel mLSTM (matrix memory) and recurrent
sLSTM (scalar memory), per arXiv:2405.04517.

The mLSTM chunked form mirrors the SSD kernel's intra/inter-chunk split
(C3's intra-lane/inter-lane structure): within a chunk the recurrence is a
decay-masked attention matmul; across chunks a (C, n, m) state is carried
with running-max stabilization of the exponential gates.  Decode is O(1)
per token, which qualifies the arch for ``long_500k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import PT, rmsnorm, silu


# ---------------------------------------------------------------------------
# mLSTM cell - chunkwise parallel (training / prefill).
# ---------------------------------------------------------------------------

def _mlstm_chunk(carry, qc, kc, vc, lf, li):
    """One chunk, one batch of heads.

    carry: (C (B,H,dk,dv), n (B,H,dk), m (B,H));
    qc/kc: (B,H,Q,dk), vc: (B,H,Q,dv); lf/li: (B,H,Q) log f / log i.
    Stored state is true state scaled by exp(-m)."""
    c_in, n_in, m_in = carry
    f_cum = jnp.cumsum(lf, axis=-1)                    # F_i, inclusive
    g = li - f_cum                                     # g_j
    m_tilde = jnp.maximum(m_in[..., None], jax.lax.cummax(g, axis=2))
    m_total = f_cum + m_tilde                          # recurrent m_t
    # intra-chunk decay matrix D_ij = exp(g_j - m_tilde_i), j <= i
    d_mat = jnp.exp(g[:, :, None, :] - m_tilde[:, :, :, None])
    q_idx = np.arange(lf.shape[-1])
    causal = (q_idx[:, None] >= q_idx[None, :])[None, None]
    d_mat = jnp.where(causal, d_mat, 0.0)
    s = jnp.einsum("bhid,bhjd->bhij", qc, kc) * d_mat  # masked scores
    inter_w = jnp.exp(m_in[..., None] - m_tilde)       # (B,H,Q)
    num = jnp.einsum("bhij,bhjv->bhiv", s, vc) \
        + inter_w[..., None] * jnp.einsum("bhid,bhdv->bhiv", qc, c_in)
    den = jnp.sum(s, axis=-1) + inter_w * jnp.einsum("bhid,bhd->bhi", qc, n_in)
    y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_total))[..., None]
    # chunk-out state (stabilized at m_out = m_total[..., -1])
    m_last = m_tilde[..., -1]
    w_out = jnp.exp(g - m_last[..., None])             # (B,H,Q)
    c_out = jnp.einsum("bhjd,bhjv->bhdv", kc * w_out[..., None], vc) \
        + jnp.exp(m_in - m_last)[..., None, None] * c_in
    n_out = jnp.einsum("bhjd,bhj->bhd", kc, w_out) \
        + jnp.exp(m_in - m_last)[..., None] * n_in
    return (c_out, n_out, f_cum[..., -1] + m_last), y


def mlstm_parallel(q, k, v, i_gate, f_gate, *, chunk=256, state=None):
    """q/k: (B, H, S, dk), v: (B, H, S, dv), i_gate/f_gate: (B, H, S) raw.
    Returns (y (B,H,S,dv), state)."""
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    k = k / np.sqrt(dk)
    lf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    li = i_gate.astype(jnp.float32)

    def to_chunks(x, extra=()):
        return jnp.moveaxis(x.reshape(b, h, nc, chunk, *extra), 2, 0)

    qs = to_chunks(q.astype(jnp.float32), (dk,))
    ks = to_chunks(k.astype(jnp.float32), (dk,))
    vs = to_chunks(v.astype(jnp.float32), (dv,))
    lfs, lis = to_chunks(lf), to_chunks(li)
    if state is None:
        state = (jnp.zeros((b, h, dk, dv), jnp.float32),
                 jnp.zeros((b, h, dk), jnp.float32),
                 jnp.full((b, h), -1e30, jnp.float32))

    # checkpoint the chunk body: the backward pass re-materializes the
    # (B,H,Q,Q) decay/score matrices per chunk instead of saving all of
    # them (they dominated xlstm train_4k memory, ~20 GB/device)
    body = jax.checkpoint(_mlstm_chunk)

    def step(carry, inp):
        return body(carry, *inp)

    state, ys = jax.lax.scan(step, state, (qs, ks, vs, lfs, lis))
    y = jnp.moveaxis(ys, 0, 2).reshape(b, h, s, dv)
    return y.astype(v.dtype), state


def mlstm_step(state, q, k, v, i_gate, f_gate):
    """One-token recurrent step.  q/k: (B,H,dk), v: (B,H,dv), gates (B,H)."""
    c, n, m = state
    dk = q.shape[-1]
    k = k.astype(jnp.float32) / np.sqrt(dk)
    q = q.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    li = i_gate.astype(jnp.float32)
    m_new = jnp.maximum(lf + m, li)
    fp = jnp.exp(lf + m - m_new)
    ip = jnp.exp(li - m_new)
    c = fp[..., None, None] * c + ip[..., None, None] * \
        jnp.einsum("bhd,bhv->bhdv", k, v.astype(jnp.float32))
    n = fp[..., None] * n + ip[..., None] * k
    num = jnp.einsum("bhd,bhdv->bhv", q, c)
    den = jnp.einsum("bhd,bhd->bh", q, n)
    y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return (c, n, m_new), y.astype(v.dtype)


# ---------------------------------------------------------------------------
# sLSTM cell - strictly recurrent scalar memory.
# ---------------------------------------------------------------------------

def slstm_scan(x_gates, r_w, state, *, segment: int = 64):
    """x_gates: (B, S, H, dh, 4) pre-activations [i, f, z, o] from the input
    path; r_w: (4, H, dh, dh) per-head recurrent weights;
    state: (c, n, h, m) each (B, H, dh).

    Two-level checkpointed scan: the backward pass re-runs one ``segment``
    at a time instead of saving per-step carries for the whole sequence
    (a 4096-step recurrence otherwise holds ~4 GB/layer of (c,n,h,m)
    snapshots)."""

    def step(carry, xt):
        c, n, h, m = carry
        rec = jnp.einsum("ghde,bhe->bghd", r_w, h)      # (B, 4, H, dh)
        it = xt[..., 0] + rec[:, 0]
        ft = xt[..., 1] + rec[:, 1]
        zt = xt[..., 2] + rec[:, 2]
        ot = xt[..., 3] + rec[:, 3]
        lf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(lf + m, it)
        ip = jnp.exp(it - m_new)
        fp = jnp.exp(lf + m - m_new)
        c = fp * c + ip * jnp.tanh(zt)
        n = fp * n + ip
        h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    xs = jnp.moveaxis(x_gates.astype(jnp.float32), 1, 0)   # (S, B, H, dh, 4)
    s_len = xs.shape[0]
    seg = segment
    while s_len % seg:
        seg -= 1
    if seg <= 1 or s_len <= seg:
        state, hs = jax.lax.scan(step, state, xs)
        return jnp.moveaxis(hs, 0, 1), state            # (B, S, H, dh)
    xseg = xs.reshape(s_len // seg, seg, *xs.shape[1:])

    @jax.checkpoint
    def run_segment(carry, xss):
        return jax.lax.scan(step, carry, xss)

    state, hs = jax.lax.scan(run_segment, state, xseg)
    hs = hs.reshape(s_len, *hs.shape[2:])
    return jnp.moveaxis(hs, 0, 1), state                # (B, S, H, dh)


def slstm_init_state(b, h, dh):
    z = jnp.zeros((b, h, dh), jnp.float32)
    return (z, z, z, jnp.full((b, h, dh), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# Blocks.
# ---------------------------------------------------------------------------

def mlstm_block_templates(d_model: int, n_heads: int, pf: int = 2,
                          d_conv: int = 4):
    di = pf * d_model
    return {
        "norm": PT((d_model,), "zeros", ("embed",)),
        "up": PT((d_model, 2 * di), "scaled", ("embed", "dinner")),
        "conv_w": PT((d_conv, di), "scaled", (None, "dinner")),
        "conv_b": PT((di,), "zeros", ("dinner",)),
        # block-diagonal per-head projections (xLSTM paper): di^2/H params
        "wq": PT((n_heads, di // n_heads, di // n_heads), "scaled",
                 (None, None, "dinner")),
        "wk": PT((n_heads, di // n_heads, di // n_heads), "scaled",
                 (None, None, "dinner")),
        "wv": PT((n_heads, di // n_heads, di // n_heads), "scaled",
                 (None, None, "dinner")),
        "w_i": PT((di, n_heads), "scaled", ("dinner", None), dtype=jnp.float32),
        "w_f": PT((di, n_heads), "scaled", ("dinner", None), dtype=jnp.float32),
        "b_i": PT((n_heads,), "zeros", (None,), dtype=jnp.float32),
        "b_f": PT((n_heads,), "ones", (None,), dtype=jnp.float32),
        "hnorm": PT((di,), "zeros", ("dinner",)),
        "down": PT((di, d_model), "scaled", ("dinner", "embed")),
    }


def _mlstm_block_inner(p, x, n_heads, *, conv_state=None, mstate=None,
                       chunk=256, norm_eps=1e-6):
    from .mamba2 import _causal_conv
    b, s, d = x.shape
    h = rmsnorm(p["norm"], x, norm_eps)
    up = jnp.einsum("bsd,de->bse", h, p["up"])
    di = up.shape[-1] // 2
    xm, z = up[..., :di], up[..., di:]
    xc, new_conv = _causal_conv(xm, p["conv_w"], p["conv_b"],
                                conv_state=conv_state)
    dh = di // n_heads
    xch = xc.reshape(b, s, n_heads, dh)
    xmh = xm.reshape(b, s, n_heads, dh)
    q = jnp.einsum("bshd,hde->bhse", xch, p["wq"])
    k = jnp.einsum("bshd,hde->bhse", xch, p["wk"])
    v = jnp.einsum("bshd,hde->bhse", xmh, p["wv"])
    ig = jnp.einsum("bse,eh->bsh", xc.astype(jnp.float32), p["w_i"]) + p["b_i"]
    fg = jnp.einsum("bse,eh->bsh", xc.astype(jnp.float32), p["w_f"]) + p["b_f"]
    y, mstate = mlstm_parallel(q, k, v, ig.transpose(0, 2, 1),
                               fg.transpose(0, 2, 1), chunk=chunk,
                               state=mstate)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, di)
    y = rmsnorm(p["hnorm"], y, norm_eps) * silu(z)
    return x + jnp.einsum("bse,ed->bsd", y, p["down"]), (new_conv, mstate)


def mlstm_block(p, x, n_heads, **kw):
    out, _ = _mlstm_block_inner(p, x, n_heads, **kw)
    return out


def mlstm_block_with_state(p, x, n_heads, conv_state, mstate, **kw):
    return _mlstm_block_inner(p, x, n_heads, conv_state=conv_state,
                              mstate=mstate, **kw)


def mlstm_block_decode(p, x, n_heads, conv_state, mstate, *, norm_eps=1e-6):
    """One-token mLSTM block step.  x: (B, 1, d); conv_state: (B, K-1, di);
    mstate: (C, n, m)."""
    b = x.shape[0]
    h = rmsnorm(p["norm"], x, norm_eps)
    up = jnp.einsum("bsd,de->bse", h, p["up"])
    di = up.shape[-1] // 2
    xm, z = up[..., :di], up[..., di:]
    xp = jnp.concatenate([conv_state.astype(xm.dtype), xm], axis=1)
    xc = silu(jnp.einsum("bkc,kc->bc", xp, p["conv_w"]) + p["conv_b"])
    new_conv = xp[:, 1:, :]
    dh = di // n_heads
    xch = xc.reshape(b, n_heads, dh)
    xmh = xm[:, 0].reshape(b, n_heads, dh)
    q = jnp.einsum("bhd,hde->bhe", xch, p["wq"])
    k = jnp.einsum("bhd,hde->bhe", xch, p["wk"])
    v = jnp.einsum("bhd,hde->bhe", xmh, p["wv"])
    ig = jnp.einsum("be,eh->bh", xc.astype(jnp.float32), p["w_i"]) + p["b_i"]
    fg = jnp.einsum("be,eh->bh", xc.astype(jnp.float32), p["w_f"]) + p["b_f"]
    mstate, y = mlstm_step(mstate, q, k, v, ig, fg)
    y = y.reshape(b, 1, di)
    y = rmsnorm(p["hnorm"], y, norm_eps) * silu(z)
    return x + jnp.einsum("bse,ed->bsd", y, p["down"]), new_conv, mstate


def slstm_block_decode(p, x, n_heads, conv_state, state, *, norm_eps=1e-6):
    """One-token sLSTM block step.  conv_state: (B, K-1, d)."""
    b, _, d = x.shape
    dh = d // n_heads
    h = rmsnorm(p["norm"], x, norm_eps)
    xp = jnp.concatenate([conv_state.astype(h.dtype), h], axis=1)
    xc = silu(jnp.einsum("bkc,kc->bc", xp, p["conv_w"]) + p["conv_b"])
    new_conv = xp[:, 1:, :]
    gates = jnp.einsum("bd,dg->bg", xc, p["w_gates"]).astype(jnp.float32)
    gates = gates.reshape(b, 1, n_heads, dh, 4)
    hs, state = slstm_scan(gates, p["r_w"], state)
    y = hs.reshape(b, 1, d).astype(x.dtype)
    y = rmsnorm(p["gnorm"], y, norm_eps)
    return x + jnp.einsum("bsd,de->bse", y, p["out"]), new_conv, state


def slstm_block_templates(d_model: int, n_heads: int, d_conv: int = 4):
    return {
        "norm": PT((d_model,), "zeros", ("embed",)),
        "conv_w": PT((d_conv, d_model), "scaled", (None, "embed")),
        "conv_b": PT((d_model,), "zeros", ("embed",)),
        "w_gates": PT((d_model, d_model * 4), "scaled", ("embed", "dinner")),
        "r_w": PT((4, n_heads, d_model // n_heads, d_model // n_heads),
                  "scaled", (None, None, None, None), dtype=jnp.float32),
        "gnorm": PT((d_model,), "zeros", ("embed",)),
        "out": PT((d_model, d_model), "scaled", ("embed", "embed")),
    }


def slstm_block(p, x, n_heads, *, conv_state=None, state=None,
                norm_eps=1e-6, return_state=False):
    from .mamba2 import _causal_conv
    b, s, d = x.shape
    dh = d // n_heads
    h = rmsnorm(p["norm"], x, norm_eps)
    xc, new_conv = _causal_conv(h, p["conv_w"], p["conv_b"],
                                conv_state=conv_state)
    gates = jnp.einsum("bsd,dg->bsg", xc, p["w_gates"]).astype(jnp.float32)
    gates = gates.reshape(b, s, n_heads, dh, 4)
    if state is None:
        state = slstm_init_state(b, n_heads, dh)
    hs, state = slstm_scan(gates, p["r_w"], state)
    y = hs.reshape(b, s, d).astype(x.dtype)
    y = rmsnorm(p["gnorm"], y, norm_eps)
    out = x + jnp.einsum("bsd,de->bse", y, p["out"])
    if return_state:
        return out, (new_conv, state)
    return out
