"""Top-k MoE layer with sort-based capacity dispatch (expert-parallel ready).

Dispatch avoids the GShard (T, E, C) one-hot tensor: token->expert
assignments are sorted by expert id, positions-within-expert computed by a
cumulative count, and tokens scattered into a dense (E*C, d) buffer that the
stacked expert SwiGLU consumes as one grouped einsum (MXU-friendly).  With
EP, the expert axis of the buffer and weights shards over ``model``; the
scatter/gather become the token-exchange collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import PT, silu

GROUP_TOKENS = 2048  # tokens/group: 1M-token steps -> 512 groups,
                     # divisible by both the 256- and 512-chip meshes


def moe_templates(d_model: int, d_ff: int, n_experts: int):
    return {
        "router": PT((d_model, n_experts), "scaled", ("embed", None),
                     dtype=jnp.float32),
        "gate": PT((n_experts, d_model, d_ff), "scaled",
                   ("expert", "embed", "ffn")),
        "up": PT((n_experts, d_model, d_ff), "scaled",
                 ("expert", "embed", "ffn")),
        "down": PT((n_experts, d_ff, d_model), "scaled",
                   ("expert", "ffn", "embed")),
    }


def _route(p, xt, top_k: int, cap: int):
    """Route one token group.  xt: (T, d).  Returns the dispatch buffer
    (E, C, d) + combine metadata (slot, token, gate, keep, probs, ids)."""
    t, d = xt.shape
    e = p["router"].shape[1]
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)      # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    flat_expert = expert_ids.reshape(-1)                      # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t), top_k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert)
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    del flat_expert, flat_token, flat_gate
    same_before = jnp.cumsum(jax.nn.one_hot(se, e, dtype=jnp.int32), axis=0)
    pos = jnp.take_along_axis(same_before, se[:, None], axis=1)[:, 0] - 1
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, e * cap)           # overflow slot

    # single scatter: with one ~4096-token group per chip the buffer is
    # ~300 MB; a k-chunked scatter chain would create k live cotangent
    # versions of it in the backward pass (measured +5 GB/dev)
    buf = jnp.zeros((e * cap + 1, d), xt.dtype)
    buf = buf.at[slot].set(xt[st])
    dispatched = buf[:e * cap].reshape(e, cap, d)
    return dispatched, (slot, st, sg, keep, probs, expert_ids)


def _combine(y, meta, t: int, d: int, top_k: int):
    del top_k
    slot, st, sg, keep, _, _ = meta
    e_cap = y.shape[0] * y.shape[1]
    y_flat = jnp.concatenate([y.reshape(e_cap, d),
                              jnp.zeros((1, d), y.dtype)], axis=0)
    contrib = y_flat[slot] * sg[:, None].astype(y.dtype) \
        * keep[:, None].astype(y.dtype)
    return jnp.zeros((t, d), y.dtype).at[st].add(contrib)


def moe_apply(p, x, *, top_k: int, capacity_factor: float = 1.25,
              return_aux: bool = False, exact: bool = False):
    """x: (..., d) -> (..., d).  Tokens beyond expert capacity are dropped
    (contribute zero), matching Switch/GShard semantics.  ``exact=True``
    sets capacity = T (no drops ever) - used for decode steps.

    Dispatch is *grouped*: with a (B, S, d) input, routing/sort/scatter run
    per batch row (vmapped), so under a batch-sharded mesh every group's
    sort and gather stay shard-local and the only cross-chip movement is
    the (B, E, C, d) dispatch-buffer einsum against the expert-sharded
    weights - i.e. the EP all-to-all, where it belongs.  The ungrouped
    path (global sort over all tokens) forced XLA to gather every token to
    every chip: 336 GB/device on qwen3-moe train (see EXPERIMENTS.md §Perf).
    """
    from ..distributed.act_sharding import constrain
    orig_shape = x.shape
    d = orig_shape[-1]
    x3 = x.reshape(-1, d)[None] if x.ndim <= 2 else x.reshape(
        orig_shape[0], -1, d)
    # regroup into ~GROUP_TOKENS-token groups: the group dim shards over
    # dp x tp (one group per chip at production scale), so routing, sort,
    # gather and scatter are all chip-local; the explicit reshard of the
    # dispatch buffer group-sharded -> expert-sharded below IS the EP
    # all-to-all (and the only cross-chip movement of token payloads)
    b0, t0, _ = x3.shape
    gs = GROUP_TOKENS if (t0 % GROUP_TOKENS == 0) else t0
    x3 = x3.reshape(b0 * (t0 // gs), gs, d)
    x3 = constrain(x3, "moe_tokens")
    b, t, _ = x3.shape
    e = p["router"].shape[1]
    cap = t if exact else max(1, int(top_k * t * capacity_factor / e))

    dispatched, meta = jax.vmap(
        lambda xt: _route(p, xt, top_k, cap))(x3)             # (G, E, C, d)
    dispatched = constrain(dispatched, "moe_groups")
    dispatched = constrain(dispatched, "moe_dispatch")        # <- all-to-all
    g = silu(jnp.einsum("becd,edf->becf", dispatched, p["gate"]))
    u = jnp.einsum("becd,edf->becf", dispatched, p["up"])
    y = jnp.einsum("becf,efd->becd", g * u, p["down"])
    y = constrain(y, "moe_dispatch")
    y = constrain(y, "moe_groups")                            # <- back
    out = jax.vmap(lambda yb, mb: _combine(yb, mb, t, d, top_k))(y, meta)
    out = constrain(out, "moe_tokens")
    out = out.reshape(orig_shape)
    if return_aux:
        probs, expert_ids = meta[4], meta[5]
        me = jnp.mean(probs, axis=(0, 1))
        ce = jnp.mean(jax.nn.one_hot(expert_ids[..., 0], e,
                                     dtype=jnp.float32), axis=(0, 1))
        keep = meta[3]
        aux = {"lb_loss": e * jnp.sum(me * ce),
               "drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
        return out, aux
    return out


def moe_apply_dense(p, x, *, top_k: int):
    """Reference: run every expert on every token, weight by gates (exact,
    no capacity drops).  Used as the oracle for dispatch tests."""
    xt = x.reshape(-1, x.shape[-1])
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    gates = jnp.zeros_like(probs)
    gates = jnp.take_along_axis(
        gates, expert_ids, axis=1
    )  # placeholder to keep shapes clear
    full_gates = jnp.zeros(probs.shape, probs.dtype).at[
        jnp.arange(xt.shape[0])[:, None], expert_ids].set(gate_vals)
    g = silu(jnp.einsum("td,edf->tef", xt, p["gate"]))
    u = jnp.einsum("td,edf->tef", xt, p["up"])
    y = jnp.einsum("tef,efd->ted", g * u, p["down"])
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), full_gates)
    return out.astype(x.dtype).reshape(x.shape)
