"""Model substrate: param templates, norms, RoPE, MLPs, embeddings, loss.

Parameters are plain pytrees (nested dicts of jnp arrays).  Every tree is
built from a matching tree of ``PT`` templates which carries shape, init and
*logical sharding axes*; ``init_params`` and ``param_pspecs`` both walk the
same template tree, so shardings can never drift from shapes.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Param templates.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PT:
    """Param template: shape + init scheme + logical axes (for sharding)."""
    shape: tuple[int, ...]
    init: str = "normal"        # normal | zeros | ones | scaled | ssm_dt | ssm_a
    axes: tuple[str | None, ...] = ()
    dtype: Any = jnp.bfloat16
    scale: float | None = None  # stddev override

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(f"axes {self.axes} vs shape {self.shape}")


def _init_leaf(t: PT, key) -> jnp.ndarray:
    if t.init == "zeros":
        return jnp.zeros(t.shape, t.dtype)
    if t.init == "ones":
        return jnp.ones(t.shape, t.dtype)
    if t.init == "ssm_dt":     # dt bias: softplus^-1 of U(0.001, 0.1)
        u = jax.random.uniform(key, t.shape, jnp.float32, 0.001, 0.1)
        return jnp.log(jnp.expm1(u)).astype(t.dtype)
    if t.init == "ssm_a":      # a_log: log of U(1, 16)
        return jnp.log(jax.random.uniform(key, t.shape, jnp.float32, 1.0, 16.0)
                       ).astype(t.dtype)
    if t.init == "scaled":     # fan-in scaled normal
        fan_in = t.shape[-2] if len(t.shape) >= 2 else t.shape[-1]
        std = t.scale if t.scale is not None else 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(key, t.shape, jnp.float32) * std).astype(t.dtype)
    std = t.scale if t.scale is not None else 0.02
    return (jax.random.normal(key, t.shape, jnp.float32) * std).astype(t.dtype)


def init_params(templates, key):
    """Walk a template pytree, deriving one PRNG key per leaf from its path.

    The path is folded in via crc32, not ``hash()``: Python string hashing
    is salted per process (PYTHONHASHSEED), which made "same seed, same
    params" silently untrue across processes."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        templates, is_leaf=lambda x: isinstance(x, PT))
    out = []
    for path, t in leaves:
        digest = zlib.crc32(jax.tree_util.keystr(path).encode())
        pkey = jax.random.fold_in(key, digest % (2 ** 31))
        out.append(_init_leaf(t, pkey))
    return jax.tree_util.tree_unflatten(treedef, out)


def param_pspecs(templates, rules: dict[str, Any], mesh_shape=None):
    """Template tree -> PartitionSpec tree via logical-axis rules.

    A mesh axis may appear only once per spec: when two logical axes of one
    param map to the same mesh axis (e.g. MoE ("expert","embed","ffn") with
    expert and ffn both on the TP axis), the later dim drops it.  With
    ``mesh_shape`` (dict axis->size), axes that do not divide the dim size
    are dropped too (tiny head counts, whisper-scale dims)."""
    from jax.sharding import PartitionSpec as P

    def leaf(t: PT):
        if not t.axes:
            return P()
        used: set = set()
        out = []
        for dim, a in enumerate(t.axes):
            mesh_axes = rules.get(a) if a else None
            if mesh_axes is None:
                out.append(None)
                continue
            flat = (mesh_axes,) if isinstance(mesh_axes, str) \
                else tuple(mesh_axes)
            keep = tuple(m for m in flat if m not in used)
            if keep != flat:
                keep = ()  # partial tuples change divisibility; drop whole
            if keep and mesh_shape is not None:
                size = 1
                for m in keep:
                    size *= mesh_shape[m]
                if t.shape[dim] % size:
                    keep = ()
            used.update(keep)
            out.append(keep if len(keep) > 1 else (keep[0] if keep else None))
        return P(*out)

    return jax.tree_util.tree_map(leaf, templates,
                                  is_leaf=lambda x: isinstance(x, PT))


def param_count(templates) -> int:
    leaves = jax.tree_util.tree_leaves(
        templates, is_leaf=lambda x: isinstance(x, PT))
    return sum(int(np.prod(t.shape)) for t in leaves)


def stack_layers(template_fn, n_layers: int):
    """Stack a per-layer template tree along a leading scan axis."""
    t = template_fn()
    return jax.tree_util.tree_map(
        lambda p: PT((n_layers,) + p.shape, p.init, (None,) + tuple(p.axes or (None,) * len(p.shape)),
                     p.dtype, p.scale),
        t, is_leaf=lambda x: isinstance(x, PT))


# ---------------------------------------------------------------------------
# Norms / activations.
# ---------------------------------------------------------------------------

def rmsnorm(w, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
            ).astype(x.dtype)


def layernorm(w, b, x, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# RoPE.
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (B, H, S, D) ; positions: (S,) or (B, S)."""
    d = x.shape[-1]
    inv = jnp.asarray(rope_freqs(d, theta), jnp.float32)
    pos = positions.astype(jnp.float32)
    ang = pos[..., None] * inv                 # (S, D/2) or (B, S, D/2)
    if ang.ndim == 2:
        ang = ang[None, None]                  # (1, 1, S, D/2)
    else:
        ang = ang[:, None]                     # (B, 1, S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def sinusoidal_positions(n: int, d: int):
    pos = np.arange(n)[:, None]
    div = np.exp(-np.log(10000.0) * np.arange(0, d, 2) / d)
    pe = np.zeros((n, d), np.float32)
    pe[:, 0::2] = np.sin(pos * div)
    pe[:, 1::2] = np.cos(pos * div)
    return jnp.asarray(pe)


# ---------------------------------------------------------------------------
# MLPs.
# ---------------------------------------------------------------------------

def swiglu_templates(d_model: int, d_ff: int):
    return {
        "gate": PT((d_model, d_ff), "scaled", ("embed", "ffn")),
        "up": PT((d_model, d_ff), "scaled", ("embed", "ffn")),
        "down": PT((d_ff, d_model), "scaled", ("ffn", "embed")),
    }


def swiglu_apply(p, x):
    g = silu(jnp.einsum("...d,df->...f", x, p["gate"]))
    u = jnp.einsum("...d,df->...f", x, p["up"])
    return jnp.einsum("...f,fd->...d", g * u, p["down"])


def gelu_mlp_templates(d_model: int, d_ff: int):
    return {
        "up": PT((d_model, d_ff), "scaled", ("embed", "ffn")),
        "up_b": PT((d_ff,), "zeros", ("ffn",)),
        "down": PT((d_ff, d_model), "scaled", ("ffn", "embed")),
        "down_b": PT((d_model,), "zeros", ("embed",)),
    }


def gelu_mlp_apply(p, x):
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["up"]) + p["up_b"])
    return jnp.einsum("...f,fd->...d", h, p["down"]) + p["down_b"]


# ---------------------------------------------------------------------------
# Embedding + chunked cross-entropy (fused with the LM head so the full
# (B, S, V) logits tensor never materializes).
# ---------------------------------------------------------------------------

def embed_templates(vocab: int, d_model: int):
    return {"embedding": PT((vocab, d_model), "normal", ("vocab", "embed"))}


def embed_lookup(p, tokens):
    return jnp.take(p["embedding"], tokens, axis=0)


def softmax_xent_chunked(h, w_out, labels, *, chunk=512, label_mask=None,
                         logit_softcap=None, valid_vocab=None):
    """h: (B, S, D), w_out: (D, V), labels: (B, S) int32.
    Returns (mean_loss, total_correct).  Scans over S chunks."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    while s % chunk:   # largest divisor of s <= requested chunk
        chunk -= 1     # (vlm text lengths like 3520 aren't powers of two)
    nc = s // chunk
    hc = jnp.moveaxis(h.reshape(b, nc, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)
    mc = (jnp.moveaxis(label_mask.reshape(b, nc, chunk), 1, 0)
          if label_mask is not None else jnp.ones_like(lc, jnp.float32))

    @jax.checkpoint  # recompute chunk logits in bwd: they are V-wide f32
    def step(carry, inp):
        from ..distributed.act_sharding import constrain
        loss_sum, n_tok, n_correct = carry
        hb, lb, mb = inp
        logits = jnp.einsum("bsd,dv->bsv", hb.astype(jnp.float32),
                            w_out.astype(jnp.float32))
        logits = constrain(logits, "logits")  # (B, chunk, V): V over TP
        if valid_vocab is not None and valid_vocab < logits.shape[-1]:
            # mask Megatron-style vocab padding columns
            col = jnp.arange(logits.shape[-1])
            logits = jnp.where(col < valid_vocab, logits, -1e30)
        if logit_softcap is not None:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        loss_sum += jnp.sum((lse - gold) * mb)
        n_tok += jnp.sum(mb)
        n_correct += jnp.sum((jnp.argmax(logits, -1) == lb) * mb)
        return (loss_sum, n_tok, n_correct), None

    init = (jnp.float32(0), jnp.float32(0), jnp.float32(0))
    (loss_sum, n_tok, n_correct), _ = jax.lax.scan(step, init, (hc, lc, mc))
    return loss_sum / jnp.maximum(n_tok, 1.0), n_correct / jnp.maximum(n_tok, 1.0)
