from .model import Model, build_model, decode_cache_specs, input_specs
