"""GQA attention layer (qk-norm, qkv-bias, sliding-window variants) with
train / prefill / decode modes over the kernels in ``repro.kernels``."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.act_sharding import constrain
from ..kernels import ops
from .layers import PT, apply_rope, rmsnorm


def attn_templates(cfg, *, bias: bool | None = None, d_model: int | None = None):
    d = d_model or cfg.d_model
    hd = cfg.head_dim_resolved
    bias = cfg.qkv_bias if bias is None else bias
    t = {
        "wq": PT((d, cfg.n_heads * hd), "scaled", ("embed", "qheads")),
        "wk": PT((d, cfg.n_kv_heads * hd), "scaled", ("embed", "kvheads")),
        "wv": PT((d, cfg.n_kv_heads * hd), "scaled", ("embed", "kvheads")),
        "wo": PT((cfg.n_heads * hd, d), "scaled", ("qheads", "embed")),
    }
    if bias:
        t["bq"] = PT((cfg.n_heads * hd,), "zeros", ("qheads",))
        t["bk"] = PT((cfg.n_kv_heads * hd,), "zeros", ("kvheads",))
        t["bv"] = PT((cfg.n_kv_heads * hd,), "zeros", ("kvheads",))
    if cfg.qk_norm:
        t["q_norm"] = PT((hd,), "zeros", (None,))
        t["k_norm"] = PT((hd,), "zeros", (None,))
    return t


def _project_qkv(p, x, cfg):
    b, s, _ = x.shape
    hd = cfg.head_dim_resolved
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = constrain(q, "heads")
    k = constrain(k, "heads")   # auto-replicates when Hkv < TP
    v = constrain(v, "heads")
    return q, k, v


def attn_forward(p, x, cfg, *, positions=None, window=None, causal=True,
                 cross_kv=None):
    """Full-sequence attention (training / encoder).  ``cross_kv``: optional
    (k, v) from an encoder (cross-attention skips RoPE and causality)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg) if cross_kv is None else (
        _project_q_only(p, x, cfg), *cross_kv)
    if cross_kv is None and cfg.rope_theta:
        pos = positions if positions is not None else jnp.arange(s)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    out = ops.attention(q, k, v, causal=causal and cross_kv is None,
                        window=window)
    out = constrain(out, "heads")
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


def _project_q_only(p, x, cfg):
    b, s, _ = x.shape
    hd = cfg.head_dim_resolved
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
    return q


def project_kv(p, x, cfg, *, positions=None, rope=True):
    """K/V projection only (cross-attention caches, prefill caches)."""
    b, s, _ = x.shape
    hd = cfg.head_dim_resolved
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if rope and cfg.rope_theta:
        pos = positions if positions is not None else jnp.arange(s)
        k = apply_rope(k, pos, cfg.rope_theta)
    return k, v


def attn_prefill(p, x, cfg, *, cache_len: int, window=None):
    """Prefill: run causal attention AND return the (possibly longer) KV
    cache padded to ``cache_len``.  Returns (out, (k_cache, v_cache))."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    if cfg.rope_theta:
        pos = jnp.arange(s)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    out = ops.attention(q, k, v, causal=True, window=window)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    pad = cache_len - s
    if pad > 0:
        zeros = jnp.zeros((b, cfg.n_kv_heads, pad, k.shape[-1]), k.dtype)
        k = jnp.concatenate([k, zeros], axis=2)
        v = jnp.concatenate([v, zeros], axis=2)
    elif pad < 0:
        # ring-buffer cache shorter than the prompt: keep the last
        # ``cache_len`` keys at their ring slots (token t -> slot t % W)
        shift = s % cache_len
        k = jnp.roll(k[:, :, -cache_len:], shift, axis=2)
        v = jnp.roll(v[:, :, -cache_len:], shift, axis=2)
    return out, (k, v)


def _project_decode_qkv(p, x, kv_len, cfg):
    """Single-token q/k/v projection with RoPE at position ``kv_len``
    ((B,) vector or scalar).  Shared by the dense and paged decode paths so
    both layouts see bitwise-identical projections."""
    b = x.shape[0]
    hd = cfg.head_dim_resolved
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, 1, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, 1, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, 1, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if cfg.rope_theta:
        pos = kv_len.reshape(b, 1) if kv_len.ndim else jnp.full((b, 1), kv_len)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


def attn_decode(p, x, k_cache, v_cache, kv_len, cfg, *, window=None,
                ring: bool = False):
    """One-token decode.  x: (B, 1, D); the new token's position is
    kv_len (0-based) and the caches are updated in place at that slot.
    ``ring=True``: the cache is a ring buffer of its full length W; the new
    kv goes to slot pos % W and attention covers min(pos+1, W) entries
    (slot order is irrelevant to softmax; keys carry absolute RoPE).
    Returns (out, k_cache, v_cache)."""
    b = x.shape[0]
    q, k, v = _project_decode_qkv(p, x, kv_len, cfg)
    # scatter the new kv at slot kv_len: in-place dynamic slice for a shared
    # scalar position (the serving engine's layout), one-hot blend otherwise
    w_cache = k_cache.shape[2]
    if kv_len.ndim == 0:
        slot = kv_len % w_cache if ring else kv_len
        attend = (jnp.minimum(kv_len + 1, w_cache) if ring else kv_len + 1)
        pos_b = jnp.full((b,), attend)
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, 2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, 2)
    else:
        slot = kv_len % w_cache if ring else kv_len
        pos_b = jnp.minimum(kv_len + 1, w_cache) if ring else kv_len + 1
        hot = jax.nn.one_hot(slot, w_cache, dtype=k_cache.dtype)
        k_cache = (k_cache * (1 - hot)[:, None, :, None]
                   + hot[:, None, :, None] * k)
        v_cache = (v_cache * (1 - hot)[:, None, :, None]
                   + hot[:, None, :, None] * v)
    out = ops.decode_attention(q, k_cache, v_cache, pos_b,
                               window=None if ring else window)
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), k_cache, v_cache


def attn_decode_paged(p, x, k_pool, v_pool, block_table, kv_len, cfg, *,
                      window=None):
    """One-token decode against a paged KV pool.  x: (B, 1, D);
    k_pool/v_pool: (n_blocks, Hkv, block_size, D) for this layer;
    block_table: (B, max_blocks) int32; kv_len: (B,) current lengths.

    The new token's KV lands in pool block ``block_table[b, kv_len // bs]``
    at offset ``kv_len % bs`` (the engine guarantees that entry is
    allocated before the step — idle slots' tables point at the null
    block, so their stale writes stay in scratch).
    Returns (out, k_pool, v_pool)."""
    b = x.shape[0]
    bs = k_pool.shape[2]
    q, k, v = _project_decode_qkv(p, x, kv_len, cfg)
    blk = jnp.take_along_axis(block_table, (kv_len // bs)[:, None],
                              axis=1)[:, 0]
    off = kv_len % bs
    # per-row scatter into the pool: rows own distinct blocks, so writes
    # never collide (idle rows all hit the null block — last write wins,
    # and nothing reads it)
    k_pool = k_pool.at[blk, :, off, :].set(k[:, :, 0, :])
    v_pool = v_pool.at[blk, :, off, :].set(v[:, :, 0, :])
    out = ops.paged_decode_attention(q, k_pool, v_pool, block_table,
                                     kv_len + 1, window=window)
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), k_pool, v_pool


def attn_prefill_paged(p, x, cfg, k_pool, v_pool, bt_row, chunk, *,
                       window=None):
    """One ``block_size`` chunk of a paged prefill.  x: (1, bs, d_model) —
    the chunk's hidden states, covering absolute positions
    ``[chunk * bs, (chunk + 1) * bs)``; k_pool/v_pool:
    (n_blocks, Hkv, bs, hd) for this layer; bt_row: (max_blocks,) int32
    block table of the request being prefilled; ``chunk`` may be traced
    (one compile serves every chunk of every prompt).

    The chunk's K/V are projected from just these bs rows and written
    straight into pool block ``bt_row[chunk]`` — no ``(Hkv, prompt_len, D)``
    cache is ever materialized — then the chunk's queries attend causally
    over blocks ``0..chunk`` through the block table
    (``ops.paged_prefill_attention``).  Returns (out, k_pool, v_pool)."""
    b, s, _ = x.shape
    bs = k_pool.shape[2]
    assert b == 1 and s == bs, (
        f"paged prefill runs one request in block_size chunks: got batch "
        f"{b}, chunk {s} vs block_size {bs}")
    q, k, v = _project_qkv(p, x, cfg)
    q_start = jnp.asarray(chunk, jnp.int32) * bs
    if cfg.rope_theta:
        pos = q_start + jnp.arange(s)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    blk = jnp.asarray(bt_row, jnp.int32)[jnp.asarray(chunk, jnp.int32)]
    k_pool = jax.lax.dynamic_update_index_in_dim(k_pool, k[0], blk, 0)
    v_pool = jax.lax.dynamic_update_index_in_dim(v_pool, v[0], blk, 0)
    out = ops.paged_prefill_attention(q, k_pool, v_pool, bt_row[None],
                                      q_start[None], window=window)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), k_pool, v_pool


def attn_cross_decode(p, x, k_cross, v_cross, cfg):
    """Decode-time cross-attention against fixed encoder KV."""
    b = x.shape[0]
    q = _project_q_only(p, x, cfg)
    kv_len = jnp.full((b,), k_cross.shape[2], jnp.int32)
    out = ops.decode_attention(q, k_cross, v_cross, kv_len)
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])
