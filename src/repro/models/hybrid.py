"""zamba2-style hybrid: Mamba2 backbone + one *shared* attention block
(single parameter set) applied after every ``shared_attn_every`` SSM layers.

Decode state is O(1)/token for the SSM layers; the shared attention block
uses a ring-buffered sliding-window cache (cfg.local_window) so the arch
stays sub-quadratic at long_500k (deviation from the HF full-attention
config recorded in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.act_sharding import constrain
from .attention import attn_decode, attn_forward, attn_prefill, attn_templates
from .layers import (PT, embed_lookup, embed_templates, rmsnorm,
                     softmax_xent_chunked, stack_layers, swiglu_apply,
                     swiglu_templates)
from .mamba2 import (mamba_decode, mamba_dims, mamba_forward, mamba_templates)
from .slot_state import make_slot_hooks
from .transformer import lm_head_weight


def hybrid_templates(cfg):
    dims = mamba_dims(cfg)
    t = {
        "embed": embed_templates(cfg.padded_vocab, cfg.d_model),
        "mamba": stack_layers(lambda: {
            "norm": PT((cfg.d_model,), "zeros", ("embed",)),
            "block": mamba_templates(dims)}, cfg.n_layers),
        "shared_attn": {
            "ln1": PT((cfg.d_model,), "zeros", ("embed",)),
            "attn": attn_templates(cfg),
            "ln2": PT((cfg.d_model,), "zeros", ("embed",)),
            "mlp": swiglu_templates(cfg.d_model, cfg.d_ff),
        },
        "final_norm": PT((cfg.d_model,), "zeros", ("embed",)),
        "lm_head": PT((cfg.d_model, cfg.padded_vocab), "scaled",
                      ("embed", "vocab")),
    }
    return t


def _split_groups(cfg):
    k = cfg.shared_attn_every
    n_groups = cfg.n_layers // k
    remainder = cfg.n_layers - n_groups * k
    return k, n_groups, remainder


def _group_reshape(tree, n_groups, k):
    return jax.tree_util.tree_map(
        lambda a: a[: n_groups * k].reshape((n_groups, k) + a.shape[1:]), tree)


def _tail_slice(tree, n_groups, k):
    return jax.tree_util.tree_map(lambda a: a[n_groups * k:], tree)


def _mamba_layer(lp, x, cfg, dims):
    h = rmsnorm(lp["norm"], x, cfg.norm_eps)
    return constrain(x + mamba_forward(lp["block"], h, dims,
                                       norm_eps=cfg.norm_eps), "hidden")


def _shared_attn_block(sp, x, cfg):
    h = rmsnorm(sp["ln1"], x, cfg.norm_eps)
    # the sliding window equals full attention at train_4k (W >= S) and keeps
    # serving consistent with the ring-buffered decode cache at 32k/500k
    x = x + attn_forward(sp["attn"], h, cfg, window=cfg.local_window)
    h = rmsnorm(sp["ln2"], x, cfg.norm_eps)
    return constrain(x + swiglu_apply(sp["mlp"], h), "hidden")


def hybrid_backbone(params, x, cfg, *, remat=True):
    dims = mamba_dims(cfg)
    k, n_groups, rem = _split_groups(cfg)
    grouped = _group_reshape(params["mamba"], n_groups, k)
    sp = params["shared_attn"]

    layer = _mamba_layer
    if remat:
        layer = jax.checkpoint(layer, static_argnums=(2, 3))

    def group_body(carry, gp):
        def inner(c, lp):
            return layer(lp, c, cfg, dims), None
        carry, _ = jax.lax.scan(inner, carry, gp)
        carry = _shared_attn_block(sp, carry, cfg)
        return carry, None

    x, _ = jax.lax.scan(group_body, x, grouped)
    if rem:
        tail = _tail_slice(params["mamba"], n_groups, k)

        def inner(c, lp):
            return layer(lp, c, cfg, dims), None
        x, _ = jax.lax.scan(inner, x, tail)
    return x


def hybrid_loss(params, batch, cfg, *, remat=True, xent_chunk=512):
    x = embed_lookup(params["embed"], batch["tokens"])
    x = constrain(x, "hidden")
    x = hybrid_backbone(params, x, cfg, remat=remat)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    loss, acc = softmax_xent_chunked(
        x, params["lm_head"], batch["labels"], chunk=xent_chunk,
        label_mask=batch.get("label_mask"),
        valid_vocab=cfg.vocab_size)
    return loss, {"loss": loss, "accuracy": acc}


# ---------------------------------------------------------------------------
# Serving.
#
# Every cache leaf keeps its batch (serving slot) dimension at axis 1:
# the Mamba2 conv tails / SSD states are stacked (n_layers, B, …), the
# shared attention block's ring-buffered sliding-window KV is
# (n_groups, B, Hkv, W, hd).  One slot therefore owns one index of each
# leaf plus one entry of the (B,) position vector, and the slot hooks
# below make the family continuously batchable: admission writes a
# batch-1 prefill's state into a freed slot, eviction zeroes it (see
# ``repro.models.slot_state``).  The ring cache needs no per-slot width
# bookkeeping — decode writes at ``pos % W`` per row, so each slot's ring
# phase rides entirely in its own ``pos`` entry.
# ---------------------------------------------------------------------------

# batch axis of every cache leaf (the serving slot axis)
HYBRID_STATE_AXES = {"conv": 1, "ssm": 1, "attn_k": 1, "attn_v": 1}

hybrid_cache_expand, hybrid_cache_slot_write, hybrid_cache_slot_reset = \
    make_slot_hooks(HYBRID_STATE_AXES)


def hybrid_cache_shapes(cfg, batch_size: int, cache_len: int,
                        dtype=jnp.bfloat16):
    dims = mamba_dims(cfg)
    k, n_groups, _ = _split_groups(cfg)
    w = min(cache_len, cfg.local_window or cache_len)
    hd = cfg.head_dim_resolved
    return {
        "conv": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch_size, dims.d_conv - 1, dims.conv_dim), dtype),
        "ssm": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch_size, dims.n_heads, dims.head_dim,
             dims.d_state), jnp.float32),
        "attn_k": jax.ShapeDtypeStruct(
            (n_groups, batch_size, cfg.n_kv_heads, w, hd), dtype),
        "attn_v": jax.ShapeDtypeStruct(
            (n_groups, batch_size, cfg.n_kv_heads, w, hd), dtype),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def hybrid_prefill(params, batch, cfg, *, cache_len=None):
    dims = mamba_dims(cfg)
    k, n_groups, rem = _split_groups(cfg)
    x = embed_lookup(params["embed"], batch["tokens"])
    s = x.shape[1]
    cache_len = cache_len or s
    w = min(cache_len, cfg.local_window or cache_len)
    grouped = _group_reshape(params["mamba"], n_groups, k)
    sp = params["shared_attn"]

    def mamba_step(c, lp):
        h = rmsnorm(lp["norm"], c, cfg.norm_eps)
        out, (conv, ssm) = mamba_forward(lp["block"], h, dims,
                                         return_state=True,
                                         norm_eps=cfg.norm_eps)
        return c + out, (conv, ssm)

    def group_body(carry, gp):
        carry, states = jax.lax.scan(mamba_step, carry, gp)
        h = rmsnorm(sp["ln1"], carry, cfg.norm_eps)
        a, kv = attn_prefill(sp["attn"], h, cfg, cache_len=w,
                             window=cfg.local_window)
        carry = carry + a
        h = rmsnorm(sp["ln2"], carry, cfg.norm_eps)
        carry = carry + swiglu_apply(sp["mlp"], h)
        return carry, (states, kv)

    x, (mstates, attn_kv) = jax.lax.scan(group_body, x, grouped)
    convs, ssms = mstates  # (G, k, B, ...) each
    convs = convs.reshape((n_groups * k,) + convs.shape[2:])
    ssms = ssms.reshape((n_groups * k,) + ssms.shape[2:])
    if rem:
        tail = _tail_slice(params["mamba"], n_groups, k)
        x, (tc, ts) = jax.lax.scan(mamba_step, x, tail)
        convs = jnp.concatenate([convs, tc], axis=0)
        ssms = jnp.concatenate([ssms, ts], axis=0)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    logits = logits[:, :cfg.vocab_size]
    cache = {"conv": convs, "ssm": ssms,
             "attn_k": attn_kv[0], "attn_v": attn_kv[1],
             "pos": jnp.int32(s)}
    return logits, cache


def hybrid_decode_step(params, cache, tokens, cfg):
    dims = mamba_dims(cfg)
    k, n_groups, rem = _split_groups(cfg)
    x = embed_lookup(params["embed"], tokens)
    pos = cache["pos"]
    grouped = _group_reshape(params["mamba"], n_groups, k)
    sp = params["shared_attn"]

    def mamba_step(c, inp):
        lp, conv, ssm = inp
        h = rmsnorm(lp["norm"], c, cfg.norm_eps)
        out, conv, ssm = mamba_decode(lp["block"], h, conv, ssm, dims,
                                      norm_eps=cfg.norm_eps)
        return c + out, (conv, ssm)

    conv_g = cache["conv"][: n_groups * k].reshape(
        (n_groups, k) + cache["conv"].shape[1:])
    ssm_g = cache["ssm"][: n_groups * k].reshape(
        (n_groups, k) + cache["ssm"].shape[1:])

    def group_body(carry, inp):
        gp, convs, ssms, kc, vc = inp
        carry, states = jax.lax.scan(mamba_step, carry, (gp, convs, ssms))
        h = rmsnorm(sp["ln1"], carry, cfg.norm_eps)
        a, kc, vc = attn_decode(sp["attn"], h, kc, vc, pos, cfg, ring=True)
        carry = carry + a
        h = rmsnorm(sp["ln2"], carry, cfg.norm_eps)
        carry = carry + swiglu_apply(sp["mlp"], h)
        return carry, (states, kc, vc)

    x, (mstates, k_new, v_new) = jax.lax.scan(
        group_body, x, (grouped, conv_g, ssm_g, cache["attn_k"],
                        cache["attn_v"]))
    convs, ssms = mstates
    convs = convs.reshape((n_groups * k,) + convs.shape[2:])
    ssms = ssms.reshape((n_groups * k,) + ssms.shape[2:])
    if rem:
        tail = _tail_slice(params["mamba"], n_groups, k)
        x, (tc, ts) = jax.lax.scan(
            mamba_step, x,
            (tail, cache["conv"][n_groups * k:], cache["ssm"][n_groups * k:]))
        convs = jnp.concatenate([convs, tc], axis=0)
        ssms = jnp.concatenate([ssms, ts], axis=0)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    logits = logits[:, :cfg.vocab_size]
    cache = {"conv": convs, "ssm": ssms, "attn_k": k_new, "attn_v": v_new,
             "pos": pos + 1}
    return logits, cache
