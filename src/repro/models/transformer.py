"""Decoder-only transformer families: dense, moe, vlm.

One scanned layer body per family (homogeneous stacks compile to small HLO
even at 94 layers); gemma3's 5:1 local:global pattern rides through the scan
as a per-layer traced window scalar.  Train / prefill / decode share the
same parameter tree.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.act_sharding import constrain
from .attention import (attn_decode, attn_decode_paged, attn_forward,
                        attn_prefill, attn_prefill_paged, attn_templates)
from .layers import (PT, embed_lookup, embed_templates, init_params,
                     param_pspecs, rmsnorm, softmax_xent_chunked,
                     stack_layers, swiglu_apply, swiglu_templates)
from .moe import moe_apply, moe_templates

_BIG_WINDOW = 1 << 30  # "global" layers: window larger than any context


# ---------------------------------------------------------------------------
# Templates.
# ---------------------------------------------------------------------------

def layer_templates(cfg):
    t = {
        "ln1": PT((cfg.d_model,), "zeros", ("embed",)),
        "attn": attn_templates(cfg),
        "ln2": PT((cfg.d_model,), "zeros", ("embed",)),
    }
    if cfg.family == "moe":
        t["moe"] = moe_templates(cfg.d_model, cfg.d_ff, cfg.n_experts)
    else:
        t["mlp"] = swiglu_templates(cfg.d_model, cfg.d_ff)
    return t


def decoder_templates(cfg):
    t = {
        "embed": embed_templates(cfg.padded_vocab, cfg.d_model),
        "layers": stack_layers(lambda: layer_templates(cfg), cfg.n_layers),
        "final_norm": PT((cfg.d_model,), "zeros", ("embed",)),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = PT((cfg.d_model, cfg.padded_vocab), "scaled",
                          ("embed", "vocab"))
    if cfg.family == "vlm":
        t["patch_proj"] = PT((cfg.patch_embed_dim, cfg.d_model), "scaled",
                             (None, "embed"))
    return t


def windows_array(cfg) -> jnp.ndarray | None:
    """Per-layer sliding windows as a traced scan input (None if uniform)."""
    if not cfg.local_window:
        return None
    ws = [cfg.layer_window(i) or _BIG_WINDOW for i in range(cfg.n_layers)]
    return jnp.asarray(ws, jnp.int32)


def lm_head_weight(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"]["embedding"].T
    return params["lm_head"]


def _lm_logits(params, x_last, cfg):
    """(B, D) final-norm'd last-token hiddens -> (B, V) serving logits."""
    logits = jnp.einsum("bd,dv->bv", x_last.astype(jnp.float32),
                        lm_head_weight(params, cfg).astype(jnp.float32))
    logits = logits[:, :cfg.vocab_size]
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


# ---------------------------------------------------------------------------
# Layer body (shared by train/prefill; decode has its own).
# ---------------------------------------------------------------------------

def _ffn(lp, h, cfg, exact=False):
    if cfg.family == "moe":
        return moe_apply(lp["moe"], h, top_k=cfg.top_k,
                         capacity_factor=cfg.capacity_factor, exact=exact)
    return swiglu_apply(lp["mlp"], h)


def _layer(lp, x, cfg, window, positions):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    x = x + attn_forward(lp["attn"], h, cfg, positions=positions,
                         window=window)
    x = constrain(x, "hidden")
    h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    x = x + _ffn(lp, h, cfg)
    return constrain(x, "hidden")


def _scan_layers(params, x, cfg, positions, *, remat=False):
    windows = windows_array(cfg)
    body = functools.partial(_layer, cfg=cfg, positions=positions)
    if remat:
        body = jax.checkpoint(body, static_argnums=())

    if windows is None:
        def scan_fn(carry, lp):
            return body(lp, carry, window=None), None
        x, _ = jax.lax.scan(scan_fn, x, params["layers"])
    else:
        def scan_fn(carry, inp):
            lp, w = inp
            return body(lp, carry, window=w), None
        x, _ = jax.lax.scan(scan_fn, x, (params["layers"], windows))
    return x


# ---------------------------------------------------------------------------
# Embedding of the (token | patch+token) input.
# ---------------------------------------------------------------------------

def embed_input(params, batch, cfg):
    """Returns (x (B, S_total, D), n_prefix).  For vlm, the stub patch
    embeddings occupy the first n_patches positions."""
    tok = embed_lookup(params["embed"], batch["tokens"])
    if cfg.family == "vlm":
        patches = jnp.einsum("bpe,ed->bpd", batch["patches"],
                             params["patch_proj"]).astype(tok.dtype)
        return jnp.concatenate([patches, tok], axis=1), cfg.n_patches
    return tok, 0


# ---------------------------------------------------------------------------
# Train forward + loss.
# ---------------------------------------------------------------------------

def decoder_loss(params, batch, cfg, *, remat=True, xent_chunk=512):
    x, n_prefix = embed_input(params, batch, cfg)
    x = constrain(x, "hidden")
    s_total = x.shape[1]
    positions = jnp.arange(s_total)
    x = _scan_layers(params, x, cfg, positions, remat=remat)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if n_prefix:
        x = x[:, n_prefix:]
    loss, acc = softmax_xent_chunked(
        x, lm_head_weight(params, cfg), batch["labels"], chunk=xent_chunk,
        label_mask=batch.get("label_mask"), logit_softcap=cfg.logit_softcap,
        valid_vocab=cfg.vocab_size)
    return loss, {"loss": loss, "accuracy": acc}


# ---------------------------------------------------------------------------
# Serving: prefill + decode.
# ---------------------------------------------------------------------------

def decoder_prefill(params, batch, cfg, *, cache_len=None):
    """Returns (last-token logits (B, V), cache dict).

    ``batch["prefill_len"]`` (optional, (B,) int32): per-row true token
    count when ``tokens`` is right-padded to a bucket length (the serving
    engine's prompt-length bucketing).  Causality already hides the pads
    from real tokens, pad KV lands at positions >= the true length (masked
    in decode and overwritten as decode proceeds), so only the last-token
    gather and the cache position depend on it; ``cache["pos"]`` becomes a
    (B,) vector of true lengths."""
    x, n_prefix = embed_input(params, batch, cfg)
    s_total = x.shape[1]
    cache_len = cache_len or s_total
    assert cache_len >= s_total, (
        f"cache_len {cache_len} < prompt length {s_total} "
        "(vlm prompts include n_patches prefix positions)")
    windows = windows_array(cfg)

    b = x.shape[0]
    hd = cfg.head_dim_resolved
    cache_shape = (cfg.n_layers, b, cfg.n_kv_heads, cache_len, hd)
    k0 = jnp.zeros(cache_shape, x.dtype)
    v0 = jnp.zeros(cache_shape, x.dtype)

    def scan_fn(carry, inp):
        x, kc_all, vc_all = carry
        if windows is None:
            (lp, idx), w = inp, None
        else:
            lp, idx, w = inp
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        a, (kc, vc) = attn_prefill(lp["attn"], h, cfg, cache_len=cache_len,
                                   window=w)
        x = x + a
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        # exact=True routes moe dropless: serving prefill must produce the
        # same hiddens as the chunked paged prefill (which is dropless by
        # construction at chunk length <= capacity), so paged==dense token
        # identity holds for the moe family too.  Training keeps
        # capacity-factor routing (decoder_loss does not share this body).
        x = constrain(x + _ffn(lp, h, cfg, exact=True), "hidden")
        # write the layer cache in place (carried, not stacked as scan ys:
        # ys accumulation double-buffers the full multi-GB cache)
        kc_all = jax.lax.dynamic_update_index_in_dim(kc_all, kc, idx, 0)
        vc_all = jax.lax.dynamic_update_index_in_dim(vc_all, vc, idx, 0)
        return (x, kc_all, vc_all), None

    idxs = jnp.arange(cfg.n_layers)
    xs = ((params["layers"], idxs) if windows is None
          else (params["layers"], idxs, windows))
    (x, k_cache, v_cache), _ = jax.lax.scan(scan_fn, (x, k0, v0), xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if "prefill_len" in batch:
        pos = n_prefix + batch["prefill_len"].astype(jnp.int32)   # (B,)
        x_last = jnp.take_along_axis(x, (pos - 1)[:, None, None],
                                     axis=1)[:, 0]
    else:
        pos = jnp.int32(s_total)
        x_last = x[:, -1]
    cache = {"k": k_cache, "v": v_cache, "pos": pos}
    return _lm_logits(params, x_last, cfg), cache


def _decode_scan(params, tokens, k_all, v_all, cfg, attn_fn):
    """Shared one-token decode body for both KV layouts: embed, scan the
    layer stack updating each layer's KV slice in place, final-norm, lm
    head.  ``attn_fn(lp, h, kc, vc, window) -> (attn_out, kc, vc)`` is the
    only layout-specific piece.

    The stacked KV caches ride in the scan *carry* and each layer updates
    its slice in place (dynamic_update_index): with the cache donated, XLA
    aliases the whole while-loop state.  Carrying them as scan xs/ys
    double-buffers the full cache (~2.6x cache bytes of temp measured on
    phi-3-vision decode_32k; see EXPERIMENTS.md §Perf).
    Returns (logits, k_all, v_all)."""
    x = embed_lookup(params["embed"], tokens)
    windows = windows_array(cfg)

    def scan_fn(carry, inp):
        x, kc_all, vc_all = carry
        if windows is None:
            (lp, idx), w = inp, None
        else:
            lp, idx, w = inp
        kc = jax.lax.dynamic_index_in_dim(kc_all, idx, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vc_all, idx, 0, keepdims=False)
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        a, kc, vc = attn_fn(lp["attn"], h, kc, vc, w)
        x = x + a
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + _ffn(lp, h, cfg, exact=True)
        kc_all = jax.lax.dynamic_update_index_in_dim(kc_all, kc, idx, 0)
        vc_all = jax.lax.dynamic_update_index_in_dim(vc_all, vc, idx, 0)
        return (x, kc_all, vc_all), None

    idxs = jnp.arange(cfg.n_layers)
    xs = ((params["layers"], idxs) if windows is None
          else (params["layers"], idxs, windows))
    (x, k_all, v_all), _ = jax.lax.scan(scan_fn, (x, k_all, v_all), xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _lm_logits(params, x[:, -1], cfg), k_all, v_all


def decoder_decode_step(params, cache, tokens, cfg):
    """tokens: (B, 1).  Returns (logits (B, V), new cache).

    ``cache["pos"]`` is either a scalar (uniform-position layout: every row
    decodes at the same position) or a (B,) vector (the serving engine's
    slot-pool layout: each slot tracks its own position; the new KV lands
    at each row's own slot via the one-hot path in ``attn_decode``)."""
    pos = cache["pos"]
    logits, k_new, v_new = _decode_scan(
        params, tokens, cache["k"], cache["v"], cfg,
        lambda lp, h, kc, vc, w: attn_decode(lp, h, kc, vc, pos, cfg,
                                             window=w))
    return logits, {"k": k_new, "v": v_new, "pos": pos + 1}


def make_decode_cache_specs(cfg, batch_size: int, cache_len: int,
                            dtype=jnp.bfloat16):
    """ShapeDtypeStructs for the decode cache (dry-run inputs)."""
    hd = cfg.head_dim_resolved
    shape = (cfg.n_layers, batch_size, cfg.n_kv_heads, cache_len, hd)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


# ---------------------------------------------------------------------------
# Slot-pool cache support (continuous-batching serving).
# ---------------------------------------------------------------------------

def decoder_cache_expand(sub, batch: int):
    """Grow a batch-1 prefill cache into an empty ``batch``-slot decode
    cache.  Positions become a per-slot (B,) vector; all slots start empty
    (pos 0), to be filled by :func:`decoder_cache_slot_write` on admission."""
    def grow(x):
        return jnp.zeros(x.shape[:1] + (batch,) + x.shape[2:], x.dtype)
    return {"k": grow(sub["k"]), "v": grow(sub["v"]),
            "pos": jnp.zeros((batch,), jnp.int32)}


def decoder_cache_slot_write(cache, sub, slot):
    """Write a batch-1 prefill cache into batch index ``slot`` of a
    slot-pool decode cache (prefill-on-admit).  ``slot`` may be traced, so
    a jitted caller compiles once for all slots."""
    k = jax.lax.dynamic_update_index_in_dim(cache["k"], sub["k"][:, 0],
                                            slot, 1)
    v = jax.lax.dynamic_update_index_in_dim(cache["v"], sub["v"][:, 0],
                                            slot, 1)
    pos = jax.lax.dynamic_update_index_in_dim(
        cache["pos"],
        jnp.reshape(jnp.asarray(sub["pos"], jnp.int32), ()), slot, 0)
    return {"k": k, "v": v, "pos": pos}


# ---------------------------------------------------------------------------
# Paged KV cache (block-pool serving layout; see repro.serving.kvcache).
# ---------------------------------------------------------------------------

def decoder_paged_cache_init(cfg, *, batch: int, n_blocks: int,
                             block_size: int, max_blocks: int,
                             dtype=jnp.bfloat16):
    """Empty paged decode cache: one global KV block pool shared by all
    ``batch`` slots, per-slot block tables pointing at the null block, and
    per-slot positions at 0."""
    hd = cfg.head_dim_resolved
    pool = (cfg.n_layers, n_blocks, cfg.n_kv_heads, block_size, hd)
    return {"kp": jnp.zeros(pool, dtype), "vp": jnp.zeros(pool, dtype),
            "bt": jnp.zeros((batch, max_blocks), jnp.int32),
            "pos": jnp.zeros((batch,), jnp.int32)}


def decoder_cache_dtype(params):
    """KV dtype a prefill would produce (the embedding activations'
    dtype) — lets the engine build the paged pool before any prefill
    has run."""
    return params["embed"]["embedding"].dtype


def _embed_chunk(params, batch, q_start, bs, cfg):
    """Embed combined positions ``[q_start, q_start + bs)`` of a prompt.

    ``batch["tokens"]``: (1, bs) token ids aligned to those positions (the
    engine feeds 0 where a position is a model-side prefix row or pad).
    For vlm, positions below ``n_patches`` take the projected patch
    embedding instead of the token row."""
    tok = embed_lookup(params["embed"], batch["tokens"])       # (1, bs, D)
    if cfg.family != "vlm":
        return tok
    patches = jnp.einsum("bpe,ed->bpd", batch["patches"],
                         params["patch_proj"]).astype(tok.dtype)
    pos = q_start + jnp.arange(bs)                             # (bs,)
    pat = jnp.take(patches[0], jnp.clip(pos, 0, cfg.n_patches - 1), axis=0)
    return jnp.where((pos < cfg.n_patches)[None, :, None], pat[None], tok)


def decoder_prefill_paged(params, pcache, batch, slot, chunk, prefill_len,
                          cfg):
    """One ``block_size`` chunk of a paged prefill for a single request.

    Chunked prefill: the chunk's hidden states run through the whole layer
    stack; each layer projects the chunk's K/V, writes them straight into
    the pool block ``pcache["bt"][slot, chunk]`` (installed by the engine
    before the call), and attends causally over blocks ``0..chunk`` via
    the block table — the dense batch-1 ``(L, Hkv, prompt_len, hd)``
    prefill cache of the scatter-on-admit path never exists.  ``slot``,
    ``chunk`` and ``prefill_len`` may all be traced, so one compile serves
    every chunk of every prompt at every slot (no length bucketing
    needed).

    MoE chunks route with exact (dropless) dispatch like decode: capacity
    dropping depends on the batch a token shares, which would make a
    chunk's output depend on where the chunk boundaries fall.

    Returns (last-true-token logits (1, V), new pcache) — the logits row
    is the request's next-token distribution only on the final chunk
    (``prefill_len <= (chunk + 1) * bs``); earlier chunks return a
    mid-prompt row the engine discards.  ``pcache["pos"][slot]`` advances
    to ``min((chunk + 1) * bs, prefill_len)``."""
    bs = pcache["kp"].shape[3]
    chunk = jnp.asarray(chunk, jnp.int32)
    prefill_len = jnp.asarray(prefill_len, jnp.int32)
    q_start = chunk * bs
    x = _embed_chunk(params, batch, q_start, bs, cfg)
    x = constrain(x, "hidden")
    bt_row = jax.lax.dynamic_index_in_dim(pcache["bt"], slot, 0,
                                          keepdims=False)      # (M,)
    windows = windows_array(cfg)

    def scan_fn(carry, inp):
        x, kp_all, vp_all = carry
        if windows is None:
            (lp, idx), w = inp, None
        else:
            lp, idx, w = inp
        kp = jax.lax.dynamic_index_in_dim(kp_all, idx, 0, keepdims=False)
        vp = jax.lax.dynamic_index_in_dim(vp_all, idx, 0, keepdims=False)
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        a, kp, vp = attn_prefill_paged(lp["attn"], h, cfg, kp, vp, bt_row,
                                       chunk, window=w)
        x = x + a
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = constrain(x + _ffn(lp, h, cfg, exact=True), "hidden")
        kp_all = jax.lax.dynamic_update_index_in_dim(kp_all, kp, idx, 0)
        vp_all = jax.lax.dynamic_update_index_in_dim(vp_all, vp, idx, 0)
        return (x, kp_all, vp_all), None

    idxs = jnp.arange(cfg.n_layers)
    xs = ((params["layers"], idxs) if windows is None
          else (params["layers"], idxs, windows))
    (x, kp, vp), _ = jax.lax.scan(
        scan_fn, (x, pcache["kp"], pcache["vp"]), xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    # last *true* row of this chunk (meaningful on the final chunk only)
    last = jnp.clip(prefill_len - 1 - q_start, 0, bs - 1)
    x_last = jax.lax.dynamic_index_in_dim(x[0], last, 0, keepdims=True)
    pos = pcache["pos"].at[slot].set(
        jnp.minimum(q_start + bs, prefill_len))
    return _lm_logits(params, x_last, cfg), {
        "kp": kp, "vp": vp, "bt": pcache["bt"], "pos": pos}


def decoder_decode_step_paged(params, pcache, tokens, cfg):
    """tokens: (B, 1) against the paged cache
    {"kp"/"vp": (L, n_blocks, Hkv, bs, hd), "bt": (B, M), "pos": (B,)}.
    Same layer body as :func:`decoder_decode_step`; only the KV read/write
    goes through the block table."""
    pos, bt = pcache["pos"], pcache["bt"]
    logits, kp, vp = _decode_scan(
        params, tokens, pcache["kp"], pcache["vp"], cfg,
        lambda lp, h, kc, vc, w: attn_decode_paged(lp, h, kc, vc, bt, pos,
                                                   cfg, window=w))
    return logits, {"kp": kp, "vp": vp, "bt": bt, "pos": pos + 1}
