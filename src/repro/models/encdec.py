"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, d_model).  Sinusoidal positions on
both sides (deviation from whisper's learned decoder positions recorded in
DESIGN.md).  GELU MLPs, pre-LN, LayerNorm (not RMSNorm).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.act_sharding import constrain
from .attention import (attn_cross_decode, attn_decode, attn_forward,
                        attn_prefill, attn_templates, project_kv)
from .layers import (PT, embed_lookup, embed_templates, gelu_mlp_apply,
                     gelu_mlp_templates, layernorm, sinusoidal_positions,
                     softmax_xent_chunked, stack_layers)
from .slot_state import make_slot_hooks

CROSS_LEN = 1500  # whisper's 30 s encoder output length (serving cells)


def _ln_t(d):
    return {"w": PT((d,), "ones", ("embed",)), "b": PT((d,), "zeros",
                                                       ("embed",))}


def _ln(p, x, eps):
    return layernorm(p["w"], p["b"], x, eps)


def encdec_templates(cfg):
    d = cfg.d_model
    return {
        "embed": embed_templates(cfg.padded_vocab, d),
        "enc_layers": stack_layers(lambda: {
            "ln1": _ln_t(d), "attn": attn_templates(cfg),
            "ln2": _ln_t(d), "mlp": gelu_mlp_templates(d, cfg.d_ff),
        }, cfg.n_enc_layers),
        "enc_final": _ln_t(d),
        "dec_layers": stack_layers(lambda: {
            "ln1": _ln_t(d), "self_attn": attn_templates(cfg),
            "lnx": _ln_t(d), "cross_attn": attn_templates(cfg),
            "ln2": _ln_t(d), "mlp": gelu_mlp_templates(d, cfg.d_ff),
        }, cfg.n_layers),
        "dec_final": _ln_t(d),
        "lm_head": PT((d, cfg.padded_vocab), "scaled", ("embed", "vocab")),
    }


def encode(params, frames, cfg):
    s = frames.shape[1]
    x = frames + sinusoidal_positions(s, cfg.d_model).astype(frames.dtype)

    def body(carry, lp):
        h = _ln(lp["ln1"], carry, cfg.norm_eps)
        carry = carry + attn_forward(lp["attn"], h, cfg, causal=False)
        h = _ln(lp["ln2"], carry, cfg.norm_eps)
        carry = constrain(carry + gelu_mlp_apply(lp["mlp"], h), "hidden")
        return carry, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return _ln(params["enc_final"], x, cfg.norm_eps)


def _decoder(params, tokens, enc_out, cfg, *, remat=False):
    s = tokens.shape[1]
    x = embed_lookup(params["embed"], tokens)
    x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)

    def body(carry, lp):
        h = _ln(lp["ln1"], carry, cfg.norm_eps)
        carry = carry + attn_forward(lp["self_attn"], h, cfg, causal=True)
        h = _ln(lp["lnx"], carry, cfg.norm_eps)
        ckv = project_kv(lp["cross_attn"], enc_out, cfg, rope=False)
        carry = carry + attn_forward(lp["cross_attn"], h, cfg,
                                     cross_kv=ckv)
        h = _ln(lp["ln2"], carry, cfg.norm_eps)
        carry = constrain(carry + gelu_mlp_apply(lp["mlp"], h), "hidden")
        return carry, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return _ln(params["dec_final"], x, cfg.norm_eps)


def encdec_loss(params, batch, cfg, *, remat=True, xent_chunk=512):
    enc_out = encode(params, batch["frames"], cfg)
    x = _decoder(params, batch["tokens"], enc_out, cfg, remat=remat)
    loss, acc = softmax_xent_chunked(
        x, params["lm_head"], batch["labels"], chunk=xent_chunk,
        label_mask=batch.get("label_mask"),
        valid_vocab=cfg.vocab_size)
    return loss, {"loss": loss, "accuracy": acc}


# ---------------------------------------------------------------------------
# Serving.
#
# Decode state per request: a decoder self-attention KV strip
# (k/v, written at ``pos``), plus the *cross-attention KV strip* (xk/xv)
# projected once from the request's encoder output at prefill and read-only
# afterwards.  All four leaves are stacked (n_layers, B, …) with batch at
# axis 1, so a slot owns one index of each — the cross strip rides in the
# slot exactly like self KV, which is what lets encoder-decoder requests
# enter/leave a continuous batch one at a time instead of re-encoding a
# whole lock-step group (slot hooks from ``repro.models.slot_state``).
# ---------------------------------------------------------------------------

# batch axis of every cache leaf (the serving slot axis)
ENCDEC_STATE_AXES = {"k": 1, "v": 1, "xk": 1, "xv": 1}

encdec_cache_expand, encdec_cache_slot_write, encdec_cache_slot_reset = \
    make_slot_hooks(ENCDEC_STATE_AXES)


def encdec_cache_shapes(cfg, batch_size: int, cache_len: int,
                        dtype=jnp.bfloat16):
    hd = cfg.head_dim_resolved
    l, b = cfg.n_layers, batch_size
    return {
        "k": jax.ShapeDtypeStruct((l, b, cfg.n_kv_heads, cache_len, hd),
                                  dtype),
        "v": jax.ShapeDtypeStruct((l, b, cfg.n_kv_heads, cache_len, hd),
                                  dtype),
        "xk": jax.ShapeDtypeStruct((l, b, cfg.n_kv_heads, CROSS_LEN, hd),
                                   dtype),
        "xv": jax.ShapeDtypeStruct((l, b, cfg.n_kv_heads, CROSS_LEN, hd),
                                   dtype),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def encdec_prefill(params, batch, cfg, *, cache_len=None):
    """Encode frames, project cross KV, prefill the decoder self-cache with
    ``tokens`` (the forced/prompt tokens)."""
    enc_out = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache_len = cache_len or s
    x = embed_lookup(params["embed"], tokens)
    x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)

    def body(carry, lp):
        h = _ln(lp["ln1"], carry, cfg.norm_eps)
        a, kv = attn_prefill(lp["self_attn"], h, cfg, cache_len=cache_len)
        carry = carry + a
        ckv = project_kv(lp["cross_attn"], enc_out, cfg, rope=False)
        h = _ln(lp["lnx"], carry, cfg.norm_eps)
        carry = carry + attn_forward(lp["cross_attn"], h, cfg, cross_kv=ckv)
        h = _ln(lp["ln2"], carry, cfg.norm_eps)
        carry = carry + gelu_mlp_apply(lp["mlp"], h)
        return carry, (kv, ckv)

    x, ((k, v), (xk, xv)) = jax.lax.scan(body, x, params["dec_layers"])
    x = _ln(params["dec_final"], x, cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    logits = logits[:, :cfg.vocab_size]
    cache = {"k": k, "v": v, "xk": xk, "xv": xv, "pos": jnp.int32(s)}
    return logits, cache


def encdec_decode_step(params, cache, tokens, cfg):
    """One-token decoder step.  ``cache["pos"]`` is a scalar (lock-step
    layout: every row at the same position) or a (B,) vector (slot-pool
    layout: each slot decodes at its own position)."""
    pos = cache["pos"]
    x = embed_lookup(params["embed"], tokens)
    # dynamic positional vector: sin/cos recomputed at pos (no giant
    # table), one row per slot when positions differ
    import numpy as np
    d = cfg.d_model
    div = jnp.asarray(np.exp(-np.log(10000.0) * np.arange(0, d, 2) / d))
    ang = jnp.atleast_1d(pos).astype(jnp.float32)[:, None] * div  # (P, d/2)
    pvec = jnp.zeros((ang.shape[0], d), jnp.float32)
    pvec = pvec.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    x = x + pvec[:, None, :].astype(x.dtype)   # broadcasts when P == 1

    def body(carry, inp):
        x, kc_all, vc_all = carry
        lp, idx, xk, xv = inp
        kc = jax.lax.dynamic_index_in_dim(kc_all, idx, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vc_all, idx, 0, keepdims=False)
        h = _ln(lp["ln1"], x, cfg.norm_eps)
        a, kc, vc = attn_decode(lp["self_attn"], h, kc, vc, pos, cfg)
        x = x + a
        h = _ln(lp["lnx"], x, cfg.norm_eps)
        x = x + attn_cross_decode(lp["cross_attn"], h, xk, xv, cfg)
        h = _ln(lp["ln2"], x, cfg.norm_eps)
        x = x + gelu_mlp_apply(lp["mlp"], h)
        kc_all = jax.lax.dynamic_update_index_in_dim(kc_all, kc, idx, 0)
        vc_all = jax.lax.dynamic_update_index_in_dim(vc_all, vc, idx, 0)
        return (x, kc_all, vc_all), None

    (x, k_new, v_new), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["dec_layers"], jnp.arange(cfg.n_layers),
         cache["xk"], cache["xv"]))
    x = _ln(params["dec_final"], x, cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    logits = logits[:, :cfg.vocab_size]
    cache = {"k": k_new, "v": v_new, "xk": cache["xk"], "xv": cache["xv"],
             "pos": pos + 1}
    return logits, cache
