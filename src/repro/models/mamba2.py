"""Mamba2 (SSD) block: fused in-projection, causal depthwise conv, SSD scan
(``repro.kernels.ssd_scan``), gated RMSNorm, out-projection.

Decode keeps O(1)/token state: (conv_state (B, K-1, conv_dim),
ssm_state (B, H, P, N)) - this is what makes the hybrid/ssm archs eligible
for the ``long_500k`` cell.

Both state tensors lead with the batch dimension and carry **no
cross-sequence coupling**: every op in ``mamba_decode`` is elementwise or
contracts only non-batch axes, so row ``b`` of the state is a complete,
independently addressable description of sequence ``b``.  That per-row
independence is the contract the serving layer's slot-addressable cache
hooks build on (``repro.models.slot_state``): a continuous-batching slot
pool can admit a new request into row ``b`` (overwriting just that row
with a batch-1 prefill's final state), evict it, or zero it, without
touching — or re-prefilling — any neighbor.  The hybrid family stacks
these rows as ``(n_layers, B, ...)`` cache leaves (``hybrid.py``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..kernels import ops
from .layers import PT, rmsnorm, silu


@dataclasses.dataclass(frozen=True)
class MambaDims:
    d_model: int
    d_inner: int
    head_dim: int
    n_heads: int
    n_groups: int
    d_state: int
    d_conv: int = 4

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    @property
    def proj_dim(self) -> int:
        # [z (d_inner), x (d_inner), B (G*N), C (G*N), dt (H)]
        return 2 * self.d_inner + 2 * self.n_groups * self.d_state + self.n_heads


def mamba_dims(cfg) -> MambaDims:
    d_inner = cfg.ssm_expand * cfg.d_model
    head_dim = cfg.ssm_head_dim
    return MambaDims(cfg.d_model, d_inner, head_dim, d_inner // head_dim,
                     cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv)


def mamba_templates(dims: MambaDims):
    return {
        "in_proj": PT((dims.d_model, dims.proj_dim), "scaled",
                      ("embed", "dinner")),
        "conv_w": PT((dims.d_conv, dims.conv_dim), "scaled", (None, "dinner")),
        "conv_b": PT((dims.conv_dim,), "zeros", ("dinner",)),
        "a_log": PT((dims.n_heads,), "ssm_a", (None,), dtype=jnp.float32),
        "dt_bias": PT((dims.n_heads,), "ssm_dt", (None,), dtype=jnp.float32),
        "d_skip": PT((dims.n_heads,), "ones", (None,), dtype=jnp.float32),
        "norm_w": PT((dims.d_inner,), "zeros", ("dinner",)),
        "out_proj": PT((dims.d_inner, dims.d_model), "scaled",
                       ("dinner", "embed")),
    }


def _split_proj(zxbcdt, dims: MambaDims):
    di, gn, h = dims.d_inner, dims.n_groups * dims.d_state, dims.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn:]
    assert dt.shape[-1] == h
    return z, xbc, dt


def _causal_conv(xbc, w, b, *, conv_state=None):
    """Depthwise causal conv along time.  xbc: (B, S, C); w: (K, C).
    If conv_state (B, K-1, C) given, prepend it (decode/chunked prefill);
    returns (out, new_conv_state)."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    out = out + b
    new_state = xp[:, -(k - 1):, :] if k > 1 else pad[:, :0]
    return silu(out), new_state


def mamba_forward(p, x, dims: MambaDims, *, ssm_state=None, conv_state=None,
                  return_state=False, norm_eps=1e-6):
    """Full-sequence forward.  x: (B, S, d_model)."""
    b, s, _ = x.shape
    zxbcdt = jnp.einsum("bsd,dp->bsp", x, p["in_proj"])
    z, xbc, dt = _split_proj(zxbcdt, dims)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                 conv_state=conv_state)
    xi = xbc[..., :dims.d_inner]
    bmat = xbc[..., dims.d_inner:dims.d_inner + dims.n_groups * dims.d_state]
    cmat = xbc[..., dims.d_inner + dims.n_groups * dims.d_state:]
    xh = xi.reshape(b, s, dims.n_heads, dims.head_dim)
    bm = bmat.reshape(b, s, dims.n_groups, dims.d_state)
    cm = cmat.reshape(b, s, dims.n_groups, dims.d_state)
    dt_act = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    y, h_final = ops.ssd_scan(xh, dt_act, p["a_log"], bm, cm,
                              d_skip=p["d_skip"], h0=ssm_state)
    y = y.reshape(b, s, dims.d_inner)
    y = rmsnorm(p["norm_w"], y * silu(z), norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    if return_state:
        return out, (new_conv, h_final)
    return out


def mamba_decode(p, x, conv_state, ssm_state, dims: MambaDims,
                 norm_eps=1e-6):
    """One-token step.  x: (B, 1, d_model); conv_state: (B, K-1, conv_dim);
    ssm_state: (B, H, P, N).  Returns (out, conv_state, ssm_state)."""
    b = x.shape[0]
    zxbcdt = jnp.einsum("bsd,dp->bsp", x, p["in_proj"])
    z, xbc, dt = _split_proj(zxbcdt, dims)
    # conv: shift state, apply taps
    xp = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", xp, p["conv_w"]) + p["conv_b"]
    conv_out = silu(conv_out)[:, None, :]
    new_conv = xp[:, 1:, :]
    xi = conv_out[..., :dims.d_inner]
    bmat = conv_out[..., dims.d_inner:dims.d_inner + dims.n_groups * dims.d_state]
    cmat = conv_out[..., dims.d_inner + dims.n_groups * dims.d_state:]
    xh = xi.reshape(b, dims.n_heads, dims.head_dim)
    bm = bmat.reshape(b, dims.n_groups, dims.d_state)
    cm = cmat.reshape(b, dims.n_groups, dims.d_state)
    dt_act = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    y, ssm_state = ops.ssd_step(ssm_state, xh, dt_act, p["a_log"], bm, cm,
                                d_skip=p["d_skip"])
    y = y.reshape(b, 1, dims.d_inner)
    y = rmsnorm(p["norm_w"], y * silu(z), norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, new_conv, ssm_state
