"""Slot-addressable recurrent-state helpers (continuous-batching serving).

The serving engine's continuous scheduler needs three per-slot cache
operations from every family (see ``repro.serving.engine``):

  cache_expand(sub, batch)       batch-1 prefill cache -> empty B-slot pool
  cache_slot_write(cache, sub, i) write a batch-1 prefill cache into slot i
  cache_slot_reset(cache, i)      zero slot i's state on free/preempt

For the transformer families these live in ``transformer.py`` (the KV
strips share one batch axis).  The scan/recurrent families (ssm, hybrid,
encdec) carry heterogeneous state trees whose *batch axis differs per
leaf* — xlstm's mLSTM states are ``(n_groups, m_per, B, ...)`` (batch at
axis 2) while its sLSTM states are ``(n_groups, B, ...)`` (axis 1); the
hybrid/encdec leaves all put batch at axis 1.  This module builds the
three hooks generically from a ``{leaf name: batch axis}`` map, which is
the whole per-slot layout contract: as long as each leaf's slot slice is
independent of every other slot's slice (true for recurrent state by
construction — there is no cross-sequence mixing), admitting, evicting
and resetting one request touches exactly one index of each leaf.

This is the serving analog of per-lane vector state slicing (Ara,
arXiv:1906.00478) and of AraXL's partition-into-addressable-slices
scaling argument (arXiv:2501.10301): a monolithic batch-wide state forces
lock-step scheduling; slicing it per slot lets the scheduler admit,
finish and preempt one request at a time.

``pos`` is special-cased everywhere: the batch-1 prefill returns it as a
scalar, the slot pool carries it as a ``(B,)`` vector (one position per
slot), and reset parks it at 0.

All three returned hooks take only traced/jittable arguments except
``cache_expand``'s ``batch`` (a static Python int — the engine jits it
with ``static_argnums=(1,)``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _take_row(x, axis: int):
    """Drop the (size-1) batch axis of a batch-1 prefill leaf."""
    return jax.lax.index_in_dim(x, 0, axis, keepdims=False)


def make_slot_hooks(batch_axes: dict[str, int]):
    """Build (cache_expand, cache_slot_write, cache_slot_reset) for a flat
    cache dict whose leaf ``name`` carries its batch dimension at
    ``batch_axes[name]``.  ``pos`` must not appear in the map — it is
    handled as the per-slot position vector."""
    assert "pos" not in batch_axes, "pos is implicit (per-slot vector)"

    def cache_expand(sub, batch: int):
        """Grow a batch-1 prefill cache into an empty ``batch``-slot pool:
        every state leaf zeroed with the batch axis widened to ``batch``,
        positions a (B,) zero vector.  Slots are filled one at a time by
        ``cache_slot_write`` on admission."""
        out = {}
        for name, ax in batch_axes.items():
            x = sub[name]
            shape = x.shape[:ax] + (batch,) + x.shape[ax + 1:]
            out[name] = jnp.zeros(shape, x.dtype)
        out["pos"] = jnp.zeros((batch,), jnp.int32)
        return out

    def cache_slot_write(cache, sub, slot):
        """Write a batch-1 prefill cache into slot ``slot`` of the pool
        (prefill-on-admit).  ``slot`` may be traced — one compile serves
        every slot.  Every leaf of the slot is fully overwritten, so no
        state from a previous occupant can leak into the new request."""
        out = {}
        for name, ax in batch_axes.items():
            out[name] = jax.lax.dynamic_update_index_in_dim(
                cache[name], _take_row(sub[name], ax), slot, ax)
        out["pos"] = jax.lax.dynamic_update_index_in_dim(
            cache["pos"],
            jnp.reshape(jnp.asarray(sub["pos"], jnp.int32), ()), slot, 0)
        return out

    def cache_slot_reset(cache, slot):
        """Zero slot ``slot``'s state and position (slot freed or its
        request preempted).  Admission already rewrites the whole slot, so
        this is a hygiene invariant, not a correctness requirement — but
        it makes no-leak *testable* (a freed slot's recurrent state is
        provably gone, asserted in tests) and keeps idle-slot decode math
        running on zeros instead of a dead request's state."""
        out = {}
        for name, ax in batch_axes.items():
            x = cache[name]
            row = jnp.zeros(x.shape[:ax] + x.shape[ax + 1:], x.dtype)
            out[name] = jax.lax.dynamic_update_index_in_dim(x, row, slot, ax)
        out["pos"] = jax.lax.dynamic_update_index_in_dim(
            cache["pos"], jnp.int32(0), slot, 0)
        return out

    return cache_expand, cache_slot_write, cache_slot_reset
