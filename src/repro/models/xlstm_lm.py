"""xLSTM language model: mLSTM blocks with an sLSTM block every
``slstm_every``-th layer (grouped scan: (k-1) mLSTM + 1 sLSTM per group).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.act_sharding import constrain
from .layers import (PT, embed_lookup, embed_templates, rmsnorm,
                     softmax_xent_chunked, stack_layers)
from .slot_state import make_slot_hooks
from .xlstm import (mlstm_block, mlstm_block_decode, mlstm_block_templates,
                    mlstm_block_with_state, slstm_block, slstm_block_decode,
                    slstm_block_templates, slstm_init_state)


def _groups(cfg):
    k = cfg.slstm_every
    assert cfg.n_layers % k == 0, "xlstm layer count must be a multiple of " \
                                  "slstm_every"
    return cfg.n_layers // k, k - 1  # (n_groups, mlstm per group)


def xlstm_templates(cfg):
    n_groups, m_per = _groups(cfg)
    return {
        "embed": embed_templates(cfg.padded_vocab, cfg.d_model),
        "mlstm": stack_layers(
            lambda: stack_layers(
                lambda: mlstm_block_templates(cfg.d_model, cfg.n_heads),
                m_per), n_groups),
        "slstm": stack_layers(
            lambda: slstm_block_templates(cfg.d_model, cfg.n_heads), n_groups),
        "final_norm": PT((cfg.d_model,), "zeros", ("embed",)),
        "lm_head": PT((cfg.d_model, cfg.padded_vocab), "scaled",
                      ("embed", "vocab")),
    }


def xlstm_backbone(params, x, cfg, *, remat=True):
    n_groups, m_per = _groups(cfg)

    def m_layer(lp, c):
        return mlstm_block(lp, c, cfg.n_heads, norm_eps=cfg.norm_eps)

    def s_layer(lp, c):
        return slstm_block(lp, c, cfg.n_heads, norm_eps=cfg.norm_eps)

    if remat:
        m_layer = jax.checkpoint(m_layer)
        s_layer = jax.checkpoint(s_layer)

    def group_body(carry, inp):
        mparams, sparams = inp

        def inner(c, lp):
            return constrain(m_layer(lp, c), "hidden"), None

        carry, _ = jax.lax.scan(inner, carry, mparams)
        carry = s_layer(sparams, carry)
        return constrain(carry, "hidden"), None

    x, _ = jax.lax.scan(group_body, x, (params["mlstm"], params["slstm"]))
    return x


def xlstm_loss(params, batch, cfg, *, remat=True, xent_chunk=512):
    x = embed_lookup(params["embed"], batch["tokens"])
    x = xlstm_backbone(params, x, cfg, remat=remat)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    loss, acc = softmax_xent_chunked(
        x, params["lm_head"], batch["labels"], chunk=xent_chunk,
        label_mask=batch.get("label_mask"),
        valid_vocab=cfg.vocab_size)
    return loss, {"loss": loss, "accuracy": acc}


# ---------------------------------------------------------------------------
# Serving.
#
# Decode state is O(1)/token and fully recurrent: per sequence it is a
# fixed-size tree of conv tails and (C, n, m) cell states.  Serving keeps
# it in a (…, B, …) per-slot layout — the mLSTM leaves are stacked
# (n_groups, m_per, B, …) by the grouped scan, the sLSTM leaves
# (n_groups, B, …) — so one slot's state is one index of each leaf and the
# continuous-batching slot hooks below admit/evict/reset one request at a
# time (see ``repro.models.slot_state``).
# ---------------------------------------------------------------------------

# batch axis of every cache leaf (the serving slot axis); ``pos`` is the
# implicit per-slot position vector
XLSTM_STATE_AXES = {
    "m_conv": 2, "m_c": 2, "m_n": 2, "m_m": 2,
    "s_conv": 1, "s_c": 1, "s_n": 1, "s_h": 1, "s_m": 1,
}

xlstm_cache_expand, xlstm_cache_slot_write, xlstm_cache_slot_reset = \
    make_slot_hooks(XLSTM_STATE_AXES)


def xlstm_cache_shapes(cfg, batch_size: int, cache_len: int,
                       dtype=jnp.bfloat16):
    del cache_len  # state size is context-independent (that's the point)
    n_groups, m_per = _groups(cfg)
    d = cfg.d_model
    di = 2 * d
    dh_m = di // cfg.n_heads
    dh_s = d // cfg.n_heads
    f32 = jnp.float32
    b = batch_size
    return {
        "m_conv": jax.ShapeDtypeStruct((n_groups, m_per, b, 3, di), dtype),
        "m_c": jax.ShapeDtypeStruct((n_groups, m_per, b, cfg.n_heads, dh_m,
                                     dh_m), f32),
        "m_n": jax.ShapeDtypeStruct((n_groups, m_per, b, cfg.n_heads, dh_m),
                                    f32),
        "m_m": jax.ShapeDtypeStruct((n_groups, m_per, b, cfg.n_heads), f32),
        "s_conv": jax.ShapeDtypeStruct((n_groups, b, 3, d), dtype),
        "s_c": jax.ShapeDtypeStruct((n_groups, b, cfg.n_heads, dh_s), f32),
        "s_n": jax.ShapeDtypeStruct((n_groups, b, cfg.n_heads, dh_s), f32),
        "s_h": jax.ShapeDtypeStruct((n_groups, b, cfg.n_heads, dh_s), f32),
        "s_m": jax.ShapeDtypeStruct((n_groups, b, cfg.n_heads, dh_s), f32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def xlstm_prefill(params, batch, cfg, *, cache_len=None):
    del cache_len
    x = embed_lookup(params["embed"], batch["tokens"])
    b, s, d = x.shape
    n_groups, m_per = _groups(cfg)
    di = 2 * d

    def group_body(carry, inp):
        mparams, sparams = inp

        def inner(c, lp):
            conv0 = jnp.zeros((b, 3, di), x.dtype)
            out, (conv, mstate) = mlstm_block_with_state(
                lp, c, cfg.n_heads, conv0, None, norm_eps=cfg.norm_eps)
            return out, (conv, *mstate)

        carry, mstates = jax.lax.scan(inner, carry, mparams)
        carry, (s_conv, s_state) = slstm_block(
            sparams, carry, cfg.n_heads, conv_state=None, state=None,
            norm_eps=cfg.norm_eps, return_state=True)
        return carry, (mstates, s_conv, s_state)

    x, (mstates, s_convs, s_states) = jax.lax.scan(
        group_body, x, (params["mlstm"], params["slstm"]))
    m_conv, m_c, m_n, m_m = mstates
    s_c, s_n, s_h, s_m = s_states
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    logits = logits[:, :cfg.vocab_size]
    cache = {"m_conv": m_conv, "m_c": m_c, "m_n": m_n, "m_m": m_m,
             "s_conv": s_convs, "s_c": s_c, "s_n": s_n, "s_h": s_h,
             "s_m": s_m, "pos": jnp.int32(s)}
    return logits, cache


def xlstm_decode_step(params, cache, tokens, cfg):
    x = embed_lookup(params["embed"], tokens)

    def group_body(carry, inp):
        mparams, sparams, mc, mcc, mn, mm, sc, scc, sn, sh, sm = inp

        def inner(c, lp_state):
            lp, conv, cc, nn, m_ = lp_state
            out, conv, (cc, nn, m_) = mlstm_block_decode(
                lp, c, cfg.n_heads, conv, (cc, nn, m_),
                norm_eps=cfg.norm_eps)
            return out, (conv, cc, nn, m_)

        carry, mstates = jax.lax.scan(inner, carry,
                                      (mparams, mc, mcc, mn, mm))
        carry, s_conv, s_state = slstm_block_decode(
            sparams, carry, cfg.n_heads, sc, (scc, sn, sh, sm),
            norm_eps=cfg.norm_eps)
        return carry, (mstates, s_conv, *s_state)

    x, outs = jax.lax.scan(
        group_body, x,
        (params["mlstm"], params["slstm"], cache["m_conv"], cache["m_c"],
         cache["m_n"], cache["m_m"], cache["s_conv"], cache["s_c"],
         cache["s_n"], cache["s_h"], cache["s_m"]))
    (m_conv, m_c, m_n, m_m), s_conv, s_c, s_n, s_h, s_m = outs
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    logits = logits[:, :cfg.vocab_size]
    cache = {"m_conv": m_conv, "m_c": m_c, "m_n": m_n, "m_m": m_m,
             "s_conv": s_conv, "s_c": s_c, "s_n": s_n, "s_h": s_h,
             "s_m": s_m, "pos": cache["pos"] + 1}
    return logits, cache
