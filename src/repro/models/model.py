"""Family dispatch: one uniform Model API over all 10 assigned archs.

  model.templates            - param template tree (shapes + logical axes)
  model.init(key)            - parameter pytree
  model.loss(params, batch)  - (loss, metrics); batch from input_specs
  model.prefill(params, batch, cache_len)   - (logits, cache)
  model.decode(params, cache, tokens)       - (logits, cache)
  model.cache_shapes(batch, cache_len)      - ShapeDtypeStructs for dry-run
  input_specs(cfg, shape)    - ShapeDtypeStruct batch for an assigned cell

Every family exposes the slot-pool serving hooks used by continuous
batching — the transformer families (dense/moe/vlm) for their KV strips
(``transformer.py``), the scan/recurrent families (ssm/hybrid/encdec) for
their per-slot recurrent state (built by ``repro.models.slot_state`` from
each family's ``{leaf: batch axis}`` map):

  model.cache_expand(sub, batch)        - batch-1 prefill cache -> empty
                                          B-slot pool with per-slot positions
  model.cache_slot_write(cache, sub, i) - write a batch-1 prefill cache into
                                          slot i (prefill-on-admit)
  model.cache_slot_reset(cache, i)      - zero slot i's state on free or
                                          preempt (scan families; None for
                                          the KV families, whose stale
                                          strips are masked by pos instead)

Two layout flags steer the engine's bookkeeping:

  model.bounded_cache       - True when ``cache_len`` bounds a request's
                              cache writes (KV strips: dense/moe/vlm,
                              encdec).  False for ssm (state is O(1) in
                              context) and hybrid (recurrent state plus a
                              ring-buffered sliding window that wraps) —
                              the engine skips the write-budget check.
  model.supports_prefill_len - True when prefill consumes
                              ``batch["prefill_len"]`` for right-padded
                              bucketed prompts (transformer families).
                              Scan-family prefills consume every token
                              position into recurrent state, so padding
                              would corrupt it; the engine rejects
                              ``bucket=`` for them.

The transformer families additionally expose the paged-KV hooks used by
the engine's ``kv_layout="paged"`` (block pool + per-slot block tables;
see ``repro.serving.kvcache``):

  model.paged_cache_init(batch=, n_blocks=, block_size=, max_blocks=,
                         dtype=)              - empty block-pool cache
  model.cache_dtype(params)                   - KV dtype a prefill would
                                                produce (pool allocation)
  model.prefill_paged(params, pc, batch, slot, chunk, prefill_len)
      - one block_size chunk of a prompt prefilled straight into pool
        blocks via slot ``slot``'s block table (chunked prefill: no dense
        batch-1 cache is materialized; the engine allocates each chunk's
        block just before the call)
  model.decode_paged(params, pc, tokens)      - decode via block tables

The paged hooks are None for the scan families (recurrent state has no
block-pool analog — it is O(1) per slot already); their continuous
batching runs on the dense slot layout.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import encdec, hybrid, transformer, xlstm_lm
from .layers import init_params, param_count, param_pspecs


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    templates: Any
    loss: Callable
    prefill: Callable
    decode: Callable
    cache_shapes: Callable
    # slot-pool serving hooks (every family; continuous batching)
    cache_expand: Callable | None = None
    cache_slot_write: Callable | None = None
    # per-slot state zeroing on free/preempt (scan families; None for KV
    # families, whose stale strips are masked by per-slot pos instead)
    cache_slot_reset: Callable | None = None
    # paged-KV serving hooks (None when the family has no paged layout)
    paged_cache_init: Callable | None = None
    cache_dtype: Callable | None = None
    prefill_paged: Callable | None = None
    decode_paged: Callable | None = None
    # True when cache_len bounds the request's cache writes (KV strips);
    # False for recurrent/ring state that never overflows (ssm, hybrid)
    bounded_cache: bool = True
    # True when prefill accepts batch["prefill_len"] (right-padded
    # bucketed prompts); scan-family prefills would absorb pads into state
    supports_prefill_len: bool = False

    def init(self, key):
        return init_params(self.templates, key)

    def pspecs(self, rules, mesh_shape=None):
        return param_pspecs(self.templates, rules, mesh_shape)

    @property
    def n_params(self) -> int:
        return param_count(self.templates)


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return Model(
            cfg, transformer.decoder_templates(cfg),
            functools.partial(transformer.decoder_loss, cfg=cfg),
            functools.partial(transformer.decoder_prefill, cfg=cfg),
            functools.partial(transformer.decoder_decode_step, cfg=cfg),
            functools.partial(transformer.make_decode_cache_specs, cfg),
            cache_expand=transformer.decoder_cache_expand,
            cache_slot_write=transformer.decoder_cache_slot_write,
            paged_cache_init=functools.partial(
                transformer.decoder_paged_cache_init, cfg),
            cache_dtype=transformer.decoder_cache_dtype,
            prefill_paged=functools.partial(
                transformer.decoder_prefill_paged, cfg=cfg),
            decode_paged=functools.partial(
                transformer.decoder_decode_step_paged, cfg=cfg),
            supports_prefill_len=True,
        )
    if fam == "hybrid":
        return Model(
            cfg, hybrid.hybrid_templates(cfg),
            functools.partial(hybrid.hybrid_loss, cfg=cfg),
            functools.partial(hybrid.hybrid_prefill, cfg=cfg),
            functools.partial(hybrid.hybrid_decode_step, cfg=cfg),
            functools.partial(hybrid.hybrid_cache_shapes, cfg),
            cache_expand=hybrid.hybrid_cache_expand,
            cache_slot_write=hybrid.hybrid_cache_slot_write,
            cache_slot_reset=hybrid.hybrid_cache_slot_reset,
            bounded_cache=False,   # O(1) state + wrapping attention ring
        )
    if fam == "ssm":
        return Model(
            cfg, xlstm_lm.xlstm_templates(cfg),
            functools.partial(xlstm_lm.xlstm_loss, cfg=cfg),
            functools.partial(xlstm_lm.xlstm_prefill, cfg=cfg),
            functools.partial(xlstm_lm.xlstm_decode_step, cfg=cfg),
            functools.partial(xlstm_lm.xlstm_cache_shapes, cfg),
            cache_expand=xlstm_lm.xlstm_cache_expand,
            cache_slot_write=xlstm_lm.xlstm_cache_slot_write,
            cache_slot_reset=xlstm_lm.xlstm_cache_slot_reset,
            bounded_cache=False,   # state size is context-independent
        )
    if fam == "encdec":
        return Model(
            cfg, encdec.encdec_templates(cfg),
            functools.partial(encdec.encdec_loss, cfg=cfg),
            functools.partial(encdec.encdec_prefill, cfg=cfg),
            functools.partial(encdec.encdec_decode_step, cfg=cfg),
            functools.partial(encdec.encdec_cache_shapes, cfg),
            cache_expand=encdec.encdec_cache_expand,
            cache_slot_write=encdec.encdec_cache_slot_write,
            cache_slot_reset=encdec.encdec_cache_slot_reset,
            # decoder self-KV strips are cache_len wide: budget enforced
        )
    raise ValueError(f"unknown family {fam}")


# ---------------------------------------------------------------------------
# Input specs for the assigned (arch x shape) cells: ShapeDtypeStruct
# stand-ins, weak-type-correct, no allocation.
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                dtype=jnp.bfloat16) -> dict:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(bb, ss):
        return jax.ShapeDtypeStruct((bb, ss), i32)

    if shape.kind == "train":
        if cfg.family == "vlm":
            s_text = s - cfg.n_patches
            return {"tokens": tok(b, s_text), "labels": tok(b, s_text),
                    "patches": jax.ShapeDtypeStruct(
                        (b, cfg.n_patches, cfg.patch_embed_dim), dtype)}
        if cfg.family == "encdec":
            return {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype),
                    "tokens": tok(b, s), "labels": tok(b, s)}
        return {"tokens": tok(b, s), "labels": tok(b, s)}

    if shape.kind == "prefill":
        if cfg.family == "vlm":
            s_text = s - cfg.n_patches
            return {"tokens": tok(b, s_text),
                    "patches": jax.ShapeDtypeStruct(
                        (b, cfg.n_patches, cfg.patch_embed_dim), dtype)}
        if cfg.family == "encdec":
            return {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype),
                    "tokens": tok(b, s)}
        return {"tokens": tok(b, s)}

    # decode: one new token against a seq_len cache
    return {"tokens": tok(b, 1)}


def decode_cache_specs(cfg: ModelConfig, shape: ShapeConfig,
                       dtype=jnp.bfloat16):
    model = build_model(cfg)
    return model.cache_shapes(shape.global_batch, shape.seq_len, dtype)
