"""3-step hierarchical reductions (paper contribution C3, §3 "Reductions").

Ara2 reduces a vector in three phases:
  1. intra-lane  - each lane reduces its resident elements at full FPU
     utilization, using the FPU pipeline registers as accumulators;
  2. inter-lane  - a log2(L)+1-step tree over the slide interconnect;
  3. SIMD        - a log-tree within the final 64-bit word.

TPU transplant: intra-shard ``jnp`` reduce (VPU/MXU-local), then an
inter-shard tree built from log2(L) XOR-partner ``ppermute`` steps
(halving/doubling), then the in-register tree inside the Pallas dot-product
kernel.  ``allreduce_*`` are drop-in gradient-sync schedules compared against
native ``psum`` in the dry-run (§Perf).

Latency model: ``reduction_drain_cycles`` implements the paper's closed-form
``R*(1+log2(ceil(R))) - (ceil(R)-R) - 1`` for the intra-lane pipeline drain.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from ..jax_compat import axis_size

from .vector_engine import log2i


# ---------------------------------------------------------------------------
# Single-array 3-step reduction (structural mirror of the hardware).
# ---------------------------------------------------------------------------

def simd_tree_reduce(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Explicit log-step halving tree (phase 3).  Pads with zeros."""
    n = x.shape[axis]
    x = jnp.moveaxis(x, axis, -1)
    p = 1 << (n - 1).bit_length() if n > 1 else 1
    if p != n:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, p - n)]
        x = jnp.pad(x, pad)
    while x.shape[-1] > 1:
        h = x.shape[-1] // 2
        x = x[..., :h] + x[..., h:]
    return x[..., 0]


def hierarchical_reduce(x: jnp.ndarray, n_lanes: int) -> jnp.ndarray:
    """Full 3-step sum of a 1-D vector: stripe across lanes, intra-lane
    accumulate, inter-lane tree.  Equals ``jnp.sum`` (property-tested)."""
    from .lanes import stripe
    lanes = stripe(x, n_lanes)           # (L, elems/lane)
    acc = jnp.sum(lanes, axis=1)         # phase 1: intra-lane
    return simd_tree_reduce(acc, axis=0)  # phases 2+3: log tree


# ---------------------------------------------------------------------------
# Mesh-level trees (inside shard_map).
# ---------------------------------------------------------------------------

def _xor_perm(size: int, d: int):
    return [(i, i ^ d) for i in range(size)]


def allreduce_hd(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Halving-doubling (latency-optimal) all-reduce: log2(L) full-size
    XOR-partner exchanges - the paper's inter-lane tree verbatim."""
    size = axis_size(axis_name)
    d = 1
    while d < size:
        x = x + jax.lax.ppermute(x, axis_name, _xor_perm(size, d))
        d <<= 1
    return x


def reduce_scatter_hd(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Recursive-halving reduce-scatter along leading dim (bandwidth-optimal:
    (L-1)/L of |x| per link).  Shard i of the result is chunk i."""
    size = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    assert x.shape[0] % size == 0, f"leading dim {x.shape[0]} % {size} != 0"
    d = size >> 1
    while d >= 1:
        half = x.shape[0] // 2
        bit = (idx & d) > 0
        keep_start = jnp.where(bit, half, 0)
        send_start = jnp.where(bit, 0, half)
        keep = jax.lax.dynamic_slice_in_dim(x, keep_start, half)
        send = jax.lax.dynamic_slice_in_dim(x, send_start, half)
        x = keep + jax.lax.ppermute(send, axis_name, _xor_perm(size, d))
        d >>= 1
    return x


def allgather_hd(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Recursive-doubling all-gather along leading dim (inverse of
    :func:`reduce_scatter_hd`'s placement)."""
    size = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    d = 1
    while d < size:
        other = jax.lax.ppermute(x, axis_name, _xor_perm(size, d))
        bit = (idx & d) > 0
        lower = jnp.where(bit, other, x)
        upper = jnp.where(bit, x, other)
        x = jnp.concatenate([lower, upper], axis=0)
        d <<= 1
    return x


def allreduce_rs_ag(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Bandwidth-optimal all-reduce = recursive-halving reduce-scatter +
    recursive-doubling all-gather (2*(L-1)/L of |x| per link)."""
    shape = x.shape
    size = axis_size(axis_name)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    out = allgather_hd(reduce_scatter_hd(flat, axis_name), axis_name)
    if pad:
        out = out[:-pad]
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# Latency model (paper §3).
# ---------------------------------------------------------------------------

def reduction_drain_cycles(r: float) -> float:
    """Cycles to drain R pipeline-register partial sums into one:
    ``R*(1+log2(ceil(R))) - (ceil(R)-R) - 1``; for power-of-two R this is
    ``R*(1+log2(R)) - 1`` (paper §3)."""
    rc = math.ceil(r)
    if rc <= 1:
        return 0.0
    return r * (1 + math.log2(rc)) - (rc - r) - 1


def interlane_reduction_cycles(n_lanes: int, fpu_latency: int, slide_latency: int = 2) -> float:
    """(log2(L)+1) tree steps; the slide<->FPU dependency feedback pays both
    latencies at every step (paper §3)."""
    if n_lanes == 1:
        return 0.0
    return (log2i(n_lanes) + 1) * (fpu_latency + slide_latency)


def simd_reduction_cycles(ew_bits: int, fpu_latency: int) -> float:
    """Final intra-word tree: log2(64/EW) steps, each paying FPU latency."""
    steps = max(0, log2i(64 // ew_bits)) if ew_bits < 64 else 0
    return steps * fpu_latency


def vector_reduction_cycles(n_elems: int, n_lanes: int, ew_bits: int,
                            fpu_pipe: int) -> float:
    """End-to-end reduction latency: N/L streaming + intra-lane drain +
    inter-lane tree + SIMD tree."""
    n64 = n_elems * ew_bits // 64  # 64-bit packets (paper's N)
    stream = max(n64 / n_lanes, 1.0)
    return (stream
            + reduction_drain_cycles(fpu_pipe)
            + interlane_reduction_cycles(n_lanes, fpu_pipe)
            + simd_reduction_cycles(ew_bits, fpu_pipe))
