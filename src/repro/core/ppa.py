"""PPA and energy model (paper contribution C6, §6-§7, Tables 3-5).

Stores the paper's 22nm FD-SOI implementation tables verbatim and composes
them into the multi-core energy-efficiency model behind Figs 14/15/17/18.
Energy on TPU cannot be measured in this container; everything here is the
*paper's* silicon model, used (a) to reproduce the paper's efficiency
results and (b) to rank mesh-policy choices the same way §7 ranks multi-core
configurations.
"""
from __future__ import annotations

import dataclasses

from .vector_engine import ClusterConfig, VectorEngineConfig
from .perf_model import WhatIf, matmul_opc

# ---------------------------------------------------------------------------
# Table 3: physical implementation metrics (22nm FD-SOI).
# '16*' = 16 lanes without fixed-point support + minimal mask unit.
# ---------------------------------------------------------------------------
TT_FREQ_GHZ = {2: 1.35, 4: 1.35, 8: 1.35, 16: 1.08, "16*": 1.26}
SS_FREQ_GHZ = {2: 0.95, 4: 0.96, 8: 0.94, 16: 0.75, "16*": 0.86}
DIE_AREA_MM2 = {2: 0.59, 4: 0.95, 8: 1.88, 16: 4.47, "16*": 4.47}
CELL_MACRO_AREA_KGE = {2: 2291, 4: 3688, 8: 6768, 16: 14773, "16*": 12864}
ENERGY_EFF_TABLE3 = {2: 34.1, 4: 37.8, 8: 35.7, "16*": 30.3}  # DP-GFLOPS/W

# ---------------------------------------------------------------------------
# Table 4: 4-lane design, 1.35 GHz, typical corner, 2 KiB vectors.
# name -> (elements, power mW, GOPS, GOPS/W)
# ---------------------------------------------------------------------------
TABLE4 = {
    "fmatmul64": (256, 283, 10.7, 37.8),
    "fmatmul32": (512, 238, 21.4, 90.0),
    "fmatmul16": (1024, 218, 42.8, 195.9),
    "imatmul64": (256, 272, 10.4, 38.3),
    "imatmul32": (512, 245, 20.9, 85.2),
    "imatmul16": (1024, 231, 41.8, 181.0),
    "imatmul8": (2048, 222, 83.5, 376.0),
}

# ---------------------------------------------------------------------------
# Table 5: area breakdown [kGE] per unit vs lanes ('Lane' is per-lane).
# ---------------------------------------------------------------------------
AREA_KGE = {
    "cva6":      {2: 894, 4: 896, 8: 906, 16: 904, "16*": 904},
    "lane":      {2: 612, 4: 617, 8: 626, 16: 628, "16*": 573},
    "dispatcher": {2: 16, 4: 17, 8: 19, 16: 23, "16*": 20},
    "sequencer": {2: 14, 4: 15, 8: 17, 16: 29, "16*": 29},
    "masku":     {2: 38, 4: 97, 8: 300, 16: 1105, "16*": 442},
    "addrgen":   {2: 35, 4: 36, 8: 44, 16: 59, "16*": 60},
    "vldu":      {2: 15, 4: 45, 8: 212, 16: 1286, "16*": 1135},
    "vstu":      {2: 8, 4: 21, 8: 64, 16: 332, "16*": 342},
    "new_sldu":  {2: 24, 4: 48, 8: 94, 16: 196, "16*": 190},
    "old_sldu":  {2: 39, 4: 131, 8: 577, 16: 2900, "16*": 2860},
}


def system_area_kge(n_lanes: int, sldu: str = "new_sldu") -> float:
    """Cell area of CVA6 + Ara2 from the Table 5 breakdown."""
    a = 0.0
    for unit, per_l in AREA_KGE.items():
        if unit in ("new_sldu", "old_sldu") and unit != sldu:
            continue
        v = per_l[n_lanes]
        a += v * n_lanes if unit == "lane" else v
    return a


def sldu_area_saving(n_lanes: int) -> float:
    """Measured SLDU area saving, new vs old (>=83% at 8 lanes, §6)."""
    return 1.0 - AREA_KGE["new_sldu"][n_lanes] / AREA_KGE["old_sldu"][n_lanes]


# ---------------------------------------------------------------------------
# Power / energy-efficiency model.
# ---------------------------------------------------------------------------
# Per-cluster (CVA6 + caches + Ara2) power at TT frequency on fmatmul,
# uniform-[0,1) inputs.  Derived from the paper's own tables: the 4-lane point
# is the Table 4 measurement (283 mW, adjusted -7% for the multi-core runs'
# cold caches, §4); 2/8-lane points follow from Table 3's efficiencies and the
# model's throughput at 2 KiB vectors; the 16-lane point from the 16* row
# rescaled to the full-MASKU area and 1.08 GHz.  Known modeling deviation
# (recorded in EXPERIMENTS.md): the paper's Fig 15 shows 1x16L overtaking
# 8x2L at 256^3, which these anchors do not reproduce.
CLUSTER_POWER_W = {2: 0.150, 4: 0.262, 8: 0.535, 16: 1.10, "16*": 1.00}
_UNCORE_W_PER_CORE = 0.005   # multi-bank SRAM + interconnect share (§4)


def cluster_power_w(n_lanes: int, activity: float = 1.0) -> float:
    """One CVA6+Ara2 cluster's power at its TT frequency, uniform-[0,1) data.
    ``activity`` rescales for input-data distribution (§8.2: same kernel
    spans 38.8-65 GFLOPS/W depending on distribution)."""
    return CLUSTER_POWER_W[n_lanes] * activity


def system_power_w(cluster: ClusterConfig, activity: float = 1.0) -> float:
    c = cluster.n_cores
    return c * cluster_power_w(cluster.engine.n_lanes, activity) \
        + c * _UNCORE_W_PER_CORE


def real_throughput_gflops(n: int, cluster: ClusterConfig,
                           whatif: WhatIf = WhatIf()) -> float:
    """Fig 14: raw throughput * TT frequency of the implementation."""
    return matmul_opc(n, cluster, whatif) * TT_FREQ_GHZ[cluster.engine.n_lanes]


def energy_efficiency_gflops_w(n: int, cluster: ClusterConfig,
                               whatif: WhatIf = WhatIf(),
                               activity: float = 1.0) -> float:
    """Fig 15/17/18: DP-GFLOPS/W on an n^3 fmatmul."""
    return real_throughput_gflops(n, cluster, whatif) \
        / system_power_w(cluster, activity)


# ---------------------------------------------------------------------------
# TPU v5e silicon constants (the adaptation target; used by roofline/).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TpuSpec:
    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12     # per chip
    hbm_bw: float = 819e9               # B/s per chip
    ici_link_bw: float = 50e9           # B/s per link (per direction)
    hbm_bytes: int = 16 * 2 ** 30       # 16 GiB
    vmem_bytes: int = 128 * 2 ** 20     # ~128 MiB VMEM
    # model-derived energy (for paper-style efficiency ranking only):
    chip_power_w: float = 200.0


TPU_V5E = TpuSpec()
