"""Ara2's transplanted contributions (see DESIGN.md §2):

C1 lanes / bytes-per-lane  -> vector_engine, lanes
C2 pow2 slide decomposition -> slide
C3 3-step hierarchical reduction -> reduction
C5 ideality perf model      -> perf_model
C6 PPA / energy model       -> ppa
(C4, the multi-core mesh trade-off, lives in distributed.mesh_policy.)
"""
from .vector_engine import (VectorEngineConfig, ClusterConfig, fixed_fpu_sweep,
                            log2i, ceil_div, round_up)
from .perf_model import (KERNELS, KernelSpec, WhatIf, ideality, kernel_opc,
                         matmul_opc, matmul_cycles, util_curve,
                         issue_rate_limit_opc, pool_average_ideality,
                         dotproduct_speedup_vs_scalar)
from .slide import (decompose_pow2, slide, rotate, mesh_slide,
                    mesh_halo_exchange, mux_count, sldu_saving)
from .reduction import (hierarchical_reduce, simd_tree_reduce, allreduce_hd,
                        allreduce_rs_ag, reduce_scatter_hd, allgather_hd,
                        reduction_drain_cycles, vector_reduction_cycles)
from .ppa import (TPU_V5E, TpuSpec, TT_FREQ_GHZ, AREA_KGE, TABLE4,
                  ENERGY_EFF_TABLE3, system_area_kge, sldu_area_saving,
                  system_power_w, real_throughput_gflops,
                  energy_efficiency_gflops_w)
