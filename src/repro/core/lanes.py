"""Lane striping layout transforms (paper contribution C1, §2).

Ara2 assigns consecutive vector elements to consecutive lanes ("to ease
mixed-width operations").  These helpers realize that byte layout as array
transforms; they are used by the Pallas kernels' index maps, by the byte-level
reshuffle emulation (the SLDU's second job), and by tests that check the
layout round-trips.

Logical element ``i`` of a vector lives at ``lanes[i % L, i // L]``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .vector_engine import ceil_div


def stripe(x: jnp.ndarray, n_lanes: int, fill=0):
    """Logical 1-D vector -> (n_lanes, elems_per_lane), Ara2 byte layout."""
    (n,) = x.shape
    epl = ceil_div(n, n_lanes)
    pad = epl * n_lanes - n
    if pad:
        x = jnp.concatenate([x, jnp.full((pad,), fill, dtype=x.dtype)])
    # element i -> [i % L, i // L]
    return x.reshape(epl, n_lanes).T


def unstripe(lanes: jnp.ndarray, n: int | None = None):
    """Inverse of :func:`stripe`."""
    n_lanes, epl = lanes.shape
    x = lanes.T.reshape(n_lanes * epl)
    return x if n is None else x[:n]


def lane_of(i, n_lanes: int):
    return i % n_lanes


def slot_of(i, n_lanes: int):
    return i // n_lanes


def stripe_bytes(x: np.ndarray, n_lanes: int) -> np.ndarray:
    """Byte-accurate VRF image of a vector register group: element i's bytes go
    to lane ``i % L`` at byte offset ``(i // L) * ew``.  Returns
    ``(n_lanes, bytes_per_lane)`` uint8."""
    raw = np.ascontiguousarray(x).view(np.uint8).reshape(x.size, x.itemsize)
    epl = ceil_div(x.size, n_lanes)
    img = np.zeros((n_lanes, epl * x.itemsize), dtype=np.uint8)
    for i in range(x.size):
        img[i % n_lanes, (i // n_lanes) * x.itemsize:(i // n_lanes + 1) * x.itemsize] = raw[i]
    return img


def unstripe_bytes(img: np.ndarray, dtype, n: int) -> np.ndarray:
    """Read ``n`` elements of ``dtype`` back out of a VRF byte image."""
    itemsize = np.dtype(dtype).itemsize
    n_lanes = img.shape[0]
    raw = np.zeros((n, itemsize), dtype=np.uint8)
    for i in range(n):
        raw[i] = img[i % n_lanes, (i // n_lanes) * itemsize:(i // n_lanes + 1) * itemsize]
    return raw.reshape(-1).view(dtype)[:n]


def reshuffle(img: np.ndarray, old_dtype, new_dtype, n_old: int) -> np.ndarray:
    """The Ara2 *reshuffle* micro-operation (§2 "Source Registers"): reinterpret
    a register group encoded with EW_old under EW_new.  The logical byte stream
    is preserved; only the lane/byte placement changes.  In hardware this is a
    whole-register SLDU pass; here it is the layout transform the SLDU
    implements, used as the oracle for the slide-unit tests."""
    n_lanes = img.shape[0]
    stream = unstripe_bytes(img, np.uint8, n_old * np.dtype(old_dtype).itemsize) \
        if np.dtype(old_dtype).itemsize == 1 else \
        np.ascontiguousarray(unstripe_bytes(img, old_dtype, n_old)).view(np.uint8)
    new_it = np.dtype(new_dtype).itemsize
    n_new = len(stream) // new_it
    return stripe_bytes(stream[: n_new * new_it].view(new_dtype), n_lanes)
