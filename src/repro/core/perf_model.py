"""Analytical Ara2 performance model (paper contribution C5, §5 + §7).

Reproduces the paper's performance characterization: *raw-throughput
ideality* = achieved / ideal ops-per-cycle, as a function of
(kernel, application vector length, lanes, cores), with the what-if toggles
of §5.3-5.4 (ideal dispatcher, ideal cache, streamlined vector unit).

Model structure (each term maps to a paper mechanism):
  * ``opc_max``       - Table 2 per-kernel peak (coef * SIMD * L OP/cycle);
  * utilization curve - vector-unit-only efficiency vs bytes-per-lane
    (digitized from Figs 4-6; the paper's central result is that this curve
    depends on bytes/lane, not on absolute vector length);
  * issue bound       - CVA6 dispatches one main-loop vector instruction per
    ``issue_cycles`` (4 with RVV 1.0): opc <= ops_per_vinsn / issue_cycles;
  * memory bound      - VLSU: 4*L B/cycle;
  * reduction tail    - §3 closed-form latency (dotproduct/softmax);
  * setup + sync      - fixed per-kernel-call overhead; sync grows with
    log2(cores) (§7 multi-core).

Calibration targets (asserted in tests/test_paper_claims.py):
  - 16-lane issue bound at VL=32 fp64: 16 DP-FLOP/cycle (§7.1);
  - matmul/conv2d ideality >=95% at 128 B/lane, >=75% at 64 B/lane (§5.2);
  - pool-average ideality >=50% from 128 B/lane (§5.2);
  - 8x2-lane beats 1x16-lane by >3x on 32x32x32 fmatmul, 8x2L ~ 23.6
    DP-FLOP/cycle (§7.1);
  - 2-lane dotproduct vs CVA6: ~1.4x (fp64), ~2.2x (int64) at 128 elems (§8.1);
  - Fig 4 diagonal property: ideality ~constant at fixed bytes/lane.
"""
from __future__ import annotations

import dataclasses
import math

from .vector_engine import (ClusterConfig, VectorEngineConfig, ceil_div,
                            log2i)
from .reduction import vector_reduction_cycles

# ---------------------------------------------------------------------------
# Benchmark pool (paper Table 2).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelSpec:
    name: str
    domain: str
    ew_bits: int
    simd: int              # SIMD packing factor (2 for 32-bit kernels)
    coef: float            # Table 2 "Max Perf" coefficient: opc_max = simd*coef*L
    compute_bound: bool
    uses_masks: bool = False
    uses_slides: bool = False
    strided_mem: bool = False
    indexed_mem: bool = False
    uses_reduction: bool = False
    # Main-loop shape for the issue-rate bound: useful ops per element per
    # main vector instruction, and scalar instructions per main-loop iteration.
    ops_per_elem: float = 2.0
    loop_insns: int = 3

    def opc_max(self, n_lanes: int) -> float:
        return self.simd * self.coef * n_lanes


KERNELS: dict[str, KernelSpec] = {k.name: k for k in [
    KernelSpec("matmul", "linalg/ml", 64, 1, 2.0, True),
    KernelSpec("conv2d", "dsp/ml", 64, 1, 2.0, True, uses_slides=True),
    KernelSpec("dotproduct", "linalg", 64, 1, 0.5, False, uses_reduction=True,
               ops_per_elem=2.0),
    KernelSpec("jacobi2d", "stencil", 64, 1, 1.0, True, uses_slides=True),
    KernelSpec("dropout", "ml", 32, 2, 0.25, False, uses_masks=True,
               ops_per_elem=1.0),
    KernelSpec("fft", "dsp", 32, 2, 5 / 4, True, uses_masks=True,
               uses_slides=True, indexed_mem=True),
    KernelSpec("dwt", "dsp", 32, 2, 0.5, False, strided_mem=True),
    KernelSpec("pathfinder", "routing", 32, 2, 1.0, True, uses_masks=True,
               ops_per_elem=1.0),
    KernelSpec("exp", "sci/ml", 64, 1, 30 / 23, True, uses_masks=True),
    KernelSpec("softmax", "ml", 32, 2, 34 / 27, True, uses_reduction=True),
    KernelSpec("roi_align", "ml", 32, 1, 9 / 5, False),
]}

# Vector-unit-only utilization vs bytes/lane, digitized from Figs 4-6 at
# B/lane in {8, 16, 32, 64, 128, 256, 512}; geometric interpolation between
# grid points, clamped at the ends.
_BPL_GRID = (8, 16, 32, 64, 128, 256, 512)
_UTIL_CURVES = {
    "high": (0.10, 0.22, 0.42, 0.78, 0.965, 0.975, 0.985),  # matmul, conv2d
    "med": (0.08, 0.18, 0.35, 0.60, 0.80, 0.88, 0.92),    # jacobi2d, exp, roi, dropout
    "low": (0.04, 0.10, 0.20, 0.38, 0.55, 0.68, 0.78),    # fft, dwt, pathfinder
}
# Reduction kernels (dotproduct, softmax) use the "med" streaming curve; their
# reduction cost is modeled analytically (§3 closed form) in kernel_opc, so
# baking it into the curve as well would double-count it.
_KERNEL_CURVE = {
    "matmul": "high", "conv2d": "high",
    "jacobi2d": "med", "exp": "med", "roi_align": "med", "dropout": "med",
    "dotproduct": "med", "softmax": "med",
    "fft": "low", "dwt": "low", "pathfinder": "low",
}

# Fixed overheads (cycles), calibrated to §7.1's 23.6 DP-FLOP/cycle point.
SETUP_CYCLES = 400.0            # kernel setup: vsetvl, address setup, warmup
SYNC_BASE_CYCLES = 100.0        # multi-core: CSR-based synchronization engine
SYNC_PER_STEP_CYCLES = 50.0     # per log2(cores) tree step
# Scalar-core (CVA6) comparison model (§8.1): cycles/element for a dotproduct
# (in-order single-issue: 2 loads + mac + loop overhead; fp FMA-chain latency
# partially hidden by 4-way accumulator unrolling, int mul is 2-3 cycles).
CVA6_DOT_CYCLES_PER_ELEM = {"fp": 3.8, "int": 5.5}
# L1 D-cache miss penalty model (§5.3 what-if): refill latency in cycles.
DCACHE_MISS_PENALTY = 20.0


def util_curve(kernel: str, bytes_per_lane: float) -> float:
    """Vector-unit-only efficiency at a given bytes/lane ratio."""
    ys = _UTIL_CURVES[_KERNEL_CURVE[kernel]]
    b = max(min(bytes_per_lane, _BPL_GRID[-1]), _BPL_GRID[0])
    lb = math.log2(b) - 3.0  # grid starts at 8 = 2^3
    i = min(int(lb), len(ys) - 2)
    f = lb - i
    return ys[i] ** (1 - f) * ys[i + 1] ** f


def issue_bound_opc(spec: KernelSpec, vl_elems: float,
                    issue_cycles: float) -> float:
    """Max ops/cycle the scalar core can sustain: one main vector instruction
    covering ``vl_elems`` elements every ``issue_cycles`` cycles (§7.1)."""
    return spec.ops_per_elem * spec.simd * vl_elems / issue_cycles


def memory_bound_opc(spec: KernelSpec, engine: VectorEngineConfig) -> float:
    """VLSU ceiling for memory-bound kernels (4*L B/cycle, Table 2 shapes)."""
    if spec.compute_bound:
        return float("inf")
    return spec.opc_max(engine.n_lanes)  # Table 2 already bakes in the VLSU cap


# ---------------------------------------------------------------------------
# Single-core kernel model.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WhatIf:
    """§5.3-5.4 what-if toggles."""
    ideal_dispatcher: bool = False   # CVA6 + scalar memory replaced by FIFO
    ideal_cache: bool = False        # L1D always hits
    streamlined: bool = False        # upsized queues / 16-deep insn window
    barber_pole: bool = False        # §5.4.1 VRF layout


def _barber_pole_delta(bytes_per_lane: float) -> float:
    """§5.4.1: small gain below 32 B/lane (more effective banks), loss from
    64 B/lane (perturbed access pattern)."""
    if bytes_per_lane <= 32:
        return 0.10 * (1.0 - bytes_per_lane / 64.0)
    return -0.06


def kernel_opc(kernel: str, vl_bytes: float, engine: VectorEngineConfig,
               whatif: WhatIf = WhatIf()) -> float:
    """Achieved ops/cycle for one kernel invocation on vectors of
    ``vl_bytes`` application vector length (steady-state, §5.2)."""
    spec = KERNELS[kernel]
    bpl = engine.bytes_per_lane(vl_bytes)
    vl_elems = vl_bytes / (spec.ew_bits // 8)

    util = util_curve(kernel, bpl)
    if whatif.streamlined:
        # §5.4.2: deeper buffers recover most sub-32-B/lane stalls.
        util = util + (1.0 - util) * 0.5 if bpl <= 32 else util
    if whatif.barber_pole:
        util = max(0.01, min(1.0, util + _barber_pole_delta(bpl)))

    opc = util * spec.opc_max(engine.n_lanes)
    opc = min(opc, memory_bound_opc(spec, engine))
    if not whatif.ideal_dispatcher:
        opc = min(opc, issue_bound_opc(spec, vl_elems, engine.issue_cycles))
        if not whatif.ideal_cache:
            # Scalar-memory non-ideality (§5.3): operand-forwarding kernels pay
            # D$ misses; folded in as a degradation that fades with B/lane.
            opc *= 1.0 - min(0.15, 0.15 * (16.0 / max(bpl, 16.0)) ** 1.5)

    if spec.uses_reduction:
        # Reduction tail (§3): latency paid once per vector after streaming -
        # pipeline drain + inter-lane tree + SIMD tree (stream time is already
        # in ``opc`` via the utilization curve).
        from .reduction import (interlane_reduction_cycles,
                                reduction_drain_cycles, simd_reduction_cycles)
        pipe = engine.fpu_pipe(min(spec.ew_bits, 64))
        tail = (reduction_drain_cycles(pipe)
                + interlane_reduction_cycles(engine.n_lanes, pipe)
                + simd_reduction_cycles(spec.ew_bits, pipe))
        work_ops = spec.ops_per_elem * spec.simd * vl_elems
        opc = work_ops / (work_ops / max(opc, 1e-9) + tail)
    return opc


def ideality(kernel: str, vl_bytes: float, engine: VectorEngineConfig,
             whatif: WhatIf = WhatIf()) -> float:
    """Raw-throughput ideality in [0, 1] (the Fig 4/5 quantity)."""
    spec = KERNELS[kernel]
    return min(1.0, kernel_opc(kernel, vl_bytes, engine, whatif)
               / spec.opc_max(engine.n_lanes))


def pool_average_ideality(vl_bytes_per_lane: float,
                          engine: VectorEngineConfig) -> float:
    vals = [ideality(k, vl_bytes_per_lane * engine.n_lanes, engine)
            for k in KERNELS]
    return sum(vals) / len(vals)


# ---------------------------------------------------------------------------
# fmatmul end-to-end model (Figs 8-9, 13-18).
# ---------------------------------------------------------------------------

def matmul_cycles(n: int, cluster: ClusterConfig,
                  whatif: WhatIf = WhatIf(), ew_bits: int = 64) -> float:
    """Total cycles for an n*n*n matmul split row-wise over the cluster's
    cores (the §7 parallelization: the column dimension is the vector, the
    row dimension is the multi-core dimension)."""
    eng = cluster.engine
    flops = 2.0 * n ** 3
    vl_bytes = n * ew_bits // 8
    opc_core = kernel_opc("matmul", vl_bytes, eng, whatif) * (64 // ew_bits) \
        if ew_bits == 64 else kernel_opc("matmul", vl_bytes, eng, whatif) * (64 / ew_bits)
    rows_per_core = ceil_div(n, cluster.n_cores)
    core_flops = 2.0 * rows_per_core * n * n
    t = core_flops / max(opc_core, 1e-9) + SETUP_CYCLES
    if cluster.n_cores > 1:
        t += SYNC_BASE_CYCLES + SYNC_PER_STEP_CYCLES * log2i_ceil(cluster.n_cores)
        # §7.1 "pressure on the memory system": every core re-streams the
        # shared B matrix once it no longer fits near-core storage (8 KiB
        # D$), paid at the per-core VLSU bandwidth (4*L B/cycle).  This is
        # what hands the large-problem ranking back to the big cores
        # (Fig 13's 128/256-element crossover).
        ewb = ew_bits // 8
        spill = max(0.0, n * n * ewb - 8192.0)
        t += spill * (cluster.n_cores - 1) / cluster.n_cores \
            / (4.0 * eng.n_lanes)
    return t


def matmul_opc(n: int, cluster: ClusterConfig,
               whatif: WhatIf = WhatIf(), ew_bits: int = 64) -> float:
    """Cluster-level DP-FLOP/cycle for an n^3 matmul (Fig 13 quantity)."""
    return 2.0 * n ** 3 / matmul_cycles(n, cluster, whatif, ew_bits)


def dotproduct_speedup_vs_scalar(n: int, engine: VectorEngineConfig,
                                 dtype: str = "fp") -> float:
    """§8.1: 2-lane Ara2 vs CVA6 on an n-element dotproduct."""
    if dtype == "int":
        # Integer ALU is single-cycle: no pipeline-drain tail (§8.1 explains
        # the fp/int speedup gap, 1.4x vs 2.2x, by the FPU latency).
        engine = dataclasses.replace(engine, fpu_pipe_depth={64: 1, 32: 1, 16: 1})
    vec_opc = kernel_opc("dotproduct", n * 8, engine)
    vec_cycles = 2.0 * n / max(vec_opc, 1e-9) + 30.0  # light strip-mine setup
    scalar_cycles = n * CVA6_DOT_CYCLES_PER_ELEM[dtype]
    return scalar_cycles / vec_cycles


def issue_rate_limit_opc(n: int, issue_cycles: int = 4, ew_bits: int = 64,
                         simd: int = 1) -> float:
    """The Fig 9/13 'issue-rate limitation' line for fmatmul: one vfmacc over
    n elements dispatched every ``issue_cycles`` cycles."""
    return 2.0 * simd * n / issue_cycles


def log2i_ceil(x: int) -> int:
    return max(1, (x - 1)).bit_length() if x > 1 else 0
