"""Ara2 machine model (paper contribution C1).

The vector engine abstraction that the rest of the framework is structured
around: L lanes, each with one 64-bit FPU datapath, a banked VRF slice, and a
share of the all-to-all units (SLDU / MASKU / VLSU).  At the TPU level the
"lane array" is realized twice:

  * intra-chip: Pallas BlockSpec tiling (a VMEM tile is a "vector register
    slice"; the MXU/VPU are the lane datapaths), and
  * inter-chip: the ``model`` mesh axis (each chip is a lane; ICI collectives
    are the inter-lane interconnect).

``VectorEngineConfig`` carries the Ara2 parameters used by the analytical
performance model (``perf_model``), the slide-interconnect cost model
(``slide``), and the PPA model (``ppa``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping

# Ara2 ISA/microarchitecture constants (paper §2-§4).
RVV_NUM_VREGS = 32
# VLEN contribution per lane in bits (Table 1: "1024 VLEN per lane"; the VRF
# was reduced 4x w.r.t. Ara's 4096 b/lane, §6 Key insights).
VLEN_PER_LANE_BITS = 1024
# Each lane has 8 VRF banks (§5.3: "the effective number of banks used in each
# lane is reduced from eight ...").
BANKS_PER_LANE = 8
# Lane datapath width: one 64-bit element per lane per cycle (§3, segmented
# memory ops discussion).
LANE_DATAPATH_BITS = 64
# VLSU bandwidth is half the compute byte throughput (§6: 4*L B/cycle vs
# 8*L B/cycle).
VLSU_BYTES_PER_LANE_PER_CYCLE = 4
ALU_BYTES_PER_LANE_PER_CYCLE = 8
# CVA6 issue rate: cycles between two vfmacc dispatches in the matmul main
# loop.  RVV 1.0 dropped it from 5 to 4 (§7.1 "Issue rate limitation").
ISSUE_CYCLES_RVV10 = 4
ISSUE_CYCLES_RVV05 = 5
# FPU pipeline depth R per element width (§3 Reductions: "the number of FPU
# pipeline registers increases with the EW").  fpnew-calibrated.
FPU_PIPE_DEPTH = {64: 4, 32: 3, 16: 2}
# Memory latency from request to response (§4): 7 cycles for Ara2, 5 for CVA6.
ARA_MEM_LATENCY = 7
CVA6_MEM_LATENCY = 5


@dataclasses.dataclass(frozen=True)
class VectorEngineConfig:
    """One Ara2 instance: ``n_lanes`` lanes, one 64-bit FPU per lane."""

    n_lanes: int = 4
    vlen_per_lane_bits: int = VLEN_PER_LANE_BITS
    n_vregs: int = RVV_NUM_VREGS
    banks_per_lane: int = BANKS_PER_LANE
    issue_cycles: int = ISSUE_CYCLES_RVV10
    fpu_pipe_depth: Mapping[int, int] = dataclasses.field(
        default_factory=lambda: dict(FPU_PIPE_DEPTH)
    )

    def __post_init__(self):
        if self.n_lanes < 1 or self.n_lanes & (self.n_lanes - 1):
            raise ValueError(f"n_lanes must be a power of two, got {self.n_lanes}")

    # ---- architectural sizes -------------------------------------------------
    @property
    def vlen_bits(self) -> int:
        return self.vlen_per_lane_bits * self.n_lanes

    @property
    def vlen_bytes(self) -> int:
        return self.vlen_bits // 8

    @property
    def vrf_bytes(self) -> int:
        return self.n_vregs * self.vlen_bytes

    @property
    def vrf_bytes_per_lane(self) -> int:
        return self.vrf_bytes // self.n_lanes

    def max_elements(self, ew_bytes: int, lmul: int = 1) -> int:
        """Max elements per vector register group (vl at a given LMUL)."""
        return lmul * self.vlen_bytes // ew_bytes

    @property
    def n_fpus(self) -> int:
        return self.n_lanes  # one FPU per lane

    # ---- throughput bounds ---------------------------------------------------
    @property
    def peak_fma_flops_per_cycle(self) -> float:
        """Peak DP FLOP/cycle: one FMA (2 FLOP) per lane per cycle."""
        return 2.0 * self.n_lanes

    def peak_flops_per_cycle(self, ew_bytes: int) -> float:
        """SIMD-packed peak FLOP/cycle for a given element width."""
        return 2.0 * self.n_lanes * (8 // ew_bytes)

    @property
    def mem_bytes_per_cycle(self) -> float:
        return float(VLSU_BYTES_PER_LANE_PER_CYCLE * self.n_lanes)

    def bytes_per_lane(self, vector_bytes: float) -> float:
        """The paper's central knob (§5.1): per-PE work granularity."""
        return vector_bytes / self.n_lanes

    def fpu_pipe(self, ew_bits: int) -> int:
        return self.fpu_pipe_depth[ew_bits]


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """A multi-core Ara2 system (paper §7): ``n_cores`` engines + one CVA6 and
    one memory bank per engine."""

    n_cores: int = 1
    engine: VectorEngineConfig = dataclasses.field(default_factory=VectorEngineConfig)

    @property
    def n_fpus(self) -> int:
        return self.n_cores * self.engine.n_fpus

    @property
    def peak_fma_flops_per_cycle(self) -> float:
        return self.n_cores * self.engine.peak_fma_flops_per_cycle

    def describe(self) -> str:
        return f"{self.n_cores}x{self.engine.n_lanes}L"


def fixed_fpu_sweep(n_fpus: int) -> list[ClusterConfig]:
    """All (cores x lanes) configurations with a fixed FPU budget, the paper's
    §7 experiment frame (e.g. 16 FPUs: 1x16L, 2x8L, 4x4L, 8x2L)."""
    out = []
    lanes = 2
    while lanes <= n_fpus:
        cores = n_fpus // lanes
        if cores * lanes == n_fpus:
            out.append(ClusterConfig(cores, VectorEngineConfig(n_lanes=lanes)))
        lanes *= 2
    return sorted(out, key=lambda c: c.n_cores)


def log2i(x: int) -> int:
    if x <= 0 or x & (x - 1):
        raise ValueError(f"expected positive power of two, got {x}")
    return x.bit_length() - 1


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b
