"""Power-of-two slide decomposition (paper contribution C2, §3 + Figs 2-3).

Ara2's insight: an interconnect supporting *arbitrary* slide amounts in one
step costs O(L^2) wiring; restricting single-step support to power-of-two
amounts and decomposing arbitrary slides into <= log2(L) micro-ops costs
O(L log L) and is what lets the unit scale.

TPU transplant: on the ICI torus an arbitrary one-shot shard rotation is an
``all_to_all``-class operation (every chip talks to every chip: same O(L^2)
cost shape), while a power-of-two-stride ``collective_permute`` is a cheap
neighbor-class hop.  ``mesh_slide`` therefore decomposes an arbitrary rotation
of a sharded axis into binary-weighted ``jax.lax.ppermute`` steps - the exact
analogue of the paper's micro-op decomposition.  Used for halo exchange
(conv2d / jacobi2d), FFT butterflies, ring schedules, and SSM chunk-boundary
hand-off.

``mux_count`` reproduces the Fig 3 interconnect-cost model (2:1 multiplexer
count as an area/wiring proxy) for the four slide-unit configurations the
paper plots, including the ~70% saving of the chosen design point.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from ..jax_compat import axis_size

from .vector_engine import log2i


def decompose_pow2(amount: int) -> list[int]:
    """Binary decomposition of a slide amount into power-of-two micro-ops.
    ``11 -> [8, 2, 1]``; sign is carried on each term."""
    sign = 1 if amount >= 0 else -1
    amount = abs(amount)
    return [sign * (1 << b) for b in range(amount.bit_length() - 1, -1, -1)
            if amount >> b & 1]


# ---------------------------------------------------------------------------
# Intra-array slides (vslideup/vslidedown semantics, zero fill).
# ---------------------------------------------------------------------------

def _shift1(x: jnp.ndarray, amount: int, axis: int, fill) -> jnp.ndarray:
    """One micro-op: shift by ``amount`` (any value) along ``axis``."""
    if amount == 0:
        return x
    n = x.shape[axis]
    pad = [(0, 0)] * x.ndim
    if amount > 0:  # vslideup: element i -> i + amount
        pad[axis] = (amount, 0)
        sl = [slice(None)] * x.ndim
        sl[axis] = slice(0, n)
    else:
        pad[axis] = (0, -amount)
        sl = [slice(None)] * x.ndim
        sl[axis] = slice(-amount, n - amount)
    return jnp.pad(x, pad, constant_values=fill)[tuple(sl)]


def slide(x: jnp.ndarray, amount: int, axis: int = 0, fill=0) -> jnp.ndarray:
    """Arbitrary-amount slide decomposed into power-of-two micro-ops.

    Functionally equal to a single shift (property-tested); structurally it
    mirrors the Ara2 hardware: each micro-op is a power-of-two shift the
    optimized SLDU supports natively."""
    for step in decompose_pow2(amount):
        x = _shift1(x, step, axis, fill)
    return x


def rotate(x: jnp.ndarray, amount: int, axis: int = 0) -> jnp.ndarray:
    """Circular slide via pow2 micro-ops (used by FFT butterflies)."""
    n = x.shape[axis]
    amount %= n
    out = x
    for step in decompose_pow2(amount):
        out = jnp.roll(out, step, axis=axis)
    return out


# ---------------------------------------------------------------------------
# Mesh-level slides (shard rotation across a named mesh axis).
# ---------------------------------------------------------------------------

def mesh_slide(x: jnp.ndarray, amount: int, axis_name: str) -> jnp.ndarray:
    """Rotate shards by ``amount`` positions along ``axis_name`` using
    binary-weighted collective_permutes.  Must run inside ``shard_map``.

    <= log2(L) ppermute steps, each a fixed-stride neighbor-class hop on the
    ICI torus - the paper's O(L log L) argument transplanted to collectives.
    """
    size = axis_size(axis_name)
    amount %= size
    for step in decompose_pow2(amount):
        perm = [(i, (i + step) % size) for i in range(size)]
        x = jax.lax.ppermute(x, axis_name, perm)
    return x


def mesh_halo_exchange(x: jnp.ndarray, halo: int, axis_name: str, axis: int = 0):
    """Exchange ``halo`` boundary rows with both mesh neighbors (slide-by-one,
    the SLDU's cheapest configuration).  Returns (left_halo, right_halo) from
    the neighboring shards; edges wrap (callers mask if non-periodic)."""
    size = axis_size(axis_name)
    sl_lo = [slice(None)] * x.ndim
    sl_lo[axis] = slice(0, halo)
    sl_hi = [slice(None)] * x.ndim
    sl_hi[axis] = slice(x.shape[axis] - halo, x.shape[axis])
    fwd = [(i, (i + 1) % size) for i in range(size)]
    bwd = [(i, (i - 1) % size) for i in range(size)]
    right_halo = jax.lax.ppermute(x[tuple(sl_lo)], axis_name, bwd)  # from right nbr
    left_halo = jax.lax.ppermute(x[tuple(sl_hi)], axis_name, fwd)   # from left nbr
    return left_halo, right_halo


# ---------------------------------------------------------------------------
# Interconnect cost model (Fig 3) - 2:1 mux count as area/wiring proxy.
# ---------------------------------------------------------------------------

# Element widths whose re-encodings ("reshuffles") the SLDU must support, and
# the byte fan-in each re-encoding contributes per output byte.
_RESHUFFLE_EWS = (16, 32, 64)
_RESHUFFLE_FANIN_PER_EW = 8


def mux_count(n_lanes: int, mode: str = "slideP2_tmux") -> int:
    """Number of 2:1 multiplexers for a slide-unit interconnect over the
    ``B = 8 * L`` lane bytes.  An n-to-1 mux costs n-1 2:1 muxes.

    Modes (Fig 3):
      * ``all_to_all``    - arbitrary slides + same-cycle reshuffle: every
        output byte selects among all B input bytes.
      * ``slideP2_tmux``  - the Ara2 design point: power-of-two slides only,
        slide XOR reshuffle time-multiplexed (fan-in: 2*log2(B) slide sources
        + 8 re-encode sources per supported EW).
      * ``slideP2``       - power-of-two slides only, no reshuffle support.
      * ``slide1``        - slide-by-one only (+identity).
    """
    bytes_total = 8 * n_lanes
    lb = log2i(bytes_total)
    fanin = {
        "all_to_all": bytes_total,
        "slideP2_tmux": 2 * lb + _RESHUFFLE_FANIN_PER_EW * len(_RESHUFFLE_EWS),
        "slideP2": 2 * lb + 1,
        "slide1": 3,
    }[mode]
    return bytes_total * (max(fanin, 1) - 1)


def sldu_saving(n_lanes: int) -> float:
    """Predicted area/wiring saving of the optimized SLDU (paper: 'saving up
    to 70% of the estimated area and wires')."""
    return 1.0 - mux_count(n_lanes, "slideP2_tmux") / mux_count(n_lanes, "all_to_all")
