"""Distributed semantics on 8 fake CPU devices (subprocesses, so the main
test process keeps its single real device)."""
import pytest

from helpers import run_with_devices


def test_mesh_slide_equals_roll():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.slide import mesh_slide
        mesh = make_mesh((8,), ("x",))
        from jax.sharding import NamedSharding, PartitionSpec as P
        x = jnp.arange(32.0)
        f = jax.jit(jax.shard_map(lambda v: mesh_slide(v, 3, "x"),
                    mesh=mesh, in_specs=P("x"), out_specs=P("x")))
        got = np.asarray(f(x)).reshape(8, 4)
        want = np.roll(np.arange(32.0).reshape(8, 4), 3, axis=0)
        np.testing.assert_allclose(got, want)
        # negative and >size amounts
        g2 = jax.jit(jax.shard_map(lambda v: mesh_slide(v, 13, "x"),
                     mesh=mesh, in_specs=P("x"), out_specs=P("x")))
        np.testing.assert_allclose(np.asarray(g2(x)).reshape(8, 4),
                                   np.roll(np.arange(32.).reshape(8,4), 13, 0))
        print("PASS")
    """)


def test_tree_allreduce_matches_psum():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.reduction import (allreduce_hd, allreduce_rs_ag,
                                          reduce_scatter_hd, allgather_hd)
        mesh = make_mesh((8,), ("x",))
        x = jnp.arange(64.0).reshape(8, 8)
        for fn in (allreduce_hd, allreduce_rs_ag):
            f = jax.jit(jax.shard_map(lambda v: fn(v, "x"), mesh=mesh,
                        in_specs=P("x"), out_specs=P("x")))
            got = np.asarray(f(x))
            want = np.tile(np.asarray(x).sum(0, keepdims=True), (8, 1))
            np.testing.assert_allclose(got, want, rtol=1e-6)
        # reduce-scatter shard s == chunk s of the summed vector
        f = jax.jit(jax.shard_map(lambda v: reduce_scatter_hd(v[0], "x"),
                    mesh=mesh, in_specs=P("x"), out_specs=P("x")))
        got = np.asarray(f(x))
        np.testing.assert_allclose(got, np.asarray(x).sum(0), rtol=1e-6)
        print("PASS")
    """)


def test_halo_exchange():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.slide import mesh_halo_exchange
        mesh = make_mesh((8,), ("x",))
        x = jnp.arange(32.0).reshape(32, 1)
        def body(v):
            left, right = mesh_halo_exchange(v, 1, "x", axis=0)
            return jnp.concatenate([left, v, right], 0)
        f = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=P("x"),
                                  out_specs=P("x")))
        got = np.asarray(f(x)).reshape(8, 6)
        # shard i rows: [left halo (last of i-1), rows, right halo (first of i+1)]
        for i in range(8):
            rows = np.arange(32).reshape(8, 4)[i]
            assert got[i, 1:5].ravel().tolist() == rows.tolist()
            assert got[i, 0] == (rows[0] - 1) % 32
            assert got[i, 5] == (rows[-1] + 1) % 32
        print("PASS")
    """)


def test_compressed_allreduce_error_feedback():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import compressed_allreduce
        mesh = make_mesh((8,), ("x",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
        def body(v):
            out, err = compressed_allreduce(v[0], "x")
            return out[None], err[None]
        f = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=P("x"),
                                  out_specs=(P("x"), P("x"))))
        got, err = f(x)
        want = np.asarray(x).mean(0)
        rel = np.abs(np.asarray(got)[0] - want).max() / np.abs(want).max()
        assert rel < 0.05, rel      # int8 quantization error bound
        # error feedback: accumulated error drives the mean residual to ~0
        # over repeated rounds of the same gradient
        accum = np.zeros(256); e = jnp.zeros((8, 256))
        def body2(v, e):
            out, err = compressed_allreduce(v[0], "x", error=e[0])
            return out[None], err[None]
        f2 = jax.jit(jax.shard_map(body2, mesh=mesh,
                     in_specs=(P("x"), P("x")), out_specs=(P("x"), P("x"))))
        for _ in range(20):
            out, e = f2(x, e)
            accum += np.asarray(out)[0]
        drift = np.abs(accum / 20 - want).max()
        assert drift < 0.01, drift
        print("PASS")
    """)


def test_sharded_train_step_matches_single_device():
    """Golden equivalence: the pjit-sharded train step must produce the same
    loss trajectory as the plain single-device step."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.models import build_model
        from repro.optim import AdamW
        from repro.distributed.sharding import ShardingPolicy
        from repro.train.trainer import make_train_step, state_shardings
        from repro.data import SyntheticTokens

        cfg = smoke_config("qwen3-0.6b")
        model = build_model(cfg)
        opt = AdamW(lr=1e-3)
        data = SyntheticTokens(cfg, 8, 32, seed=0)

        def run(mesh_shape, fsdp, sp):
            mesh = make_mesh(mesh_shape, ("data", "model"))
            policy = ShardingPolicy(fsdp=fsdp, sp=sp)
            step = make_train_step(model, opt, policy, mesh, donate=False)
            params = model.init(jax.random.key(0))
            state = opt.init(params)
            losses = []
            for i in range(3):
                batch = {k: jnp.asarray(v) for k, v in data(i).items()}
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
            return losses

        base = run((1, 1), False, False)
        shard = run((4, 2), True, True)
        np.testing.assert_allclose(base, shard, rtol=2e-2)
        print("PASS", base, shard)
    """, timeout=900)


def test_grad_sync_modes_agree():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import grad_sync
        mesh = make_mesh((8,), ("x",))
        rng = np.random.default_rng(1)
        g = {"w": jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)}
        outs = {}
        for mode in ("psum", "tree_bw", "tree_hd"):
            def body(gg):
                out, _ = grad_sync({"w": gg["w"][0]}, "x", mode=mode)
                return {"w": out["w"][None]}
            f = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=({"w": P("x")},),
                        out_specs={"w": P("x")}))
            outs[mode] = np.asarray(f(g)["w"])[0]
        np.testing.assert_allclose(outs["tree_bw"], outs["psum"], rtol=1e-5)
        np.testing.assert_allclose(outs["tree_hd"], outs["psum"], rtol=1e-5)
        print("PASS")
    """)
