"""Paper-faithfulness validation (EXPERIMENTS.md §Paper-validation).

Each test pins one *printed claim* of the paper against our perf/PPA model.
These are the reproduction gates: if a refactor breaks one, the model no
longer reproduces the paper.
"""
import pytest

from repro.core import (ENERGY_EFF_TABLE3, TT_FREQ_GHZ, WhatIf,
                        dotproduct_speedup_vs_scalar,
                        energy_efficiency_gflops_w, fixed_fpu_sweep, ideality,
                        issue_rate_limit_opc, matmul_opc,
                        pool_average_ideality, real_throughput_gflops,
                        sldu_saving)
from repro.core.ppa import AREA_KGE, sldu_area_saving, system_area_kge
from repro.core.vector_engine import ClusterConfig, VectorEngineConfig

E2, E4, E8, E16 = (VectorEngineConfig(n_lanes=l) for l in (2, 4, 8, 16))


def test_issue_rate_limit_16_flop_per_cycle():
    """§7.1: 'the single-core 16-lane Ara2 cannot theoretically go beyond
    16 DP-FLOP/cycle when operating on 32x32x32 matrices'."""
    assert issue_rate_limit_opc(32) == pytest.approx(16.0)


def test_rvv10_issue_rate_improvement():
    """§7.1: RVV 1.0 drops the matmul issue rate from 5 to 4 cycles/vfmacc
    (scalar forwarded with the vfmacc) - the limit line moves up 5/4."""
    assert issue_rate_limit_opc(32, issue_cycles=4) \
        == pytest.approx(issue_rate_limit_opc(32, issue_cycles=5) * 5 / 4)


def test_matmul_ideality_thresholds():
    """§5.2: matmul/conv2d reach >=95% from 128 B/lane, >=75% from 64."""
    for eng in (E2, E4, E8, E16):
        for kern in ("matmul", "conv2d"):
            assert ideality(kern, 128 * eng.n_lanes, eng) >= 0.95
            assert ideality(kern, 64 * eng.n_lanes, eng) >= 0.75


def test_pool_average_50pct_from_128_bpl():
    """§5.2: 'the system achieves, on average, 50% of its raw throughput
    ideality on all the kernels and configurations starting from
    128 Byte/Lane'."""
    for eng in (E2, E4, E8, E16):
        for bpl in (128, 256, 512):
            assert pool_average_ideality(bpl, eng) >= 0.50


def test_fig4_diagonal_property():
    """§5.1: ideality is ~constant at fixed bytes/lane (Fig 4 diagonals)."""
    for bpl in (32, 64, 128, 256):
        vals = [ideality("matmul", bpl * l, VectorEngineConfig(n_lanes=l))
                for l in (2, 4, 8, 16)]
        assert max(vals) - min(vals) < 0.02


def test_dotproduct_diagonal_regression_with_lanes():
    """§5.1: dotproduct ideality *decreases* with lanes at fixed B/lane
    (inter-lane reduction latency grows with log2 L)."""
    vals = [ideality("dotproduct", 256 * l, VectorEngineConfig(n_lanes=l))
            for l in (2, 4, 8, 16)]
    assert vals == sorted(vals, reverse=True)


def test_multicore_beats_single_core_32cubed():
    """§7.1/§Abstract: 8x2-lane > 3x the 16-lane single core on 32^3
    fmatmul; the 8x2L cluster reaches ~23.6 DP-FLOP/cycle."""
    single = matmul_opc(32, ClusterConfig(1, E16))
    multi = matmul_opc(32, ClusterConfig(8, E2))
    assert multi / single > 3.0
    assert multi == pytest.approx(23.6, rel=0.05)


def test_multicore_crossover_with_problem_size():
    """§7.1: the dual-core 8-lane and single-core 16-lane take over at
    128 and 256 elements - big cores win as vectors lengthen."""
    small_rank = sorted(fixed_fpu_sweep(16),
                        key=lambda c: -matmul_opc(16, c))
    large_rank = sorted(fixed_fpu_sweep(16),
                        key=lambda c: -matmul_opc(256, c))
    assert small_rank[0].n_cores == 8          # many small cores at 16^3
    assert large_rank[0].n_cores <= 2          # few big cores at 256^3


def test_dotproduct_speedups_vs_scalar():
    """§8.1: 2-lane Ara2 vs CVA6, 128-element dotproduct: 1.4x fp, 2.2x int."""
    assert dotproduct_speedup_vs_scalar(128, E2, "fp") \
        == pytest.approx(1.4, rel=0.1)
    assert dotproduct_speedup_vs_scalar(128, E2, "int") \
        == pytest.approx(2.2, rel=0.1)


def test_ideal_dispatcher_lifts_short_vectors():
    """§5.3/Fig 9: the ideal dispatcher lifts short-vector performance and
    the issue-rate line binds only the CVA6-coupled system."""
    eng = E16
    base = ideality("matmul", 512, eng)               # 32 B/lane
    ideal = ideality("matmul", 512, eng, WhatIf(ideal_dispatcher=True))
    assert ideal > base
    long_base = ideality("matmul", 128 * 16, eng)
    long_ideal = ideality("matmul", 128 * 16, eng,
                          WhatIf(ideal_dispatcher=True))
    assert long_ideal - long_base < 0.05              # amortized when long


def test_barber_pole_effect():
    """§5.4.1/Fig 8: Barber's Pole helps below ~32 B/lane, hurts beyond."""
    eng = E4
    short = 16 * 4    # 16 B/lane
    longv = 256 * 4   # 256 B/lane
    assert ideality("matmul", short, eng, WhatIf(barber_pole=True)) \
        > ideality("matmul", short, eng)
    assert ideality("matmul", longv, eng, WhatIf(barber_pole=True)) \
        < ideality("matmul", longv, eng)


def test_streamlined_vector_unit_gains_short_vectors():
    """§5.4.2/Fig 9: upsized queues boost <=32 B/lane; negligible later."""
    eng = E16
    w = WhatIf(ideal_dispatcher=True, streamlined=True)
    base = WhatIf(ideal_dispatcher=True)
    assert ideality("matmul", 16 * 16, eng, w) \
        > ideality("matmul", 16 * 16, eng, base) + 0.05


# ---------------------------------------------------------------------------
# PPA (§6, Tables 3-5) and multi-core energy (§7.2, Figs 14-15).
# ---------------------------------------------------------------------------

def test_sldu_area_saving_measured():
    """§6: optimized SLDU area -83% at 8 lanes vs the all-to-all one, and
    the new unit scales ~2x per lane doubling (Table 5)."""
    assert sldu_area_saving(8) >= 0.83
    assert AREA_KGE["new_sldu"][16] / AREA_KGE["new_sldu"][8] \
        == pytest.approx(2.0, abs=0.15)
    assert AREA_KGE["old_sldu"][16] / AREA_KGE["old_sldu"][8] \
        == pytest.approx(5.0, abs=0.2)


def test_predicted_vs_measured_saving():
    """Fig 3 predicts ~70%; the implementation measured more (>=83%) -
    'the greater reduction ... explained by the diminished routing
    density' (§6)."""
    assert sldu_area_saving(8) > sldu_saving(8)


def test_frequency_table():
    """Table 3: 1.35 GHz up to 8 lanes; 1.08 at 16 (0.8x)."""
    assert TT_FREQ_GHZ[2] == TT_FREQ_GHZ[4] == TT_FREQ_GHZ[8] == 1.35
    assert TT_FREQ_GHZ[16] == pytest.approx(1.08)


def test_energy_efficiency_ordering_fig15():
    """§7.2: 4x4L most efficient (~39 GFLOPS/W at 256^3), 2x8L next (~38),
    8x2L 5-18% below 4x4L."""
    effs = {c.describe(): energy_efficiency_gflops_w(256, c)
            for c in fixed_fpu_sweep(16)}
    assert effs["4x4L"] > effs["2x8L"] > effs["8x2L"]
    assert effs["4x4L"] == pytest.approx(39.2, rel=0.05)
    assert 0.05 <= 1 - effs["8x2L"] / effs["4x4L"] <= 0.18


def test_16lane_slowest_real_throughput_fig14():
    """§7.1/Fig 14: with real frequencies the 16-lane system 'becomes slower
    than all the other designs' (its 0.8x clock)."""
    for n in (64, 128, 256):
        t16 = real_throughput_gflops(n, ClusterConfig(1, E16))
        for c in (ClusterConfig(2, E8), ClusterConfig(4, E4),
                  ClusterConfig(8, E2)):
            assert t16 < real_throughput_gflops(n, c)


def test_table3_peak_efficiency_point():
    """Table 3: the 4-lane design is the most efficient single-core point
    (37.8 DP-GFLOPS/W)."""
    assert ENERGY_EFF_TABLE3[4] == max(ENERGY_EFF_TABLE3.values())


def test_area_single_core_monotone():
    for sldu in ("new_sldu", "old_sldu"):
        areas = [system_area_kge(l, sldu) for l in (2, 4, 8, 16)]
        assert areas == sorted(areas)
