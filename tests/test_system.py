"""End-to-end system behaviour: trainer loop with checkpoint/auto-resume,
straggler watchdog, serving engine, mesh policy, HLO cost parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, smoke_config
from repro.data import SyntheticTokens
from repro.distributed.mesh_policy import choose_mesh, enumerate_policies
from repro.distributed.sharding import ShardingPolicy
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine
from repro.serving import Request, ServeEngine
from repro.train import TrainConfig, Trainer, Watchdog


def _mesh11():
    return make_mesh((1, 1), ("data", "model"))


def _trainer(tmp_path, steps, arch="qwen3-0.6b", **kw):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    # fixed schedule horizon: resume determinism requires the schedule to be
    # a function of the global step only, not of the run length
    opt = AdamW(lr=warmup_cosine(1e-3, 2, 20))
    data = SyntheticTokens(cfg, batch_size=4, seq_len=32, seed=0)
    tc = TrainConfig(steps=steps, ckpt_dir=str(tmp_path), ckpt_every=4,
                     log_every=100, **kw)
    return Trainer(model, opt, ShardingPolicy(fsdp=False), _mesh11(), data,
                   tc, log=lambda *_: None)


def test_train_loss_decreases_and_resumes(tmp_path):
    tr = _trainer(tmp_path, steps=8)
    state, log = tr.run()
    assert log[-1]["loss"] < log[0]["loss"]
    assert log[-1]["step"] == 8
    # resume continues from the written checkpoint, exact step accounting
    tr2 = _trainer(tmp_path, steps=11)
    _, log2 = tr2.run()
    assert [r["step"] for r in log2] == [9, 10, 11]


def test_resume_is_deterministic(tmp_path):
    """Train 6 straight vs 4 (ckpt) + resume to 6: same final loss (restart
    determinism: checkpoint + pure-function data stream)."""
    t_a = _trainer(tmp_path / "a", steps=6)
    _, log_a = t_a.run()
    t_b1 = _trainer(tmp_path / "b", steps=4)
    t_b1.run()
    t_b2 = _trainer(tmp_path / "b", steps=6)
    _, log_b = t_b2.run()
    np.testing.assert_allclose(log_a[-1]["loss"], log_b[-1]["loss"],
                               rtol=1e-4)


def test_watchdog():
    w = Watchdog(factor=2.0, max_step_time=10.0)
    for _ in range(6):
        assert w.observe(1.0) is None
    assert w.observe(3.5) == "straggler"
    assert w.stragglers == 1
    assert w.observe(11.0) == "abort"


def test_serving_engine_batches_and_slots():
    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, max_batch=2, cache_len=64)
    reqs = [Request([1, 2, 3], 6, rid=0), Request([4, 5], 4, rid=1),
            Request([9], 5, rid=2)]
    res = eng.generate(reqs)
    assert sorted(r.rid for r in res) == [0, 1, 2]
    lens = {r.rid: len(r.tokens) for r in res}
    assert lens == {0: 6, 1: 4, 2: 5}
    for r in res:
        assert all(0 <= t < cfg.vocab_size for t in r.tokens)


def test_serving_greedy_deterministic():
    cfg = smoke_config("yi-6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    eng = ServeEngine(model, params, max_batch=2, cache_len=32)
    r1 = eng.generate([Request([1, 2, 3], 5, rid=0)])
    r2 = eng.generate([Request([1, 2, 3], 5, rid=0)])
    assert r1[0].tokens == r2[0].tokens


# ---------------------------------------------------------------------------
# Mesh policy (C4 transplant).
# ---------------------------------------------------------------------------

def test_enumerate_policies():
    ps = enumerate_policies(256)
    assert (256, 1) in ps and (1, 256) in ps and (16, 16) in ps
    assert all(dp * tp == 256 for dp, tp in ps)


def test_policy_prefers_dp_for_small_models():
    """The paper's multi-core insight at mesh level: a small dense model's
    train step wants many replicas (large dp, the '8 small cores')."""
    small = choose_mesh(get_config("qwen3-0.6b"), SHAPES["train_4k"], 256)
    assert small[0].dp >= small[0].tp
    big = choose_mesh(get_config("qwen3-moe-235b-a22b"), SHAPES["train_4k"],
                      256)
    assert any(c.fits for c in big)


def test_policy_decode_is_memory_bound():
    c = choose_mesh(get_config("yi-6b"), SHAPES["decode_32k"], 256)[0]
    assert c.t_memory > c.t_compute


# ---------------------------------------------------------------------------
# HLO cost parser (subprocess: needs multiple devices).
# ---------------------------------------------------------------------------

def test_hlo_cost_parser_on_known_program():
    from helpers import run_with_devices
    run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.roofline.hlo_cost import HloCost
        mesh = make_mesh((2, 4), ("data", "model"))
        def body(x, w):
            def step(c, wi):
                return jnp.tanh(c @ wi), None
            out, _ = jax.lax.scan(step, x, w)
            return out.sum()
        K, N = 7, 256
        f = jax.jit(body, in_shardings=(
            NamedSharding(mesh, P("data", None)),
            NamedSharding(mesh, P(None, None, "model"))))
        co = f.lower(jax.ShapeDtypeStruct((64, N), jnp.float32),
                     jax.ShapeDtypeStruct((K, N, N), jnp.float32)).compile()
        c = HloCost(co.as_text()).cost()
        # per-device dot: (32,256)@(256,64) x 7 trips
        assert c.flops == 2 * 32 * 256 * 64 * K, c.flops
        ag = c.coll_breakdown["all-gather"]
        assert abs(ag - 32 * 256 * 4 / 4 * K) < 1, ag
        print("PASS")
    """)
