"""Dry-run + roofline machinery end-to-end on a small fake mesh (the full
production sweep runs via `python -m repro.launch.dryrun --all`)."""
from helpers import run_with_devices


def test_dryrun_machinery_small_mesh():
    """Lower+compile a smoke-config train and decode cell on a (2,4) mesh
    and extract roofline terms - the same code path as the production
    dry-run, at test scale."""
    run_with_devices("""
        import dataclasses, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import smoke_config
        from repro.configs.base import ShapeConfig
        from repro.distributed.sharding import (ShardingPolicy,
            batch_shardings, cache_shardings, tree_shardings)
        from repro.distributed.act_sharding import activation_sharding
        from repro.models import build_model, input_specs
        from repro.models.layers import PT
        from repro.optim import AdamW
        from repro.roofline.analysis import analyze
        from repro.train.trainer import _step_body

        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = smoke_config("qwen3-0.6b")
        model = build_model(cfg)
        policy = ShardingPolicy(fsdp=True, sp=True)
        pspecs = model.pspecs(policy.param_rules(), dict(mesh.shape))
        param_sh = tree_shardings(mesh, pspecs)
        shape = ShapeConfig("mini_train", 64, 8, "train")
        batch = input_specs(cfg, shape)
        batch_sh = batch_shardings(mesh, batch, policy)

        opt = AdamW(lr=1e-3)
        leaves = lambda f: jax.tree_util.tree_map(
            f, model.templates, is_leaf=lambda x: isinstance(x, PT))
        state_specs = {
            "master": leaves(lambda t: jax.ShapeDtypeStruct(t.shape,
                                                            jnp.float32)),
            "m": leaves(lambda t: jax.ShapeDtypeStruct(t.shape, jnp.float32)),
            "v": leaves(lambda t: jax.ShapeDtypeStruct(t.shape, jnp.float32)),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}
        state_sh = {"master": param_sh, "m": param_sh, "v": param_sh,
                    "step": NamedSharding(mesh, P())}
        body = _step_body(model, opt, mesh, policy.act_rules(), 1.0, True)
        fn = jax.jit(body, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None))
        with mesh:
            compiled = fn.lower(state_specs, batch).compile()
        roof = analyze(compiled, arch="smoke", shape="mini_train",
                       mesh_desc="2x4", chips=8, model_flops=1e9)
        assert roof.flops_per_device > 0
        assert roof.bytes_per_device > 0
        assert roof.coll_bytes_per_device > 0   # TP/FSDP must communicate
        assert roof.dominant in ("compute", "memory", "collective")
        assert 0 < roof.t_bound < 100

        # decode cell
        shape_d = ShapeConfig("mini_decode", 64, 8, "decode")
        cache_specs = model.cache_shapes(8, 64)
        cache_sh = cache_shardings(mesh, cache_specs, policy, batch_size=8)
        tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)

        def decode_fn(params, cache, tokens):
            with activation_sharding(mesh, policy.act_rules()):
                return model.decode(params, cache, tokens)
        param_specs = leaves(lambda t: jax.ShapeDtypeStruct(t.shape, t.dtype))
        fn_d = jax.jit(decode_fn, in_shardings=(param_sh, cache_sh, None),
                       donate_argnums=(1,))
        with mesh:
            co_d = fn_d.lower(param_specs, cache_specs, tok).compile()
        ma = co_d.memory_analysis()
        # donation must alias the cache through to the output
        assert ma.alias_size_in_bytes > 0
        print("PASS")
    """, timeout=900)
