"""Multi-replica serving cluster: router-policy equivalence, preemption
correctness, shared-pool accounting, and rid-keyed sampling invariance."""
import jax
import pytest

from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import (BlockAllocator, ClusterEngine, PoolPressure,
                           Request, ServeEngine)

CACHE_LEN = 64
BLOCK = 8


@pytest.fixture(scope="module")
def model_and_params():
    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _cluster(model_and_params, **kw):
    _, model, params = model_and_params
    kw.setdefault("cache_len", CACHE_LEN)
    kw.setdefault("block_size", BLOCK)
    return ClusterEngine(model, params, **kw)


def _single(model_and_params, **kw):
    _, model, params = model_and_params
    kw.setdefault("cache_len", CACHE_LEN)
    return ServeEngine(model, params, **kw)


def _trace(n=10):
    return [Request([1 + i, 2 + i, 3 + i], 5 + (i % 4), rid=i)
            for i in range(n)]


@pytest.mark.parametrize("router",
                         ["round_robin", "least_loaded", "shortest_queue"])
def test_cluster_matches_single_engine(model_and_params, router):
    """(a) greedy outputs are replica-placement- and router-independent:
    a 2x2 cluster produces the same tokens as one 4-slot engine."""
    reqs = _trace()
    ref = _single(model_and_params, max_batch=4,
                  kv_layout="paged").generate(reqs)
    cl = _cluster(model_and_params, replicas=2, total_slots=4,
                  router=router)
    got = cl.generate(reqs)
    for a, b in zip(ref, got):
        assert a.tokens == b.tokens, (router, a.rid)
    s = cl.last_stats
    assert s.mode == "cluster" and s.router_policy == router
    assert len(cl.replica_stats) == 2
    assert s.generated_tokens == sum(r.max_new_tokens for r in reqs)


def test_cluster_sampled_matches_single_engine(model_and_params):
    """(b) rid-keyed sampling: temperature>0 outputs are also identical
    between the cluster and a single engine (placement cannot perturb a
    request's sampled stream)."""
    reqs = [Request([2 + i, 3 + i], 6, temperature=0.8, rid=i)
            for i in range(6)]
    key = jax.random.key(7)
    ref = _single(model_and_params, max_batch=4,
                  kv_layout="paged").generate(reqs, key=key)
    got = _cluster(model_and_params, replicas=2,
                   total_slots=4).generate(reqs, key=key)
    for a, b in zip(ref, got):
        assert a.tokens == b.tokens, a.rid
    # and the streams do depend on the base key (not accidentally frozen)
    other = _cluster(model_and_params, replicas=2, total_slots=4).generate(
        reqs, key=jax.random.key(8))
    assert any(a.tokens != b.tokens for a, b in zip(ref, other))


def test_preempted_request_completes_correctly(model_and_params):
    """(c) pool pressure fires preemption, and the preempted request's
    final tokens are identical to an uncontended run (re-prefill with the
    generated prefix + rid-keyed streams make eviction invisible)."""
    reqs = [Request([3 * i + 1, 3 * i + 2], 24, rid=i) for i in range(6)]
    ref = _single(model_and_params, max_batch=4,
                  kv_layout="paged").generate(reqs)
    # 4 slots x worst case 4 blocks (2 + 23 pos) = 16 blocks wanted
    # concurrently, against a 10-block pool: growth must preempt
    cl = _cluster(model_and_params, replicas=2, total_slots=4, n_blocks=11)
    got = cl.generate(reqs)
    assert cl.last_stats.preempted >= 1
    assert cl.last_stats.requeued == cl.last_stats.preempted
    for a, b in zip(ref, got):
        assert a.tokens == b.tokens, a.rid
        assert len(b.tokens) == 24


def test_preemption_invisible_in_sampled_stream(model_and_params):
    """(c'') preemption is invisible to *sampled* output too: re-prefill
    resumes the rid-keyed stream at index len(done), so a temperature>0
    request evicted mid-decode still matches its uncontended run."""
    reqs = [Request([3 * i + 1, 3 * i + 2], 24, temperature=0.9, rid=i)
            for i in range(6)]
    key = jax.random.key(11)
    ref = _single(model_and_params, max_batch=4,
                  kv_layout="paged").generate(reqs, key=key)
    cl = _cluster(model_and_params, replicas=2, total_slots=4, n_blocks=11)
    got = cl.generate(reqs, key=key)
    assert cl.last_stats.preempted >= 1
    for a, b in zip(ref, got):
        assert a.tokens == b.tokens, a.rid


def test_shared_pool_drains_clean(model_and_params):
    """(d) leak check: after every drain (with and without preemption) the
    shared pool is fully free and unreserved."""
    cl = _cluster(model_and_params, replicas=2, total_slots=4, n_blocks=11)
    for _ in range(2):
        cl.generate(_trace(8))
        assert cl.pool.n_live == 0
        assert cl.pool.n_reserved == 0
        assert cl.pool.n_free == cl.pool.capacity
        assert cl.pool.live_by_owner() == {}


def test_priority_guides_victim_selection(model_and_params):
    """(e) preemption evicts the lowest-priority request first: the
    high-priority requests' slots survive (all still complete, and at
    least one preemption hit a low-priority rid)."""
    # priorities: rids 0/1 low, 2..5 high; same shapes as (c) so pressure
    # fires.  Low-priority requests still finish (requeue, not drop).
    reqs = [Request([3 * i + 1, 3 * i + 2], 24, rid=i,
                    priority=(0 if i < 2 else 1)) for i in range(6)]
    ref = _single(model_and_params, max_batch=4,
                  kv_layout="paged").generate(reqs)
    cl = _cluster(model_and_params, replicas=2, total_slots=4, n_blocks=11)
    got = cl.generate(reqs)
    assert cl.last_stats.preempted >= 1
    for a, b in zip(ref, got):
        assert a.tokens == b.tokens, a.rid


def test_preemption_hysteresis_prevents_thrash(model_and_params):
    """(c''') anti-thrash regression: the raw FIFO requeue (hysteresis 0)
    re-admits a victim straight back into the pressure that evicted it —
    an admit → preempt → admit loop paying a re-prefill per bounce.  With
    the hysteresis the victim waits out a few scheduler rounds, so the
    same trace completes with strictly fewer preemptions, and outputs
    stay identical to the uncontended run either way."""
    reqs = [Request([3 * i + 1, 3 * i + 2], 24, rid=i) for i in range(6)]
    ref = _single(model_and_params, max_batch=4,
                  kv_layout="paged").generate(reqs)
    counts = {}
    for k in (0, 4):
        cl = _cluster(model_and_params, replicas=2, total_slots=4,
                      n_blocks=11, preempt_hysteresis=k)
        got = cl.generate(reqs)
        for a, b in zip(ref, got):
            assert a.tokens == b.tokens, (k, a.rid)
        counts[k] = cl.last_stats.preempted
    # the k=0 loop fires repeatedly (measured: 8 preemptions on this
    # trace); the hysteresis collapses it
    assert counts[0] > counts[4] >= 1, counts
    # mid-prefill preemption: victims evicted before their first token
    # re-prefill from scratch (done unchanged) and still finish correctly
    assert all(len(r.tokens) == 24 for r in ref)


def test_hysteresis_waived_when_cluster_idle(model_and_params):
    """A cool-down must never stall an idle cluster: if every replica
    drains while the queue head is still cooling down, it is admitted
    immediately (an empty cluster cannot be under pressure)."""
    # tiny pool: the lone long request is preempted by nothing (no
    # co-tenants), but a pair that forces one eviction then drains
    # exercises the waiver path
    reqs = [Request([1, 2], 20, rid=0, priority=1),
            Request([5, 6], 20, rid=1, priority=0)]
    cl = _cluster(model_and_params, replicas=2, total_slots=2, n_blocks=5,
                  preempt_hysteresis=100)
    got = cl.generate(reqs)
    assert [len(r.tokens) for r in got] == [20, 20]
    ref = _single(model_and_params, max_batch=2, kv_layout="paged",
                  block_size=BLOCK).generate(reqs)
    for a, b in zip(ref, got):
        assert a.tokens == b.tokens, a.rid


def test_cluster_rejects_impossible_request(model_and_params):
    """(f) a request whose worst case exceeds the whole shared pool errors
    up front; the cluster stays usable afterwards."""
    cl = _cluster(model_and_params, replicas=2, total_slots=4, n_blocks=5)
    with pytest.raises(ValueError, match="KV blocks"):
        cl.generate([Request(list(range(8)), 40, rid=0)])
    assert cl.pool.n_live == 0
    res = cl.generate([Request([1, 2], 4, rid=1)])
    assert len(res[0].tokens) == 4


def test_cluster_validates_shape_and_family(model_and_params):
    _, model, params = model_and_params
    with pytest.raises(ValueError, match="router"):
        ClusterEngine(model, params, router="random")
    with pytest.raises(ValueError, match="multiple"):
        ClusterEngine(model, params, replicas=3, total_slots=4)
    cfg = smoke_config("xlstm-350m")
    scan_model = build_model(cfg)
    scan_params = scan_model.init(jax.random.key(0))
    # scan families cluster on the dense slot layout; explicitly asking
    # for paged still fails loudly (no block hooks to page with)
    with pytest.raises(ValueError, match="paged"):
        ClusterEngine(scan_model, scan_params, kv_layout="paged")
    cl = ClusterEngine(scan_model, scan_params, replicas=2, total_slots=4,
                       cache_len=32)
    assert cl.kv_layout == "dense" and cl.pool is None


def test_scan_cluster_matches_single_engine():
    """Dense-layout cluster (slot-addressable recurrent state): scan
    families routed over narrow replicas emit the single-engine stream,
    greedy and sampled rows alike."""
    cfg = smoke_config("zamba2-1.2b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    reqs = [Request([1 + i, 2 + i, 3 + i], 4 + (i % 3),
                    temperature=(1.0 if i % 2 else 0.0), rid=i)
            for i in range(7)]
    key = jax.random.key(23)
    ref = ServeEngine(model, params, max_batch=4, cache_len=32,
                      mode="continuous").generate(reqs, key=key)
    cl = ClusterEngine(model, params, replicas=2, total_slots=4,
                       cache_len=32)
    for a, b in zip(ref, cl.generate(reqs, key=key)):
        assert a.tokens == b.tokens, a.rid
    assert cl.last_stats.kv_layout == "dense"
    assert cl.last_stats.preempted == 0   # no pool, no pressure


def test_scan_state_reset_on_preempt_no_leak():
    """The per-slot scan-state analog of the allocator leak checks: a
    preempted slot's recurrent state is zeroed immediately, and the slot's
    next occupant decodes exactly as it would on a fresh engine - nothing
    of the evicted request leaks through the recurrent state."""
    import numpy as np
    from repro.models.xlstm_lm import XLSTM_STATE_AXES
    cfg = smoke_config("xlstm-350m")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    key = jax.random.key(31)
    eng = ServeEngine(model, params, max_batch=1, cache_len=32,
                      mode="continuous")
    eng.begin_session(key)
    victim = Request([9, 8, 7], 8, temperature=1.3, rid=0)
    eng.session_admit(victim, tag=0)
    eng.session_step()
    eng.session_step()
    _, requeued = eng.session_preempt(0)
    assert len(requeued.done) == 3      # admit token + two step tokens
    cache = eng._sess.cache
    assert int(np.asarray(cache["pos"])[0]) == 0
    for name, ax in XLSTM_STATE_AXES.items():
        row = np.moveaxis(np.asarray(cache[name], np.float32), ax, 0)[0]
        assert not row.any(), name
    # next occupant of the same slot: byte-identical to a fresh engine
    nxt = Request([1, 2, 3], 4, temperature=0.9, rid=1)
    eng.session_admit(nxt, tag=1)
    outs = {}
    while eng.session_active:
        for tag, res in eng.session_step():
            outs[tag] = res
    eng.end_session()
    fresh = ServeEngine(model, params, max_batch=1, cache_len=32,
                        mode="continuous").generate([nxt], key=key)[0]
    assert outs[1].tokens == fresh.tokens
    # and the victim's resume is preemption-invisible, recurrent state
    # rebuilt from prompt + done alone
    resumed = ServeEngine(model, params, max_batch=1, cache_len=32,
                          mode="continuous").generate([requeued],
                                                      key=key)[0]
    uninterrupted = ServeEngine(model, params, max_batch=1, cache_len=32,
                                mode="continuous").generate([victim],
                                                            key=key)[0]
    assert resumed.tokens == uninterrupted.tokens


def test_cotenant_held_pool_fails_loudly(model_and_params):
    """(h) generate() on an engine whose shared pool is held by a
    co-tenant raises instead of busy-spinning (only a cluster driver can
    interleave engines to resolve the wait)."""
    _, model, params = model_and_params
    pool = BlockAllocator(9, BLOCK)         # 8 allocatable blocks
    kw = dict(max_batch=1, cache_len=CACHE_LEN, kv_layout="paged",
              allocator=pool)
    a = ServeEngine(model, params, owner="a", **kw)
    b = ServeEngine(model, params, owner="b", **kw)
    a.begin_session()
    # worst case 8 blocks (3 + 59 positions): a's reservation covers the
    # whole pool
    assert a.session_admit(Request([1, 2, 3], 60, rid=0), tag=0) is None
    with pytest.raises(MemoryError, match="co-tenants"):
        b.generate([Request([4, 5, 6], 60, rid=1)])
    a.session_preempt(0)
    a.end_session()
    assert pool.n_live == 0 and pool.n_reserved == 0


def test_shared_pool_rejects_conflicting_tenants(model_and_params):
    """(i) a shared pool refuses mixed admission policies (overcommit
    growth would eat a reserve tenant's promised blocks) and conflicting
    block sizes."""
    _, model, params = model_and_params
    pool = BlockAllocator(9, BLOCK)
    kw = dict(max_batch=1, cache_len=CACHE_LEN, kv_layout="paged",
              allocator=pool)
    ServeEngine(model, params, admission="reserve", **kw)
    with pytest.raises(ValueError, match="admission"):
        ServeEngine(model, params, admission="overcommit", **kw)
    with pytest.raises(ValueError, match="block_size"):
        ServeEngine(model, params, block_size=BLOCK * 2, **kw)


def test_prefill_finished_result_survives_pool_pressure(model_and_params):
    """(g') a Result finished during session_step's prefill phase must not
    be lost when a later slot's growth raises PoolPressure in the same
    step: the slot is already released, so the Result is parked in the
    session and returned by the retried step."""
    _, model, params = model_and_params
    eng = ServeEngine(model, params, max_batch=2, cache_len=32,
                      kv_layout="paged", block_size=8, n_blocks=3,
                      admission="overcommit")
    eng.begin_session()
    # A: one chunk, budget satisfied by prefill alone (finishes in-phase)
    assert eng.session_admit(Request([1, 2, 3], 1, rid=0), tag=0) is None
    # B: three chunks against a 2-block pool -> pressure mid-prefill
    assert eng.session_admit(Request(list(range(17)), 4, rid=1),
                             tag=1) is None
    with pytest.raises(PoolPressure):
        eng.session_step()
    tag, requeued = eng.session_preempt(1)   # evict B, blocks freed
    assert tag == 1 and requeued.done == () and requeued.requeues == 1
    finished = eng.session_step()            # retry returns A's Result
    assert [(t, r.rid, len(r.tokens)) for t, r in finished] == [(0, 0, 1)]
    eng.session_abort()
    assert eng.allocator.n_live == 0 and eng.allocator.n_reserved == 0


def test_mid_prefill_preemption_keeps_ttft_base(model_and_params):
    """(g'') a request evicted before its first token keeps its original
    admission as the TTFT base: the eventual Result.prefill_ms spans the
    aborted attempt and the requeue wait, not just the final attempt."""
    import time as _time
    _, model, params = model_and_params
    eng = ServeEngine(model, params, max_batch=2, cache_len=32,
                      kv_layout="paged", block_size=8, n_blocks=4,
                      admission="overcommit")
    eng.begin_session()
    # co-tenant B takes 1 of the 3 blocks; A needs 3 prefill chunks, so
    # its third chunk finds the pool empty mid-prefill
    assert eng.session_admit(Request([1, 2, 3], 2, rid=1), tag=1) is None
    assert eng.session_admit(Request(list(range(17)), 2, rid=0),
                             tag=0) is None
    with pytest.raises(PoolPressure):
        eng.session_step()
    _, requeued = eng.session_preempt(1)     # evict A (admitted 2nd)
    assert requeued.rid == 0 and requeued.done == ()
    assert requeued.first_admit_t is not None
    _time.sleep(0.06)                        # the requeue wait
    finished = {}
    while eng.session_active:                # drain B, freeing its block
        for t, r in eng.session_step():
            finished[t] = r
    assert eng.session_admit(requeued, tag=0) is None
    while eng.session_active:
        for t, r in eng.session_step():
            finished[t] = r
    assert len(finished[0].tokens) == 2
    assert finished[0].prefill_ms >= 60.0    # spans eviction + wait
    eng.end_session()
    assert eng.allocator.n_live == 0


def test_overcommit_without_cluster_surfaces_pool_pressure(
        model_and_params):
    """(g) an overcommitted single engine propagates PoolPressure from
    generate (preemption is the cluster driver's job), and its abort path
    leaks nothing."""
    eng = _single(model_and_params, max_batch=4, kv_layout="paged",
                  block_size=BLOCK, n_blocks=9, admission="overcommit")
    reqs = [Request([3 * i + 1, 3 * i + 2], 24, rid=i) for i in range(4)]
    with pytest.raises(PoolPressure):
        eng.generate(reqs)
    assert eng.allocator.n_live == 0
    assert eng.allocator.n_reserved == 0


# ---------------------------------------------------------------------------
# Threaded driver: byte-identity with the sequential reference.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("router",
                         ["round_robin", "least_loaded", "shortest_queue"])
def test_threaded_driver_matches_sequential(model_and_params, router):
    """(h) the threaded driver is byte-identical to the sequential one
    under every router (rid-keyed sampling makes outputs timing- and
    placement-independent; only the wall clock may differ)."""
    reqs = _trace()
    cl = _cluster(model_and_params, replicas=2, total_slots=4,
                  router=router)
    seq = cl.generate(reqs, driver="sequential")
    thr = cl.generate(reqs, driver="threaded")
    for a, b in zip(seq, thr):
        assert a.tokens == b.tokens, (router, a.rid)
    s = cl.last_stats
    assert s.mode == "cluster" and s.router_policy == router
    assert s.generated_tokens == sum(r.max_new_tokens for r in reqs)


def test_threaded_driver_reserve_admission(model_and_params):
    """(h') reserve admission under the threaded driver: a worker-side
    reservation can lose the pool race the coordinator's headroom check
    won (admit_retry protocol) — outputs still match."""
    reqs = _trace(8)
    cl = _cluster(model_and_params, replicas=2, total_slots=4,
                  n_blocks=17, admission="reserve")
    seq = cl.generate(reqs, driver="sequential")
    thr = cl.generate(reqs, driver="threaded")
    for a, b in zip(seq, thr):
        assert a.tokens == b.tokens, a.rid
    assert cl.pool.n_live == 0 and cl.pool.n_reserved == 0


def test_threaded_driver_sampled_matches_sequential(model_and_params):
    """(h'') sampled streams too: temperature > 0 exercises the rid+index
    keyed sampler from concurrent worker threads."""
    reqs = [Request([1 + i, 2 + i, 3 + i], 5 + (i % 4), temperature=0.9,
                    rid=i) for i in range(8)]
    key = jax.random.key(7)
    cl = _cluster(model_and_params, replicas=2, total_slots=4)
    seq = cl.generate(reqs, key=key, driver="sequential")
    thr = cl.generate(reqs, key=key, driver="threaded")
    for a, b in zip(seq, thr):
        assert a.tokens == b.tokens, a.rid


def test_threaded_driver_preemption_invisible(model_and_params):
    """(h''') pool pressure under the threaded driver resolves through
    the coordinator (pressure event -> victim preempt -> resume) and
    stays invisible in the output; the shared pool drains clean and the
    lifecycle trace stays well-formed.  The preemption *count* is
    timing-dependent under threads (unlike the sequential driver's
    deterministic schedule), but with 4 concurrent 4-block requests
    against a 10-block pool at least one eviction is unavoidable."""
    from repro.serving import Tracer, validate_lifecycle
    reqs = [Request([3 * i + 1, 3 * i + 2], 24, rid=i) for i in range(6)]
    ref = _single(model_and_params, max_batch=4,
                  kv_layout="paged").generate(reqs)
    cl = _cluster(model_and_params, replicas=2, total_slots=4, n_blocks=11,
                  driver="threaded")
    tracer = Tracer()
    cl.set_tracer(tracer)
    try:
        got = cl.generate(reqs)
    finally:
        cl.set_tracer(None)
    assert cl.last_stats.preempted >= 1
    assert cl.last_stats.requeued == cl.last_stats.preempted
    for a, b in zip(ref, got):
        assert a.tokens == b.tokens, a.rid
    assert cl.pool.n_live == 0 and cl.pool.n_reserved == 0
    assert cl.pool.n_free == cl.pool.capacity
    validate_lifecycle(tracer.events())


def test_cluster_stream_yields_ordered_tokens(model_and_params):
    """(i) the streaming API: per-rid TokenEvents arrive in index order
    with exactly one final marker, and concatenate to the generate
    output — under both drivers."""
    reqs = _trace(6)
    cl = _cluster(model_and_params, replicas=2, total_slots=4)
    ref = cl.generate(reqs, driver="sequential")
    for driver in ("sequential", "threaded"):
        by_rid = {}
        finals = 0
        for ev in cl.stream(reqs, driver=driver):
            assert ev.index == len(by_rid.setdefault(ev.rid, []))
            by_rid[ev.rid].append(ev.token)
            finals += ev.final
        assert finals == len(reqs), driver
        for r in ref:
            assert by_rid[r.rid] == r.tokens, (driver, r.rid)


def test_stream_propagates_failures(model_and_params):
    """(i') an exception inside a streaming run re-raises out of the
    generator (after the driver thread is joined) instead of hanging the
    consumer."""
    cl = _cluster(model_and_params, replicas=2, total_slots=4)
    bad = [Request(list(range(CACHE_LEN + 8)), 4, rid=0)]
    with pytest.raises(ValueError):
        list(cl.stream(bad, driver="threaded"))


def test_invalid_driver_rejected(model_and_params):
    """(j) driver names are validated at construction and per call."""
    with pytest.raises(ValueError, match="driver"):
        _cluster(model_and_params, replicas=2, total_slots=4,
                 driver="asyncio")
    cl = _cluster(model_and_params, replicas=2, total_slots=4)
    with pytest.raises(ValueError, match="driver"):
        cl.generate(_trace(2), driver="greenlet")
