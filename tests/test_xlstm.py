"""xLSTM cells: chunkwise-parallel mLSTM vs the step recurrence; sLSTM scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.xlstm import (mlstm_parallel, mlstm_step, slstm_init_state,
                                slstm_scan)

KEY = jax.random.key(3)


def make(b=2, h=2, s=64, dk=8, dv=8):
    f = jax.random.fold_in
    q = jax.random.normal(f(KEY, 1), (b, h, s, dk))
    k = jax.random.normal(f(KEY, 2), (b, h, s, dk))
    v = jax.random.normal(f(KEY, 3), (b, h, s, dv))
    ig = jax.random.normal(f(KEY, 4), (b, h, s)) * 0.5
    fg = jax.random.normal(f(KEY, 5), (b, h, s)) * 0.5 + 2.0
    return q, k, v, ig, fg


def recurrent_oracle(q, k, v, ig, fg):
    b, h, s, dk = q.shape
    state = (jnp.zeros((b, h, dk, v.shape[-1])), jnp.zeros((b, h, dk)),
             jnp.full((b, h), -1e30))
    ys = []
    for t in range(s):
        state, y = mlstm_step(state, q[:, :, t], k[:, :, t], v[:, :, t],
                              ig[:, :, t], fg[:, :, t])
        ys.append(y)
    return jnp.stack(ys, axis=2), state


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_mlstm_parallel_matches_recurrence(chunk):
    q, k, v, ig, fg = make()
    want, wstate = recurrent_oracle(q, k, v, ig, fg)
    got, gstate = mlstm_parallel(q, k, v, ig, fg, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4,
                               rtol=1e-3)
    for a, b_ in zip(gstate, wstate):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-4,
                                   rtol=1e-3)


def test_mlstm_chunk_invariance():
    q, k, v, ig, fg = make(s=96)
    y1, _ = mlstm_parallel(q, k, v, ig, fg, chunk=16)
    y2, _ = mlstm_parallel(q, k, v, ig, fg, chunk=48)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4,
                               rtol=1e-3)


def test_mlstm_state_carry():
    """Processing [first half] then [second half with carried state] equals
    processing the whole sequence."""
    q, k, v, ig, fg = make(s=64)
    full, _ = mlstm_parallel(q, k, v, ig, fg, chunk=16)
    h1, st = mlstm_parallel(q[:, :, :32], k[:, :, :32], v[:, :, :32],
                            ig[:, :, :32], fg[:, :, :32], chunk=16)
    h2, _ = mlstm_parallel(q[:, :, 32:], k[:, :, 32:], v[:, :, 32:],
                           ig[:, :, 32:], fg[:, :, 32:], chunk=16, state=st)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(full[:, :, 32:]),
                               atol=2e-4, rtol=1e-3)


def test_mlstm_exp_gate_stability():
    """Large input gates must not overflow (running-max stabilization)."""
    q, k, v, ig, fg = make(s=32)
    y, st = mlstm_parallel(q, k, v, ig + 40.0, fg, chunk=8)
    assert bool(jnp.isfinite(y).all())
    assert all(bool(jnp.isfinite(s).all()) for s in st)


def test_slstm_scan_shapes_and_stability():
    b, s, h, dh = 2, 16, 4, 8
    gates = jax.random.normal(jax.random.fold_in(KEY, 9), (b, s, h, dh, 4))
    r_w = jax.random.normal(jax.random.fold_in(KEY, 10), (4, h, dh, dh)) * 0.1
    hs, state = slstm_scan(gates, r_w, slstm_init_state(b, h, dh))
    assert hs.shape == (b, s, h, dh)
    assert bool(jnp.isfinite(hs).all())
    # recurrence actually feeds back: zeroing r_w changes outputs
    hs0, _ = slstm_scan(gates, r_w * 0.0, slstm_init_state(b, h, dh))
    assert float(jnp.abs(hs - hs0).max()) > 1e-4
