"""Attention: flash pallas/xla vs oracle; gradients; causality property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import given, settings, st

from repro.kernels.attention import (attention_xla, decode_attention_xla,
                                     flash_attention_pallas)
from repro.kernels.ref import attention_ref

settings.register_profile("fast", max_examples=10, deadline=None)
settings.load_profile("fast")

KEY = jax.random.key(0)


def qkv(b=2, hq=4, hkv=2, s=128, d=32, sk=None):
    sk = sk or s
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (b, hq, s, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (b, hkv, sk, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (b, hkv, sk, d))
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 32])
@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_flash_matches_ref(causal, window, impl):
    q, k, v = qkv()
    want = attention_ref(q, k, v, causal=causal, window=window)
    if impl == "pallas":
        got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                     bq=32, bk=32, interpret=True)
    else:
        got = attention_xla(q, k, v, causal=causal, window=window,
                            q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("hq,hkv", [(8, 8), (8, 2), (8, 1)])
def test_gqa_ratios(hq, hkv):
    q, k, v = qkv(hq=hq, hkv=hkv)
    want = attention_ref(q, k, v)
    got = attention_xla(q, k, v, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_gradients_match_ref():
    q, k, v = qkv(s=96, d=16)

    def loss(fn):
        return lambda q, k, v: (fn(q, k, v) ** 2).sum()

    gr = jax.grad(loss(lambda q, k, v: attention_ref(q, k, v, causal=True)),
                  argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(loss(lambda q, k, v: attention_xla(
        q, k, v, causal=True, q_chunk=32, kv_chunk=32)),
        argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gr, gf):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4,
                                   rtol=1e-3)


@given(st.integers(min_value=0, max_value=62))
def test_causality_property(t):
    """Output at position t is independent of tokens > t (the causal-mask
    invariant, checked by perturbing the future)."""
    q, k, v = qkv(b=1, hq=2, hkv=2, s=64, d=8)
    out1 = attention_xla(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    noise = jnp.zeros_like(k).at[:, :, t + 1:, :].set(99.0)
    out2 = attention_xla(q, k + noise, v + noise, causal=True, q_chunk=32,
                         kv_chunk=32)
    np.testing.assert_allclose(np.asarray(out1[:, :, :t + 1]),
                               np.asarray(out2[:, :, :t + 1]), atol=1e-5)


def test_decode_matches_ref():
    q, k, v = qkv(s=1, sk=128)
    kv_len = jnp.array([57, 128])
    want = attention_ref(q, k, v, causal=False, kv_len=kv_len)
    got = decode_attention_xla(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_decode_window():
    q, k, v = qkv(s=1, sk=128)
    kv_len = jnp.array([100, 128])
    want = attention_ref(q, k, v, causal=False, kv_len=kv_len, window=None)
    # windowed decode only sees the last W entries
    got_w = decode_attention_xla(q, k, v, kv_len, window=16)
    ref_w = attention_ref(
        q, jnp.where(jnp.arange(128)[None, None, :, None]
                     < (kv_len - 1 - 16)[:, None, None, None], -1e9, k),
        v, causal=False, kv_len=kv_len)
    assert np.abs(np.asarray(got_w) - np.asarray(want)).max() > 1e-3
