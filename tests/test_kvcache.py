"""Paged KV-cache subsystem: BlockAllocator semantics (including a
stateful property test), paged-vs-dense engine equivalence, bucketed
prefill, and paged-kernel-vs-reference numerics for both the decode and
the chunked-prefill kernels."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.kernels import ops
from repro.kernels.paged_attention import (paged_decode_attention_pallas,
                                           paged_decode_attention_xla,
                                           paged_prefill_attention_pallas,
                                           paged_prefill_attention_xla)
from repro.kernels.ref import paged_prefill_attention_ref
from repro.models import build_model
from repro.serving import (BlockAllocator, Request, ServeEngine,
                           blocks_needed, prefix_chain_keys)

from helpers import (HAS_HYPOTHESIS, RuleBasedStateMachine, invariant,
                     precondition, rule, run_state_machine_as_test,
                     settings, st)

CACHE_LEN = 64
BLOCK = 16


@pytest.fixture(scope="module")
def model_and_params():
    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _engine(model_and_params, **kw):
    _, model, params = model_and_params
    kw.setdefault("cache_len", CACHE_LEN)
    return ServeEngine(model, params, **kw)


# ---------------------------------------------------------------------------
# BlockAllocator.
# ---------------------------------------------------------------------------

def test_allocator_null_block_reserved():
    a = BlockAllocator(8, BLOCK)
    assert a.capacity == 7
    ids = a.alloc_n(7)
    assert 0 not in ids                 # null block is never handed out
    assert sorted(ids) == list(range(1, 8))


def test_allocator_reuse_is_lifo():
    a = BlockAllocator(8, BLOCK)
    b1, b2, b3 = a.alloc(), a.alloc(), a.alloc()
    assert (b1, b2, b3) == (1, 2, 3)    # fresh pool hands out in order
    a.free([b2])
    assert a.alloc() == b2              # most recently freed reused first
    assert a.alloc() == 4               # then the untouched tail


def test_allocator_exhaustion_and_atomic_alloc_n():
    a = BlockAllocator(4, BLOCK)
    a.alloc_n(2)
    free_before = a.n_free
    with pytest.raises(MemoryError):
        a.alloc_n(2)                    # only 1 free: all-or-nothing
    assert a.n_free == free_before
    a.alloc()
    with pytest.raises(MemoryError):
        a.alloc()


def test_allocator_free_validates_and_reset():
    a = BlockAllocator(4, BLOCK)
    blk = a.alloc()
    a.free([blk])
    with pytest.raises(ValueError):
        a.free([blk])                   # double free
    with pytest.raises(ValueError):
        a.free([0])                     # null block was never live
    a.alloc_n(3)
    a.reset()
    assert a.n_free == a.capacity == 3 and a.n_live == 0


def test_allocator_stats_track_peak():
    a = BlockAllocator(5, BLOCK)
    ids = a.alloc_n(3)
    a.free(ids[:2])
    s = a.stats()
    assert (s.n_live, s.peak_live) == (1, 3)
    assert s.utilization == pytest.approx(1 / 4)
    assert s.peak_utilization == pytest.approx(3 / 4)
    a.reset_peak()
    assert a.stats().peak_live == 1


def test_allocator_owner_accounting():
    """Shared-pool bookkeeping: live block references are tagged with the
    owner that drew them (a cluster's replica index), and ``free``
    validates the caller actually holds the reference it drops."""
    a = BlockAllocator(8, BLOCK)
    xs = a.alloc_n(2, owner="r0")
    y = a.alloc(owner="r1")
    assert a.live_by_owner() == {"r0": 2, "r1": 1}
    assert a.owner_of(y) == "r1"
    with pytest.raises(ValueError, match="owner"):
        a.free(xs, owner="r1")          # r1 holds no reference on xs
    assert a.live_by_owner() == {"r0": 2, "r1": 1}
    a.free(xs, owner="r0")
    assert a.live_by_owner() == {"r1": 1}
    a.free([y], owner="r1")
    assert a.live_by_owner() == {}


def test_allocator_reservations():
    """Pool-level worst-case promises: n_avail shrinks, over-reserving and
    over-unreserving are rejected."""
    a = BlockAllocator(6, BLOCK)            # capacity 5
    a.reserve(3)
    assert (a.n_reserved, a.n_avail, a.n_free) == (3, 2, 5)
    with pytest.raises(MemoryError):
        a.reserve(3)                        # only 2 unreserved-free
    a.unreserve(1)
    assert a.n_avail == 3
    with pytest.raises(ValueError):
        a.unreserve(5)
    assert a.stats().n_reserved == 2
    a.reset()
    assert a.n_reserved == 0


def test_blocks_needed():
    assert blocks_needed(0, 16) == 0
    assert blocks_needed(1, 16) == 1
    assert blocks_needed(16, 16) == 1
    assert blocks_needed(17, 16) == 2


def test_alloc_gates_on_avail_not_free():
    """Regression (reservation starvation): an allocation without a
    matching reservation must gate on ``n_avail``, never raw ``n_free`` —
    before the fix an atomic admission could consume blocks promised to
    another request's lazy growth, making the promised growth fail."""
    a = BlockAllocator(6, BLOCK)            # capacity 5
    a.reserve(3)                            # another request's promise
    a.alloc()                               # 2 unreserved-free: fine
    a.alloc()
    assert (a.n_free, a.n_avail) == (3, 0)
    with pytest.raises(MemoryError):
        a.alloc()                           # would eat a promised block
    free_before = a.n_free
    with pytest.raises(MemoryError):
        a.alloc_n(1)                        # same hole via alloc_n
    assert a.n_free == free_before          # and it mutated nothing
    # the promise holder itself draws *from* the reservation: always
    # succeeds, and retires the promise atomically with the grant
    for want in (2, 1, 0):
        a.alloc(from_reservation=True)
        assert a.n_reserved == want
    assert a.n_free == 0


def test_alloc_n_from_reservation():
    a = BlockAllocator(6, BLOCK)
    a.reserve(4)
    with pytest.raises(MemoryError):
        a.alloc_n(2)                        # 1 unreserved-free only
    ids = a.alloc_n(4, from_reservation=True)
    assert len(ids) == 4 and a.n_reserved == 0


def test_free_is_atomic():
    """Regression (partial free): a ``free`` whose list fails validation
    mid-way must leave the pool exactly as it was — before the fix the
    blocks ahead of the bad entry were already freed when the ValueError
    raised, leaving the pool half-mutated."""
    a = BlockAllocator(6, BLOCK)
    b1, b2, b3 = a.alloc_n(3)
    with pytest.raises(ValueError):
        a.free([b1, b2, 999, b3])           # 999 was never live
    assert a.n_live == 3                    # b1/b2 NOT freed by the reject
    with pytest.raises(ValueError):
        a.free([b1, b1])                    # one reference, listed twice
    assert a.n_live == 3
    a.free([b1, b2, b3])                    # the valid list still works
    assert a.n_live == 0 and a.n_free == a.capacity


# ---------------------------------------------------------------------------
# Prefix index: chain keys, refcounted sharing, cached LRU.
# ---------------------------------------------------------------------------

def test_prefix_chain_keys_exact():
    ks = prefix_chain_keys([1, 2, 3, 4, 5], 2)
    assert len(ks) == 2                     # full spans only
    assert ks[0] == (None, (1, 2))
    assert ks[1] == ((None, (1, 2)), (3, 4))
    # same span, different prefix -> different key (chained identity)
    other = prefix_chain_keys([9, 9, 3, 4], 2)
    assert other[1] != ks[1]
    assert prefix_chain_keys([1], 2) == []


def test_prefix_register_lookup_and_writer_scope():
    a = BlockAllocator(8, BLOCK)
    blk = a.alloc(owner="r0")
    key = ("k", 0)
    a.register(key, blk, owner="r0")
    assert a.lookup(key, owner="r0") == blk
    # entries are writer-scoped: another replica's device pool does not
    # hold these bytes, so its lookup must miss
    assert a.lookup(key, owner="r1") is None
    assert a.lookup(("k", 1), owner="r0") is None
    with pytest.raises(ValueError):
        a.register(("k", 2), 999)           # never live


def test_prefix_refcount_sharing():
    a = BlockAllocator(8, BLOCK)
    blk = a.alloc(owner="r0")
    a.incref(blk, owner="r0")               # second request, same replica
    assert a.refcount(blk) == 2
    a.free([blk], owner="r0")
    assert a.refcount(blk) == 1 and a.n_live == 1
    a.free([blk], owner="r0")
    assert a.refcount(blk) == 0 and a.n_free == a.capacity
    with pytest.raises(ValueError):
        a.incref(blk)                       # not live any more


def test_cached_block_lifecycle():
    """A registered block whose last reference drops parks in the cached
    LRU: still indexed (hits revive it), still counted free, evicted
    LRU-first only when the raw free list runs dry."""
    a = BlockAllocator(5, BLOCK)            # capacity 4
    b1 = a.alloc()
    a.register(("k", 1), b1)
    a.free([b1])
    assert a.is_cached(b1) and a.n_cached == 1
    assert a.n_free == a.capacity           # cached blocks stay allocatable
    assert a.lookup(("k", 1)) == b1
    a.take_cached(b1)                       # hit revives it
    assert a.refcount(b1) == 1 and a.n_cached == 0
    a.free([b1])                            # parks again
    # eviction order: raw free list first, cached LRU-last
    got = [a.alloc() for _ in range(3)]
    assert b1 not in got
    assert a.alloc() == b1                  # free list dry: evicts cached
    assert a.lookup(("k", 1)) is None       # eviction dropped the entry


def test_cached_lru_eviction_order():
    a = BlockAllocator(5, BLOCK)
    b1, b2 = a.alloc(), a.alloc()
    a.register(("k", 1), b1)
    a.register(("k", 2), b2)
    a.free([b1])                            # older cached entry
    a.free([b2])
    a.alloc_n(2)                            # drain the raw free list
    assert a.alloc() == b1                  # LRU-first eviction
    assert a.lookup(("k", 1)) is None
    assert a.lookup(("k", 2)) == b2         # newer entry survives


def test_register_supersede_last_writer_wins():
    a = BlockAllocator(8, BLOCK)
    b1, b2 = a.alloc(), a.alloc()
    key = ("k", 0)
    a.register(key, b1)
    a.free([b1])                            # b1 parks cached under key
    a.register(key, b2)                     # a fresh writer supersedes
    assert a.lookup(key) == b2
    assert not a.is_cached(b1)              # superseded cached copy is
    assert a.n_cached == 0                  # a plain free block again
    a.check_integrity()


def test_take_cached_gating_and_flush():
    a = BlockAllocator(4, BLOCK)            # capacity 3
    b1 = a.alloc()
    a.register(("k", 1), b1)
    a.free([b1])
    a.reserve(3)                            # everything promised away
    with pytest.raises(MemoryError):
        a.take_cached(b1)                   # revival spends n_avail
    a.take_cached(b1, from_reservation=True)
    assert a.refcount(b1) == 1 and a.n_reserved == 2
    a.unreserve(2)
    a.free([b1])
    assert a.n_cached == 1
    assert a.flush_index() == 1             # index torn down: cached
    assert a.n_cached == 0                  # blocks rejoin the free list
    assert a.n_free == a.capacity
    a.check_integrity()


def test_flush_index_per_owner():
    a = BlockAllocator(8, BLOCK)
    b1 = a.alloc(owner="r0")
    b2 = a.alloc(owner="r1")
    a.register(("k", 1), b1, owner="r0")
    a.register(("k", 2), b2, owner="r1")
    assert a.flush_index("r0") == 1
    assert a.lookup(("k", 1), owner="r0") is None
    assert a.lookup(("k", 2), owner="r1") == b2
    a.free([b1], owner="r0")
    a.free([b2], owner="r1")


# ---------------------------------------------------------------------------
# Stateful allocator property: random alloc/grow/free/reserve/share/
# register sequences must conserve blocks, never double-hand-out or
# double-free, keep owner and refcount accounting exact (sum(refs) >=
# n_live; a non-holder cannot free), keep cached blocks allocatable, and
# drain the pool fully free at teardown.  The hypothesis
# RuleBasedStateMachine explores+shrinks sequences in CI; the seeded
# random walk keeps the same coverage when hypothesis is absent.
# ---------------------------------------------------------------------------

_MACHINE_BLOCKS = 9          # 8 allocatable + null
_OWNERS = ["r0", "r1"]


class AllocatorMachine(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        self.a = BlockAllocator(_MACHINE_BLOCKS, BLOCK)
        # model: owner -> list of held references (a shared block appears
        # once per reference, possibly under both owners)
        self.held: dict = {o: [] for o in _OWNERS}
        self.reserved = 0
        self.next_key = 0

    def _distinct_held(self):
        return {b for ids in self.held.values() for b in ids}

    @rule(owner=st.sampled_from(_OWNERS))
    def alloc_one(self, owner):
        if self.a.n_avail:
            blk = self.a.alloc(owner)
            assert blk != 0, "null block handed out"
            assert blk not in self._distinct_held(), \
                f"block {blk} handed out twice"
            self.held[owner].append(blk)
        else:
            # raw free blocks may remain, but they are spoken for:
            # an unreserved allocation must not eat them
            with pytest.raises(MemoryError):
                self.a.alloc(owner)

    @rule(owner=st.sampled_from(_OWNERS))
    def alloc_from_reservation(self, owner):
        if self.reserved:
            blk = self.a.alloc(owner, from_reservation=True)
            assert blk not in self._distinct_held()
            self.held[owner].append(blk)
            self.reserved -= 1          # the grant retired one promise

    @rule(n=st.integers(0, 4), owner=st.sampled_from(_OWNERS))
    def alloc_many(self, n, owner):
        free_before = self.a.n_free
        if n <= self.a.n_avail:
            ids = self.a.alloc_n(n, owner)
            assert len(set(ids)) == n and 0 not in ids
            self.held[owner].extend(ids)
        else:
            with pytest.raises(MemoryError):
                self.a.alloc_n(n, owner)
            assert self.a.n_free == free_before    # all-or-nothing

    @rule(k=st.integers(0, 3), owner=st.sampled_from(_OWNERS))
    def free_some(self, k, owner):
        ids, keep = self.held[owner][:k], self.held[owner][k:]
        self.a.free(ids, owner)
        self.held[owner] = keep

    @rule(i=st.integers(0, 7), owner=st.sampled_from(_OWNERS))
    def incref_shared(self, i, owner):
        """A prefix hit on a live block: any owner may add a reference."""
        live = sorted(self._distinct_held())
        if live:
            blk = live[i % len(live)]
            self.a.incref(blk, owner)
            self.held[owner].append(blk)

    @rule(i=st.integers(0, 7), owner=st.sampled_from(_OWNERS))
    def register_one(self, i, owner):
        """Publish a held block under a fresh chain key (the prefix index
        itself is exercised by the unit tests; here it matters because a
        registered block parks in the cached LRU instead of the free list
        when its last reference drops — conservation must hold anyway)."""
        ids = self.held[owner]
        if ids:
            self.a.register(("k", self.next_key), ids[i % len(ids)], owner)
            self.next_key += 1

    @rule(i=st.integers(0, 7), owner=st.sampled_from(_OWNERS))
    def revive_cached(self, i, owner):
        """A prefix hit on a cached (refcount-0) block revives it; the
        revival spends an allocatable block so it gates like alloc."""
        cached = sorted(b for b in range(1, _MACHINE_BLOCKS)
                        if self.a.is_cached(b))
        if not cached:
            return
        blk = cached[i % len(cached)]
        if self.a.n_avail:
            self.a.take_cached(blk, owner)
            self.held[owner].append(blk)
        else:
            with pytest.raises(MemoryError):
                self.a.take_cached(blk, owner)

    @rule()
    def double_free_rejected(self):
        ids = self.held["r0"]
        if ids:
            blk = ids.pop()
            before = self.a.refcount(blk)
            self.a.free([blk], "r0")
            if blk not in self._distinct_held() and before == 1:
                with pytest.raises(ValueError):
                    self.a.free([blk], "r0")

    @rule()
    def non_holder_free_rejected(self):
        """Only an owner holding a reference may drop one — and the
        rejected call must not mutate the pool (atomicity)."""
        only_r0 = [b for b in self.held["r0"] if b not in self.held["r1"]]
        if only_r0:
            live_before = self.a.n_live
            with pytest.raises(ValueError):
                self.a.free([only_r0[0]], "r1")
            assert self.a.n_live == live_before

    @rule(n=st.integers(0, 4))
    def reserve_some(self, n):
        if n <= self.a.n_avail:
            self.a.reserve(n)
            self.reserved += n
        else:
            with pytest.raises(MemoryError):
                self.a.reserve(n)

    @rule(n=st.integers(0, 4))
    def unreserve_some(self, n):
        if n <= self.reserved:
            self.a.unreserve(n)
            self.reserved -= n
        else:
            with pytest.raises(ValueError):
                self.a.unreserve(n)

    @rule()
    def flush_some_index(self):
        self.a.flush_index("r1")        # live refs unaffected by design

    @invariant()
    def conservation(self):
        distinct = self._distinct_held()
        refs = sum(len(ids) for ids in self.held.values())
        assert self.a.n_live == len(distinct)
        assert refs >= self.a.n_live            # sum(refs) >= n_live
        assert self.a.n_free + self.a.n_live == self.a.capacity
        assert self.a.n_cached <= self.a.n_free
        assert self.a.n_reserved == self.reserved
        assert self.a.n_avail == self.a.n_free - self.reserved
        by_owner = {o: len(ids) for o, ids in self.held.items() if ids}
        assert self.a.live_by_owner() == by_owner
        stats = self.a.stats()
        assert stats.peak_live >= self.a.n_live
        self.a.check_integrity()

    def teardown(self):
        for owner, ids in self.held.items():
            self.a.free(ids, owner)
        self.a.unreserve(self.reserved)
        self.a.flush_index()
        assert self.a.n_live == 0 and self.a.n_reserved == 0
        assert self.a.n_free == self.a.capacity
        assert self.a.n_cached == 0


def test_allocator_state_machine():
    run_state_machine_as_test(AllocatorMachine)


@pytest.mark.skipif(HAS_HYPOTHESIS,
                    reason="hypothesis runs the state machine instead")
@pytest.mark.parametrize("seed", range(8))
def test_allocator_random_walk(seed):
    """Seeded fallback for the stateful property when hypothesis is
    missing: drive the same rule set from a numpy PRNG."""
    rng = np.random.default_rng(seed)
    m = AllocatorMachine()
    own = lambda: _OWNERS[rng.integers(2)]          # noqa: E731
    rules = [lambda: m.alloc_one(own()),
             lambda: m.alloc_from_reservation(own()),
             lambda: m.alloc_many(int(rng.integers(0, 5)), own()),
             lambda: m.free_some(int(rng.integers(0, 4)), own()),
             lambda: m.incref_shared(int(rng.integers(0, 8)), own()),
             lambda: m.register_one(int(rng.integers(0, 8)), own()),
             lambda: m.revive_cached(int(rng.integers(0, 8)), own()),
             lambda: m.double_free_rejected(),
             lambda: m.non_holder_free_rejected(),
             lambda: m.reserve_some(int(rng.integers(0, 5))),
             lambda: m.unreserve_some(int(rng.integers(0, 5))),
             lambda: m.flush_some_index()]
    for _ in range(400):
        rules[rng.integers(len(rules))]()
        m.conservation()
    m.teardown()


def test_allocator_threaded_stress():
    """Multi-threaded variant of the rule machine: 4 owner threads hammer
    one shared allocator with alloc/free/reserve/register/lookup+revive
    for a few hundred ops each.  Per-op assertions are the ones that hold
    without a global lock (no double-handout, no null block); the full
    conservation + index invariants run after the join.  This is the
    contract the threaded cluster driver leans on — every replica worker
    mutates this object concurrently."""
    import threading

    a = BlockAllocator(48, BLOCK)
    handed = set()                       # blocks live anywhere, any owner
    handed_lock = threading.Lock()
    errors: list[BaseException] = []

    def claim(blk):
        with handed_lock:
            assert blk != 0, "null block handed out"
            assert blk not in handed, f"block {blk} handed out twice"
            handed.add(blk)

    def release(blk):
        with handed_lock:
            handed.discard(blk)

    def worker(owner: int):
        rng = np.random.default_rng(100 + owner)
        held: list[int] = []
        keys = 0
        try:
            for _ in range(400):
                op = int(rng.integers(0, 6))
                if op == 0:
                    try:
                        blk = a.alloc(owner)
                    except MemoryError:
                        pass
                    else:
                        claim(blk)
                        held.append(blk)
                elif op == 1 and held:
                    blk = held.pop(int(rng.integers(len(held))))
                    if held.count(blk) == 0:
                        release(blk)
                    a.free([blk], owner)
                elif op == 2 and held:
                    # a second reference from this owner (prefix hit on a
                    # block it already holds: incref never races free of
                    # the same ref because only this thread frees it)
                    blk = held[int(rng.integers(len(held)))]
                    a.incref(blk, owner)
                    held.append(blk)
                elif op == 3 and held:
                    # publish under an owner-namespaced key
                    blk = held[int(rng.integers(len(held)))]
                    a.register(("t", owner, keys), blk, owner)
                    keys += 1
                elif op == 4 and keys:
                    # the documented compound-atomic pattern: resolve +
                    # revive under the allocator's own lock
                    key = ("t", owner, int(rng.integers(keys)))
                    with a.lock:
                        blk = a.lookup(key, owner)
                        if (blk is not None and a.is_cached(blk)
                                and a.n_avail):
                            a.take_cached(blk, owner)
                            claim(blk)
                            held.append(blk)
                else:
                    try:
                        a.reserve(2)
                    except MemoryError:
                        pass
                    else:
                        a.unreserve(2)
        except BaseException as e:      # surfaced after the join
            errors.append(e)
        finally:
            for blk in set(held):
                release(blk)
            a.free(held, owner)

    threads = [threading.Thread(target=worker, args=(o,), daemon=True)
               for o in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive(), "allocator stress worker wedged"
    assert not errors, errors
    a.check_integrity()
    assert a.n_live == 0 and a.n_reserved == 0
    assert a.n_free == a.capacity
    assert a.live_by_owner() == {}
    a.flush_index()
    assert a.n_cached == 0


# ---------------------------------------------------------------------------
# Paged engine vs dense engine.
# ---------------------------------------------------------------------------

def test_paged_matches_dense_greedy(model_and_params):
    """Greedy tokens are identical across KV layouts, including slot reuse
    and block recycling (6 requests through 2 slots)."""
    reqs = [Request([1, 2, 3], 6, rid=0), Request([4, 5], 8, rid=1),
            Request([9, 8, 7, 6], 5, rid=2), Request([3], 7, rid=3),
            Request([5, 6, 7], 9, rid=4), Request([8, 9], 3, rid=5)]
    dense = _engine(model_and_params, max_batch=2).generate(reqs)
    peng = _engine(model_and_params, max_batch=2, kv_layout="paged",
                   block_size=BLOCK)
    paged = peng.generate(reqs)
    for d, p in zip(dense, paged):
        assert d.tokens == p.tokens, d.rid
    s = peng.last_stats
    assert s.kv_layout == "paged"
    assert 0.0 < s.block_util_peak <= 1.0


def test_paged_bucketed_matches_exact(model_and_params):
    """pow2 bucketing changes compile counts, not outputs (dense); the
    paged layout's chunked prefill is shape-invariant outright — one
    compiled (1, block_size) chunk covers every prompt, bucket or not."""
    reqs = [Request(list(range(1, 1 + n)), 5, rid=i)
            for i, n in enumerate([3, 5, 6, 7, 9, 11])]
    exact = _engine(model_and_params, max_batch=2).generate(reqs)
    for layout, compiles in (("dense", 3), ("paged", 1)):
        eng = _engine(model_and_params, max_batch=2, bucket="pow2",
                      kv_layout=layout, block_size=BLOCK)
        got = eng.generate(reqs)
        for e, g in zip(exact, got):
            assert e.tokens == g.tokens, (layout, e.rid)
        # dense: lengths 3..11 bucket to {4, 8, 16} = 3 compiles (not 6);
        # paged: a single chunk shape regardless of prompt lengths
        assert eng.last_stats.prefill_compiles == compiles, layout


def test_paged_admits_beyond_dense_reservation(model_and_params):
    """The paged pool is bounded by *live* blocks, not per-slot
    reservation: a trace whose summed KV footprint exceeds the pool (and
    the equivalent dense max_batch*cache_len) completes because finished
    requests recycle their blocks."""
    reqs = [Request([7 * i + 1, 7 * i + 2], 15, rid=i) for i in range(8)]
    # footprint: 8 requests * (2 + 14) = 128 positions through a pool of
    # 4 allocatable blocks = 64 positions (2 slots * cache_len 32)
    footprint = sum(len(r.prompt) + r.max_new_tokens - 1 for r in reqs)
    eng = _engine(model_and_params, max_batch=2, cache_len=32,
                  kv_layout="paged", block_size=BLOCK, n_blocks=5)
    assert footprint > eng.allocator.capacity * BLOCK
    res = eng.generate(reqs)
    assert [len(r.tokens) for r in res] == [r.max_new_tokens for r in reqs]
    dense = _engine(model_and_params, max_batch=2,
                    cache_len=32).generate(reqs)
    for d, p in zip(dense, res):
        assert d.tokens == p.tokens, d.rid


def test_paged_matches_dense_vlm_patch_prefix():
    """vlm paged prefill embeds the model-side patch prefix chunk by chunk
    (``_embed_chunk`` + the engine's zeroed prefix token feed) instead of
    reusing the dense prefill — outputs must still match the dense layout
    exactly, covering a chunk that straddles the patch/token seam
    (block 16 > n_patches 8), a prefix-only first chunk (block 8), and a
    partial trailing chunk."""
    cfg = smoke_config("phi-3-vision-4.2b")
    assert cfg.n_patches == 8
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    patches = jax.random.normal(
        jax.random.key(1), (3, cfg.n_patches, cfg.patch_embed_dim),
        jnp.float32)
    reqs = [Request([1, 2, 3], 6, rid=0),
            Request(list(range(9)), 5, rid=1),
            Request([7] * 17, 4, rid=2)]
    dense = ServeEngine(model, params, max_batch=2, cache_len=CACHE_LEN,
                        extra_inputs={"patches": patches}).generate(reqs)
    for bs in (16, 8):
        paged = ServeEngine(model, params, max_batch=2,
                            cache_len=CACHE_LEN, kv_layout="paged",
                            block_size=bs,
                            extra_inputs={"patches": patches}
                            ).generate(reqs)
        for d, p in zip(dense, paged):
            assert d.tokens == p.tokens, (bs, d.rid)


def test_paged_request_never_fits_rejected(model_and_params):
    """A request whose worst case exceeds the whole pool errors up front
    (before any scheduling), and the engine stays usable: no blocks or
    reservations leak from the rejected batch."""
    eng = _engine(model_and_params, max_batch=2, cache_len=64,
                  kv_layout="paged", block_size=BLOCK, n_blocks=3)
    fits = Request([1, 2, 3], 6, rid=0)
    with pytest.raises(ValueError, match="KV blocks"):
        # the admissible request rides in the same batch as the impossible
        # one: up-front validation must reject before either is scheduled
        eng.generate([fits, Request(list(range(10)), 40, rid=1)])
    assert eng.allocator.n_live == 0 and eng.allocator.n_reserved == 0
    res = eng.generate([fits])          # engine not wedged by the reject
    assert len(res[0].tokens) == fits.max_new_tokens


def test_paged_cache_len_budget_still_enforced(model_and_params):
    """cache_len stays the per-request context bound (block-table width)."""
    eng = _engine(model_and_params, max_batch=2, kv_layout="paged",
                  block_size=BLOCK)
    with pytest.raises(ValueError, match="cache positions"):
        eng.generate([Request(list(range(10)), CACHE_LEN, rid=0)])


def test_paged_requires_capable_family():
    cfg = smoke_config("xlstm-350m")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(model, params, max_batch=2, cache_len=32,
                    kv_layout="paged")


# ---------------------------------------------------------------------------
# Prefix cache: engine-level hit/COW/identity semantics.
# ---------------------------------------------------------------------------

_SHARED = list(range(1, 17))        # two full blocks at block_size=8


def _prefix_engines(model_and_params, **kw):
    cold = _engine(model_and_params, max_batch=2, kv_layout="paged",
                   block_size=8, **kw)
    warm = _engine(model_and_params, max_batch=2, kv_layout="paged",
                   block_size=8, prefix_cache=True, **kw)
    return cold, warm


def _assert_drained(a):
    a.check_integrity()
    assert a.n_live == 0 and a.n_reserved == 0
    assert a.n_free == a.capacity


def test_prefix_cache_hits_and_identity(model_and_params):
    """Shared-prefix admissions hit the index, skip prefill chunks, and
    emit tokens byte-identical to the cold path; the pool drains clean
    with the reused blocks parked in the cached LRU."""
    reqs = [Request(_SHARED + [20 + i], 6, rid=i) for i in range(3)]
    cold, warm = _prefix_engines(model_and_params)
    ref = cold.generate(reqs)
    got = warm.generate(reqs)
    for d, p in zip(ref, got):
        assert d.tokens == p.tokens, d.rid
    s = warm.last_stats
    assert s.prefix_hits > 0
    assert s.prefix_tokens_reused == s.prefix_hits * 8
    _assert_drained(warm.allocator)
    assert warm.allocator.n_cached > 0


def test_prefix_cache_survives_sessions(model_and_params):
    """Cached blocks (and their device-side bytes) outlive the session:
    a second ``generate`` hits the prefixes the first one registered."""
    cold, warm = _prefix_engines(model_and_params)
    warm.generate([Request(_SHARED + [40], 4, rid=0)])
    first_hits = warm.last_stats.prefix_hits
    got = warm.generate([Request(_SHARED + [41], 4, rid=1)])
    assert warm.last_stats.prefix_hits == 2     # both full blocks hit
    ref = cold.generate([Request(_SHARED + [41], 4, rid=1)])
    assert got[0].tokens == ref[0].tokens
    assert first_hits == 0                      # nothing resident at first
    _assert_drained(warm.allocator)


def test_prefix_cache_full_boundary_cow(model_and_params):
    """A prompt fully covered by hits re-runs only its final chunk (the
    first token needs its logits) behind a copy-on-write of the shared
    block — tokens still match the cold path, for a sole survivor and
    for two concurrent sharers of the same blocks."""
    cold, warm = _prefix_engines(model_and_params)
    warm.generate([Request(_SHARED + [40], 4, rid=0)])      # seed the index
    for reqs in ([Request(_SHARED, 5, rid=1)],
                 [Request(_SHARED, 5, rid=2),
                  Request(_SHARED, 5, rid=3)]):
        got = warm.generate(reqs)
        ref = cold.generate(reqs)
        for d, p in zip(ref, got):
            assert d.tokens == p.tokens, d.rid
        assert warm.last_stats.prefix_hits >= 2
        _assert_drained(warm.allocator)


def test_prefix_cache_overcommit_admission(model_and_params):
    """prefix_cache composes with overcommit admission (no reservations:
    hits and revivals spend n_avail directly)."""
    reqs = [Request(_SHARED + [50 + i], 5, rid=i) for i in range(4)]
    cold = _engine(model_and_params, max_batch=2, kv_layout="paged",
                   block_size=8).generate(reqs)
    warm = _engine(model_and_params, max_batch=2, kv_layout="paged",
                   block_size=8, prefix_cache=True, admission="overcommit")
    got = warm.generate(reqs)
    for d, p in zip(cold, got):
        assert d.tokens == p.tokens, d.rid
    assert warm.last_stats.prefix_hits > 0
    _assert_drained(warm.allocator)


def test_prefix_cache_abort_flushes_index(model_and_params):
    """``session_abort`` must leave the pool clean *and* drop this
    engine's index entries — an aborted session's device pool is torn
    down, so the registered bytes no longer exist to be hit."""
    _, warm = _prefix_engines(model_and_params)
    warm.generate([Request(_SHARED + [40], 4, rid=0)])
    assert warm.allocator.n_cached > 0          # prefixes are resident
    warm.begin_session()
    warm.session_admit(Request(_SHARED + [41], 4, rid=1), tag=0)
    warm.session_abort()
    assert warm.allocator.n_cached == 0         # abort flushed the index
    _assert_drained(warm.allocator)
    # the engine is not wedged: a fresh generate recomputes cold and
    # re-registers (no hits the first time around)
    got = warm.generate([Request(_SHARED + [42], 4, rid=2)])
    assert len(got[0].tokens) == 4
    assert warm.last_stats.prefix_hits == 0
    _assert_drained(warm.allocator)


def test_prefix_cache_requires_paged(model_and_params):
    _, model, params = model_and_params
    with pytest.raises(ValueError, match="prefix_cache"):
        ServeEngine(model, params, max_batch=2, cache_len=CACHE_LEN,
                    prefix_cache=True)


def test_prefix_cache_rejects_vlm():
    cfg = smoke_config("phi-3-vision-4.2b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    with pytest.raises(ValueError, match="vlm"):
        ServeEngine(model, params, max_batch=2, cache_len=CACHE_LEN,
                    kv_layout="paged", block_size=16, prefix_cache=True)


# ---------------------------------------------------------------------------
# Paged-attention kernel vs reference path.
# ---------------------------------------------------------------------------

def _rand_paged_case(key, *, n_blocks=9, hkv=2, bs=16, d=16, b=3, m=4, g=3):
    k1, k2, k3 = jax.random.split(key, 3)
    kp = jax.random.normal(k1, (n_blocks, hkv, bs, d), jnp.float32)
    vp = jax.random.normal(k2, (n_blocks, hkv, bs, d), jnp.float32)
    q = jax.random.normal(k3, (b, hkv * g, 1, d), jnp.float32)
    bt = jnp.asarray(
        np.array([[1, 2, 3, 4], [5, 6, 0, 0], [7, 8, 0, 0]]), jnp.int32)
    kv_len = jnp.asarray([64, 23, 17], jnp.int32)
    return q, kp, vp, bt, kv_len


def test_paged_kernel_matches_reference():
    q, kp, vp, bt, kv_len = _rand_paged_case(jax.random.key(1))
    ref = paged_decode_attention_xla(q, kp, vp, bt, kv_len)
    got = paged_decode_attention_pallas(q, kp, vp, bt, kv_len,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_partial_block_boundaries():
    """kv_len at and just past block boundaries (the masked tail of a
    block and a fully masked trailing block)."""
    q, kp, vp, bt, _ = _rand_paged_case(jax.random.key(2))
    for lens in ([16, 16, 16], [1, 32, 33], [48, 17, 1]):
        kv_len = jnp.asarray(lens, jnp.int32)
        ref = paged_decode_attention_xla(q, kp, vp, bt, kv_len)
        got = paged_decode_attention_pallas(q, kp, vp, bt, kv_len,
                                            interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5, err_msg=str(lens))


def test_paged_kernel_ignores_garbage_past_kv_len():
    """Entries at or past kv_len must not leak into the output, whatever
    the trailing block-table ids point at."""
    q, kp, vp, bt, kv_len = _rand_paged_case(jax.random.key(3))
    ref = paged_decode_attention_xla(q, kp, vp, bt, kv_len)
    kp2 = kp.at[0].set(1e6)             # null block: rows 1/2 padding
    vp2 = vp.at[0].set(-1e6)
    ref2 = paged_decode_attention_xla(q, kp2, vp2, bt, kv_len)
    np.testing.assert_allclose(np.asarray(ref2[1:]), np.asarray(ref[1:]),
                               rtol=1e-6, atol=1e-6)
    got2 = paged_decode_attention_pallas(q, kp2, vp2, bt, kv_len,
                                         interpret=True)
    np.testing.assert_allclose(np.asarray(got2[1:]), np.asarray(ref[1:]),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_via_ops_dispatch():
    q, kp, vp, bt, kv_len = _rand_paged_case(jax.random.key(4))
    ref = ops.paged_decode_attention(q, kp, vp, bt, kv_len, impl="xla")
    got = ops.paged_decode_attention(q, kp, vp, bt, kv_len,
                                     impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # sliding windows ride the gather/reference path in every impl
    win = ops.paged_decode_attention(q, kp, vp, bt, kv_len,
                                     impl="interpret", window=8)
    winref = paged_decode_attention_xla(q, kp, vp, bt, kv_len, window=8)
    np.testing.assert_allclose(np.asarray(win), np.asarray(winref),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Paged chunked-prefill kernel vs reference paths.
# ---------------------------------------------------------------------------

def _rand_prefill_case(key, *, n_blocks=9, hkv=2, bs=8, d=16, b=3, m=4,
                       g=2):
    k1, k2, k3 = jax.random.split(key, 3)
    kp = jax.random.normal(k1, (n_blocks, hkv, bs, d), jnp.float32)
    vp = jax.random.normal(k2, (n_blocks, hkv, bs, d), jnp.float32)
    q = jax.random.normal(k3, (b, hkv * g, bs, d), jnp.float32)
    bt = jnp.asarray(
        np.array([[1, 2, 3, 4], [5, 6, 0, 0], [7, 8, 0, 0]]), jnp.int32)
    # rows sit at chunks 3, 1, 0: causal frontiers mid-table, early, first
    q_start = jnp.asarray([24, 8, 0], jnp.int32)
    return q, kp, vp, bt, q_start


def test_paged_prefill_kernel_matches_reference():
    q, kp, vp, bt, qs = _rand_prefill_case(jax.random.key(5))
    ref = paged_prefill_attention_ref(q, kp, vp, bt, qs)
    xla = paged_prefill_attention_xla(q, kp, vp, bt, qs)
    pal = paged_prefill_attention_pallas(q, kp, vp, bt, qs, interpret=True)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_paged_prefill_kernel_chunk_positions():
    """Every chunk index, including the first (block 0 must always
    contribute — the online softmax init relies on it) and the last
    (frontier at the table's end)."""
    q, kp, vp, bt, _ = _rand_prefill_case(jax.random.key(6))
    for starts in ([0, 0, 0], [8, 16, 24], [24, 24, 24]):
        qs = jnp.asarray(starts, jnp.int32)
        ref = paged_prefill_attention_ref(q, kp, vp, bt, qs)
        xla = paged_prefill_attention_xla(q, kp, vp, bt, qs)
        pal = paged_prefill_attention_pallas(q, kp, vp, bt, qs,
                                             interpret=True)
        np.testing.assert_allclose(np.asarray(xla), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5, err_msg=str(starts))
        np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5, err_msg=str(starts))


def test_paged_prefill_kernel_ignores_blocks_past_frontier():
    """Blocks beyond a chunk's causal frontier must not leak into the
    output whatever their table entries point at (the engine leaves
    trailing entries on the null block)."""
    q, kp, vp, bt, qs = _rand_prefill_case(jax.random.key(7))
    ref = paged_prefill_attention_ref(q, kp, vp, bt, qs)
    kp2 = kp.at[0].set(1e6)            # null block: rows 1/2 trailing ids
    vp2 = vp.at[0].set(-1e6)
    for fn in (paged_prefill_attention_xla,
               lambda *a: paged_prefill_attention_pallas(*a,
                                                         interpret=True)):
        got = fn(q, kp2, vp2, bt, qs)
        np.testing.assert_allclose(np.asarray(got[1:]), np.asarray(ref[1:]),
                                   rtol=1e-5, atol=1e-5)


def test_paged_prefill_kernel_via_ops_dispatch():
    q, kp, vp, bt, qs = _rand_prefill_case(jax.random.key(8))
    ref = ops.paged_prefill_attention(q, kp, vp, bt, qs, impl="xla")
    got = ops.paged_prefill_attention(q, kp, vp, bt, qs, impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # sliding windows ride the per-block gather path in every impl
    win = ops.paged_prefill_attention(q, kp, vp, bt, qs, impl="interpret",
                                      window=5)
    winref = paged_prefill_attention_ref(q, kp, vp, bt, qs, window=5)
    np.testing.assert_allclose(np.asarray(win), np.asarray(winref),
                               rtol=1e-5, atol=1e-5)
