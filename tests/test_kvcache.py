"""Paged KV-cache subsystem: BlockAllocator semantics (including a
stateful property test), paged-vs-dense engine equivalence, bucketed
prefill, and paged-kernel-vs-reference numerics for both the decode and
the chunked-prefill kernels."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.kernels import ops
from repro.kernels.paged_attention import (paged_decode_attention_pallas,
                                           paged_decode_attention_xla,
                                           paged_prefill_attention_pallas,
                                           paged_prefill_attention_xla)
from repro.kernels.ref import paged_prefill_attention_ref
from repro.models import build_model
from repro.serving import BlockAllocator, Request, ServeEngine, blocks_needed

from helpers import (HAS_HYPOTHESIS, RuleBasedStateMachine, invariant,
                     precondition, rule, run_state_machine_as_test,
                     settings, st)

CACHE_LEN = 64
BLOCK = 16


@pytest.fixture(scope="module")
def model_and_params():
    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _engine(model_and_params, **kw):
    _, model, params = model_and_params
    kw.setdefault("cache_len", CACHE_LEN)
    return ServeEngine(model, params, **kw)


# ---------------------------------------------------------------------------
# BlockAllocator.
# ---------------------------------------------------------------------------

def test_allocator_null_block_reserved():
    a = BlockAllocator(8, BLOCK)
    assert a.capacity == 7
    ids = a.alloc_n(7)
    assert 0 not in ids                 # null block is never handed out
    assert sorted(ids) == list(range(1, 8))


def test_allocator_reuse_is_lifo():
    a = BlockAllocator(8, BLOCK)
    b1, b2, b3 = a.alloc(), a.alloc(), a.alloc()
    assert (b1, b2, b3) == (1, 2, 3)    # fresh pool hands out in order
    a.free([b2])
    assert a.alloc() == b2              # most recently freed reused first
    assert a.alloc() == 4               # then the untouched tail


def test_allocator_exhaustion_and_atomic_alloc_n():
    a = BlockAllocator(4, BLOCK)
    a.alloc_n(2)
    free_before = a.n_free
    with pytest.raises(MemoryError):
        a.alloc_n(2)                    # only 1 free: all-or-nothing
    assert a.n_free == free_before
    a.alloc()
    with pytest.raises(MemoryError):
        a.alloc()


def test_allocator_free_validates_and_reset():
    a = BlockAllocator(4, BLOCK)
    blk = a.alloc()
    a.free([blk])
    with pytest.raises(ValueError):
        a.free([blk])                   # double free
    with pytest.raises(ValueError):
        a.free([0])                     # null block was never live
    a.alloc_n(3)
    a.reset()
    assert a.n_free == a.capacity == 3 and a.n_live == 0


def test_allocator_stats_track_peak():
    a = BlockAllocator(5, BLOCK)
    ids = a.alloc_n(3)
    a.free(ids[:2])
    s = a.stats()
    assert (s.n_live, s.peak_live) == (1, 3)
    assert s.utilization == pytest.approx(1 / 4)
    assert s.peak_utilization == pytest.approx(3 / 4)
    a.reset_peak()
    assert a.stats().peak_live == 1


def test_allocator_owner_accounting():
    """Shared-pool bookkeeping: live blocks are tagged with the owner that
    drew them (a cluster's replica index)."""
    a = BlockAllocator(8, BLOCK)
    xs = a.alloc_n(2, owner="r0")
    y = a.alloc(owner="r1")
    assert a.live_by_owner() == {"r0": 2, "r1": 1}
    assert a.owner_of(y) == "r1"
    a.free(xs)
    assert a.live_by_owner() == {"r1": 1}
    a.free([y])
    assert a.live_by_owner() == {}


def test_allocator_reservations():
    """Pool-level worst-case promises: n_avail shrinks, over-reserving and
    over-unreserving are rejected."""
    a = BlockAllocator(6, BLOCK)            # capacity 5
    a.reserve(3)
    assert (a.n_reserved, a.n_avail, a.n_free) == (3, 2, 5)
    with pytest.raises(MemoryError):
        a.reserve(3)                        # only 2 unreserved-free
    a.unreserve(1)
    assert a.n_avail == 3
    with pytest.raises(ValueError):
        a.unreserve(5)
    assert a.stats().n_reserved == 2
    a.reset()
    assert a.n_reserved == 0


def test_blocks_needed():
    assert blocks_needed(0, 16) == 0
    assert blocks_needed(1, 16) == 1
    assert blocks_needed(16, 16) == 1
    assert blocks_needed(17, 16) == 2


# ---------------------------------------------------------------------------
# Stateful allocator property: random alloc/grow/free/reserve sequences
# must conserve blocks, never double-hand-out or double-free, keep owner
# accounting exact, and leave the pool fully free at teardown.  The
# hypothesis RuleBasedStateMachine explores+shrinks sequences in CI; the
# seeded random walk keeps the same coverage when hypothesis is absent.
# ---------------------------------------------------------------------------

_MACHINE_BLOCKS = 9          # 8 allocatable + null


class AllocatorMachine(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        self.a = BlockAllocator(_MACHINE_BLOCKS, BLOCK)
        self.held: dict = {"r0": [], "r1": []}    # model: owner -> ids
        self.reserved = 0

    @rule(owner=st.sampled_from(["r0", "r1"]))
    def alloc_one(self, owner):
        if self.a.n_free:
            blk = self.a.alloc(owner)
            assert blk != 0, "null block handed out"
            assert all(blk not in ids for ids in self.held.values()), \
                f"block {blk} handed out twice"
            self.held[owner].append(blk)
        else:
            with pytest.raises(MemoryError):
                self.a.alloc(owner)

    @rule(n=st.integers(0, 4), owner=st.sampled_from(["r0", "r1"]))
    def alloc_many(self, n, owner):
        free_before = self.a.n_free
        if n <= free_before:
            ids = self.a.alloc_n(n, owner)
            assert len(set(ids)) == n and 0 not in ids
            self.held[owner].extend(ids)
        else:
            with pytest.raises(MemoryError):
                self.a.alloc_n(n, owner)
            assert self.a.n_free == free_before    # all-or-nothing

    @rule(k=st.integers(0, 3), owner=st.sampled_from(["r0", "r1"]))
    def free_some(self, k, owner):
        ids, keep = self.held[owner][:k], self.held[owner][k:]
        self.a.free(ids)
        self.held[owner] = keep

    @rule()
    def double_free_rejected(self):
        ids = self.held["r0"]
        if ids:
            blk = ids.pop()
            self.a.free([blk])
            with pytest.raises(ValueError):
                self.a.free([blk])

    @rule(n=st.integers(0, 4))
    def reserve_some(self, n):
        if n <= self.a.n_avail:
            self.a.reserve(n)
            self.reserved += n
        else:
            with pytest.raises(MemoryError):
                self.a.reserve(n)

    @rule(n=st.integers(0, 4))
    def unreserve_some(self, n):
        if n <= self.reserved:
            self.a.unreserve(n)
            self.reserved -= n
        else:
            with pytest.raises(ValueError):
                self.a.unreserve(n)

    @invariant()
    def conservation(self):
        held = sum(len(ids) for ids in self.held.values())
        assert self.a.n_live == held
        assert self.a.n_free + self.a.n_live == self.a.capacity
        assert self.a.n_reserved == self.reserved
        assert self.a.n_avail == self.a.n_free - self.reserved
        by_owner = {o: len(ids) for o, ids in self.held.items() if ids}
        assert self.a.live_by_owner() == by_owner
        stats = self.a.stats()
        assert stats.peak_live >= self.a.n_live

    def teardown(self):
        for ids in self.held.values():
            self.a.free(ids)
        self.a.unreserve(self.reserved)
        assert self.a.n_live == 0 and self.a.n_reserved == 0
        assert self.a.n_free == self.a.capacity


def test_allocator_state_machine():
    run_state_machine_as_test(AllocatorMachine)


@pytest.mark.skipif(HAS_HYPOTHESIS,
                    reason="hypothesis runs the state machine instead")
@pytest.mark.parametrize("seed", range(8))
def test_allocator_random_walk(seed):
    """Seeded fallback for the stateful property when hypothesis is
    missing: drive the same rule set from a numpy PRNG."""
    rng = np.random.default_rng(seed)
    m = AllocatorMachine()
    rules = [lambda: m.alloc_one(["r0", "r1"][rng.integers(2)]),
             lambda: m.alloc_many(int(rng.integers(0, 5)),
                                  ["r0", "r1"][rng.integers(2)]),
             lambda: m.free_some(int(rng.integers(0, 4)),
                                 ["r0", "r1"][rng.integers(2)]),
             lambda: m.double_free_rejected(),
             lambda: m.reserve_some(int(rng.integers(0, 5))),
             lambda: m.unreserve_some(int(rng.integers(0, 5)))]
    for _ in range(300):
        rules[rng.integers(len(rules))]()
        m.conservation()
    m.teardown()


# ---------------------------------------------------------------------------
# Paged engine vs dense engine.
# ---------------------------------------------------------------------------

def test_paged_matches_dense_greedy(model_and_params):
    """Greedy tokens are identical across KV layouts, including slot reuse
    and block recycling (6 requests through 2 slots)."""
    reqs = [Request([1, 2, 3], 6, rid=0), Request([4, 5], 8, rid=1),
            Request([9, 8, 7, 6], 5, rid=2), Request([3], 7, rid=3),
            Request([5, 6, 7], 9, rid=4), Request([8, 9], 3, rid=5)]
    dense = _engine(model_and_params, max_batch=2).generate(reqs)
    peng = _engine(model_and_params, max_batch=2, kv_layout="paged",
                   block_size=BLOCK)
    paged = peng.generate(reqs)
    for d, p in zip(dense, paged):
        assert d.tokens == p.tokens, d.rid
    s = peng.last_stats
    assert s.kv_layout == "paged"
    assert 0.0 < s.block_util_peak <= 1.0


def test_paged_bucketed_matches_exact(model_and_params):
    """pow2 bucketing changes compile counts, not outputs (dense); the
    paged layout's chunked prefill is shape-invariant outright — one
    compiled (1, block_size) chunk covers every prompt, bucket or not."""
    reqs = [Request(list(range(1, 1 + n)), 5, rid=i)
            for i, n in enumerate([3, 5, 6, 7, 9, 11])]
    exact = _engine(model_and_params, max_batch=2).generate(reqs)
    for layout, compiles in (("dense", 3), ("paged", 1)):
        eng = _engine(model_and_params, max_batch=2, bucket="pow2",
                      kv_layout=layout, block_size=BLOCK)
        got = eng.generate(reqs)
        for e, g in zip(exact, got):
            assert e.tokens == g.tokens, (layout, e.rid)
        # dense: lengths 3..11 bucket to {4, 8, 16} = 3 compiles (not 6);
        # paged: a single chunk shape regardless of prompt lengths
        assert eng.last_stats.prefill_compiles == compiles, layout


def test_paged_admits_beyond_dense_reservation(model_and_params):
    """The paged pool is bounded by *live* blocks, not per-slot
    reservation: a trace whose summed KV footprint exceeds the pool (and
    the equivalent dense max_batch*cache_len) completes because finished
    requests recycle their blocks."""
    reqs = [Request([7 * i + 1, 7 * i + 2], 15, rid=i) for i in range(8)]
    # footprint: 8 requests * (2 + 14) = 128 positions through a pool of
    # 4 allocatable blocks = 64 positions (2 slots * cache_len 32)
    footprint = sum(len(r.prompt) + r.max_new_tokens - 1 for r in reqs)
    eng = _engine(model_and_params, max_batch=2, cache_len=32,
                  kv_layout="paged", block_size=BLOCK, n_blocks=5)
    assert footprint > eng.allocator.capacity * BLOCK
    res = eng.generate(reqs)
    assert [len(r.tokens) for r in res] == [r.max_new_tokens for r in reqs]
    dense = _engine(model_and_params, max_batch=2,
                    cache_len=32).generate(reqs)
    for d, p in zip(dense, res):
        assert d.tokens == p.tokens, d.rid


def test_paged_matches_dense_vlm_patch_prefix():
    """vlm paged prefill embeds the model-side patch prefix chunk by chunk
    (``_embed_chunk`` + the engine's zeroed prefix token feed) instead of
    reusing the dense prefill — outputs must still match the dense layout
    exactly, covering a chunk that straddles the patch/token seam
    (block 16 > n_patches 8), a prefix-only first chunk (block 8), and a
    partial trailing chunk."""
    cfg = smoke_config("phi-3-vision-4.2b")
    assert cfg.n_patches == 8
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    patches = jax.random.normal(
        jax.random.key(1), (3, cfg.n_patches, cfg.patch_embed_dim),
        jnp.float32)
    reqs = [Request([1, 2, 3], 6, rid=0),
            Request(list(range(9)), 5, rid=1),
            Request([7] * 17, 4, rid=2)]
    dense = ServeEngine(model, params, max_batch=2, cache_len=CACHE_LEN,
                        extra_inputs={"patches": patches}).generate(reqs)
    for bs in (16, 8):
        paged = ServeEngine(model, params, max_batch=2,
                            cache_len=CACHE_LEN, kv_layout="paged",
                            block_size=bs,
                            extra_inputs={"patches": patches}
                            ).generate(reqs)
        for d, p in zip(dense, paged):
            assert d.tokens == p.tokens, (bs, d.rid)


def test_paged_request_never_fits_rejected(model_and_params):
    """A request whose worst case exceeds the whole pool errors up front
    (before any scheduling), and the engine stays usable: no blocks or
    reservations leak from the rejected batch."""
    eng = _engine(model_and_params, max_batch=2, cache_len=64,
                  kv_layout="paged", block_size=BLOCK, n_blocks=3)
    fits = Request([1, 2, 3], 6, rid=0)
    with pytest.raises(ValueError, match="KV blocks"):
        # the admissible request rides in the same batch as the impossible
        # one: up-front validation must reject before either is scheduled
        eng.generate([fits, Request(list(range(10)), 40, rid=1)])
    assert eng.allocator.n_live == 0 and eng.allocator.n_reserved == 0
    res = eng.generate([fits])          # engine not wedged by the reject
    assert len(res[0].tokens) == fits.max_new_tokens


def test_paged_cache_len_budget_still_enforced(model_and_params):
    """cache_len stays the per-request context bound (block-table width)."""
    eng = _engine(model_and_params, max_batch=2, kv_layout="paged",
                  block_size=BLOCK)
    with pytest.raises(ValueError, match="cache positions"):
        eng.generate([Request(list(range(10)), CACHE_LEN, rid=0)])


def test_paged_requires_capable_family():
    cfg = smoke_config("xlstm-350m")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(model, params, max_batch=2, cache_len=32,
                    kv_layout="paged")


# ---------------------------------------------------------------------------
# Paged-attention kernel vs reference path.
# ---------------------------------------------------------------------------

def _rand_paged_case(key, *, n_blocks=9, hkv=2, bs=16, d=16, b=3, m=4, g=3):
    k1, k2, k3 = jax.random.split(key, 3)
    kp = jax.random.normal(k1, (n_blocks, hkv, bs, d), jnp.float32)
    vp = jax.random.normal(k2, (n_blocks, hkv, bs, d), jnp.float32)
    q = jax.random.normal(k3, (b, hkv * g, 1, d), jnp.float32)
    bt = jnp.asarray(
        np.array([[1, 2, 3, 4], [5, 6, 0, 0], [7, 8, 0, 0]]), jnp.int32)
    kv_len = jnp.asarray([64, 23, 17], jnp.int32)
    return q, kp, vp, bt, kv_len


def test_paged_kernel_matches_reference():
    q, kp, vp, bt, kv_len = _rand_paged_case(jax.random.key(1))
    ref = paged_decode_attention_xla(q, kp, vp, bt, kv_len)
    got = paged_decode_attention_pallas(q, kp, vp, bt, kv_len,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_partial_block_boundaries():
    """kv_len at and just past block boundaries (the masked tail of a
    block and a fully masked trailing block)."""
    q, kp, vp, bt, _ = _rand_paged_case(jax.random.key(2))
    for lens in ([16, 16, 16], [1, 32, 33], [48, 17, 1]):
        kv_len = jnp.asarray(lens, jnp.int32)
        ref = paged_decode_attention_xla(q, kp, vp, bt, kv_len)
        got = paged_decode_attention_pallas(q, kp, vp, bt, kv_len,
                                            interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5, err_msg=str(lens))


def test_paged_kernel_ignores_garbage_past_kv_len():
    """Entries at or past kv_len must not leak into the output, whatever
    the trailing block-table ids point at."""
    q, kp, vp, bt, kv_len = _rand_paged_case(jax.random.key(3))
    ref = paged_decode_attention_xla(q, kp, vp, bt, kv_len)
    kp2 = kp.at[0].set(1e6)             # null block: rows 1/2 padding
    vp2 = vp.at[0].set(-1e6)
    ref2 = paged_decode_attention_xla(q, kp2, vp2, bt, kv_len)
    np.testing.assert_allclose(np.asarray(ref2[1:]), np.asarray(ref[1:]),
                               rtol=1e-6, atol=1e-6)
    got2 = paged_decode_attention_pallas(q, kp2, vp2, bt, kv_len,
                                         interpret=True)
    np.testing.assert_allclose(np.asarray(got2[1:]), np.asarray(ref[1:]),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_via_ops_dispatch():
    q, kp, vp, bt, kv_len = _rand_paged_case(jax.random.key(4))
    ref = ops.paged_decode_attention(q, kp, vp, bt, kv_len, impl="xla")
    got = ops.paged_decode_attention(q, kp, vp, bt, kv_len,
                                     impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # sliding windows ride the gather/reference path in every impl
    win = ops.paged_decode_attention(q, kp, vp, bt, kv_len,
                                     impl="interpret", window=8)
    winref = paged_decode_attention_xla(q, kp, vp, bt, kv_len, window=8)
    np.testing.assert_allclose(np.asarray(win), np.asarray(winref),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Paged chunked-prefill kernel vs reference paths.
# ---------------------------------------------------------------------------

def _rand_prefill_case(key, *, n_blocks=9, hkv=2, bs=8, d=16, b=3, m=4,
                       g=2):
    k1, k2, k3 = jax.random.split(key, 3)
    kp = jax.random.normal(k1, (n_blocks, hkv, bs, d), jnp.float32)
    vp = jax.random.normal(k2, (n_blocks, hkv, bs, d), jnp.float32)
    q = jax.random.normal(k3, (b, hkv * g, bs, d), jnp.float32)
    bt = jnp.asarray(
        np.array([[1, 2, 3, 4], [5, 6, 0, 0], [7, 8, 0, 0]]), jnp.int32)
    # rows sit at chunks 3, 1, 0: causal frontiers mid-table, early, first
    q_start = jnp.asarray([24, 8, 0], jnp.int32)
    return q, kp, vp, bt, q_start


def test_paged_prefill_kernel_matches_reference():
    q, kp, vp, bt, qs = _rand_prefill_case(jax.random.key(5))
    ref = paged_prefill_attention_ref(q, kp, vp, bt, qs)
    xla = paged_prefill_attention_xla(q, kp, vp, bt, qs)
    pal = paged_prefill_attention_pallas(q, kp, vp, bt, qs, interpret=True)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_paged_prefill_kernel_chunk_positions():
    """Every chunk index, including the first (block 0 must always
    contribute — the online softmax init relies on it) and the last
    (frontier at the table's end)."""
    q, kp, vp, bt, _ = _rand_prefill_case(jax.random.key(6))
    for starts in ([0, 0, 0], [8, 16, 24], [24, 24, 24]):
        qs = jnp.asarray(starts, jnp.int32)
        ref = paged_prefill_attention_ref(q, kp, vp, bt, qs)
        xla = paged_prefill_attention_xla(q, kp, vp, bt, qs)
        pal = paged_prefill_attention_pallas(q, kp, vp, bt, qs,
                                             interpret=True)
        np.testing.assert_allclose(np.asarray(xla), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5, err_msg=str(starts))
        np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5, err_msg=str(starts))


def test_paged_prefill_kernel_ignores_blocks_past_frontier():
    """Blocks beyond a chunk's causal frontier must not leak into the
    output whatever their table entries point at (the engine leaves
    trailing entries on the null block)."""
    q, kp, vp, bt, qs = _rand_prefill_case(jax.random.key(7))
    ref = paged_prefill_attention_ref(q, kp, vp, bt, qs)
    kp2 = kp.at[0].set(1e6)            # null block: rows 1/2 trailing ids
    vp2 = vp.at[0].set(-1e6)
    for fn in (paged_prefill_attention_xla,
               lambda *a: paged_prefill_attention_pallas(*a,
                                                         interpret=True)):
        got = fn(q, kp2, vp2, bt, qs)
        np.testing.assert_allclose(np.asarray(got[1:]), np.asarray(ref[1:]),
                                   rtol=1e-5, atol=1e-5)


def test_paged_prefill_kernel_via_ops_dispatch():
    q, kp, vp, bt, qs = _rand_prefill_case(jax.random.key(8))
    ref = ops.paged_prefill_attention(q, kp, vp, bt, qs, impl="xla")
    got = ops.paged_prefill_attention(q, kp, vp, bt, qs, impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # sliding windows ride the per-block gather path in every impl
    win = ops.paged_prefill_attention(q, kp, vp, bt, qs, impl="interpret",
                                      window=5)
    winref = paged_prefill_attention_ref(q, kp, vp, bt, qs, window=5)
    np.testing.assert_allclose(np.asarray(win), np.asarray(winref),
                               rtol=1e-5, atol=1e-5)
