"""Per-arch smoke tests (reduced configs) + the decode==prefill invariant +
a short training-loss-decreases check per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, cell_applicable, get_config, list_archs, \
    smoke_config
from repro.models import build_model

KEY = jax.random.key(7)
ARCHS = list_archs()


def smoke_batch(cfg, b=2, s=32, seed=0):
    f = jax.random.fold_in
    toks = jax.random.randint(f(KEY, seed), (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            f(KEY, seed + 1), (b, cfg.n_patches, cfg.patch_embed_dim)
        ).astype(jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            f(KEY, seed + 2), (b, s, cfg.d_model)).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one grad step on CPU: output shapes + no NaNs."""
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = smoke_batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: model.loss(p, b, remat=False))(params, batch)
    assert jnp.isfinite(loss), arch
    assert 0.0 < float(loss) < 20.0
    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), arch
    assert any(float(jnp.abs(g).max()) > 0 for g in flat), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_equals_incremental_prefill(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    b, s = 2, 17
    batch = smoke_batch(cfg, b=b, s=s + 1, seed=3)
    toks = batch["tokens"]
    extra = {k: v for k, v in batch.items()
             if k not in ("tokens", "labels")}
    npfx = cfg.n_patches if cfg.family == "vlm" else 0
    full, _ = model.prefill(params, {"tokens": toks, **extra},
                            cache_len=s + 1 + npfx)
    _, cache = model.prefill(params, {"tokens": toks[:, :s], **extra},
                             cache_len=s + 4 + npfx)
    dec, _ = model.decode(params, cache, toks[:, s:s + 1])
    # bf16 activations: the chunked-prefill vs step-decode paths round
    # differently; ssm/hybrid (chunked scans vs recurrent steps) are loosest
    # (atol covers the few near-zero logits where rtol is meaningless)
    tol = 5e-2 if cfg.family in ("hybrid", "ssm") else 2e-2
    atol = 15e-2 if cfg.family in ("hybrid", "ssm") else 2e-2
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(dec, np.float32),
                               atol=atol, rtol=tol)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "zamba2-1.2b", "xlstm-350m",
                                  "granite-moe-1b-a400m", "whisper-base"])
def test_loss_decreases(arch):
    """5 SGD-ish steps on a fixed batch must reduce the loss (one arch per
    family)."""
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = smoke_batch(cfg, seed=11)

    from repro.optim import clip_by_global_norm

    # zamba2's SSD dt/decay params are step-size sensitive (0.05
    # intermittently NaNs at smoke scale); others descend faster at 0.05
    lr = 0.01 if cfg.family == "hybrid" else 0.05

    @jax.jit
    def step(p, b):
        loss, grads = jax.value_and_grad(
            lambda p_: model.loss(p_, b, remat=False)[0])(p)
        grads, _ = clip_by_global_norm(grads, 1.0)
        p = jax.tree_util.tree_map(
            lambda w, g: (w.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(w.dtype),
            p, grads)
        return p, loss

    losses = []
    for _ in range(5):
        params, loss = step(params, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.05, losses


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_templates(arch):
    """The FULL configs build templates with exact assigned dimensions (no
    allocation)."""
    cfg = get_config(arch)
    model = build_model(cfg)
    n = model.n_params
    expected_ranges = {
        "phi-3-vision-4.2b": (3.5e9, 5.0e9),
        "zamba2-1.2b": (1.0e9, 1.6e9),
        "qwen3-0.6b": (0.5e9, 0.85e9),
        "yi-6b": (5.5e9, 6.6e9),
        "gemma3-27b": (25e9, 30e9),
        "qwen2.5-3b": (2.7e9, 3.6e9),
        "xlstm-350m": (0.28e9, 0.42e9),
        "qwen3-moe-235b-a22b": (225e9, 245e9),
        "granite-moe-1b-a400m": (1.0e9, 1.6e9),
        "whisper-base": (0.06e9, 0.11e9),
    }
    lo, hi = expected_ranges[arch]
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of [{lo/1e9}, {hi/1e9}]"


def test_cell_applicability_table():
    """34 runnable cells + 6 documented long_500k skips."""
    runnable = skipped = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = cell_applicable(cfg, shape)
            runnable += ok
            skipped += not ok
            if not ok:
                assert shape.name == "long_500k" and why
    assert runnable == 32 and skipped == 8


def test_moe_active_params():
    """qwen3-moe: ~22B active of ~235B total."""
    from repro.distributed.mesh_policy import _active_params
    cfg = get_config("qwen3-moe-235b-a22b")
    assert 18e9 <= _active_params(cfg) <= 26e9
