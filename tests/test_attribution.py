"""Utilization-attribution tests (see docs/observability.md).

Unit level: every bottleneck verdict is reachable and stable under a
synthetic :class:`MachineSpec` (no jax involved — the classifier is
pure arithmetic over span timings and a :class:`PhaseCost`), the
dominant-verdict tie-break follows the paper-ordered taxonomy, the
recorded ``attr_*`` metrics merge losslessly across registries, and the
:class:`EngineStats` rollup derives fu_utilization / achieved rates /
verdict counts from the merged union exactly.

Integration level: an attributed ServeEngine produces byte-identical
tokens (attribution is host-side only — its costs come from a separate
AOT lowering), positive HLO-derived costs with a memoized cost table,
``roofline`` counter events on the trace, and attribution fields on the
stats view; a cluster shares one Attributor across replicas and rolls
the replicas up through the registry merge.
"""
import jax
import pytest

from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import (NULL_ATTR, NULL_TRACER, Attributor, ClusterEngine,
                           EngineStats, FakeClock, MachineSpec,
                           MetricsRegistry, NullAttributor, PhaseCost,
                           Request, ServeEngine, Tracer, VERDICTS,
                           dominant_verdict)

CACHE_LEN = 48
BLOCK = 8
SLOTS = 3

# ridge = 100/10 = 10 flops/byte: verdicts are easy to place on either side
SPEC = MachineSpec("synthetic", peak_flops=100.0, mem_bw=10.0)


# ---------------------------------------------------------------------------
# Classifier: every verdict reachable, stable at the boundaries
# ---------------------------------------------------------------------------

def _classify(at, **kw):
    base = dict(active=4, width=4, dispatch_s=0.1, device_s=0.9,
                cost=PhaseCost(flops=100.0, mem_bytes=1.0))   # ai=100
    base.update(kw)
    return at.classify(**base)


def test_machine_spec_ridge():
    assert SPEC.ridge == pytest.approx(10.0)
    assert PhaseCost(flops=50.0, mem_bytes=2.0).ai == pytest.approx(25.0)
    assert MachineSpec.detect().peak_flops > 0     # never degenerate


def test_classify_idle():
    at = Attributor(spec=SPEC)
    assert _classify(at, active=0) == "idle"


def test_classify_issue_bound():
    """Dispatch >= threshold * total launch time: the serving twin of the
    paper's scalar-core issue-rate bound, checked before the roofline."""
    at = Attributor(spec=SPEC, issue_threshold=0.5)
    assert _classify(at, dispatch_s=0.6, device_s=0.4) == "issue"
    assert _classify(at, dispatch_s=0.5, device_s=0.5) == "issue"  # boundary
    assert _classify(at, dispatch_s=0.4, device_s=0.6) != "issue"


def test_classify_memory_vs_compute():
    at = Attributor(spec=SPEC)
    lo = PhaseCost(flops=50.0, mem_bytes=10.0)      # ai=5  < ridge 10
    hi = PhaseCost(flops=500.0, mem_bytes=10.0)     # ai=50 > ridge 10
    assert _classify(at, cost=lo) == "memory"
    assert _classify(at, cost=hi) == "compute"


def test_classify_idle_lanes_drag_intensity_down():
    """Useful AI scales by the live fraction: a launch whose nominal
    intensity clears the ridge reads memory-bound when most lanes are
    idle (idle lanes still drag their rows through HBM)."""
    at = Attributor(spec=SPEC)
    hi = PhaseCost(flops=200.0, mem_bytes=10.0)     # nominal ai=20 > ridge
    assert _classify(at, cost=hi, active=4, width=4) == "compute"
    assert _classify(at, cost=hi, active=1, width=4) == "memory"   # ai -> 5


def test_classify_is_deterministic():
    at = Attributor(spec=SPEC)
    kw = dict(active=2, width=4, dispatch_s=0.2, device_s=0.8,
              cost=PhaseCost(flops=120.0, mem_bytes=10.0))
    assert len({at.classify(**kw) for _ in range(10)}) == 1


def test_dominant_verdict_order_and_ties():
    assert dominant_verdict({}) == ""
    assert dominant_verdict({"memory": 3, "compute": 1}) == "memory"
    # ties break in VERDICTS order (issue first)
    assert dominant_verdict({"memory": 2, "issue": 2}) == "issue"
    assert dominant_verdict({v: 1 for v in VERDICTS}) == "issue"


def test_null_attributor_is_inert():
    at = NULL_ATTR
    assert isinstance(at, NullAttributor) and not at.enabled
    assert at.phase_cost("k", None, ()) is None
    m = MetricsRegistry()
    at.record_step(m, NULL_TRACER, "t", t0=0.0, t_disp=1.0, t1=2.0,
                   active=1, width=1, cost=None)
    at.record_prefill(m, NULL_TRACER, "t", t0=0.0, t1=1.0, cost=None)
    assert m.snapshot() == {}


# ---------------------------------------------------------------------------
# Recording + merge + stats rollup (synthetic registries, no engine)
# ---------------------------------------------------------------------------

def _record_steps(at, m, specs):
    """specs: list of (active, dispatch_s, device_s, cost) tuples."""
    t = 0.0
    for active, disp, dev, cost in specs:
        at.record_step(m, NULL_TRACER, "trk", t0=t, t_disp=t + disp,
                       t1=t + disp + dev, active=active, width=4, cost=cost)
        t += disp + dev


def test_record_step_metrics_and_rollup():
    at = Attributor(spec=SPEC)
    m = MetricsRegistry()
    lo = PhaseCost(flops=50.0, mem_bytes=10.0)     # memory side
    hi = PhaseCost(flops=500.0, mem_bytes=10.0)    # compute side
    _record_steps(at, m, [
        (4, 0.0, 1.0, lo),     # memory
        (4, 0.0, 1.0, hi),     # compute
        (4, 0.9, 0.1, hi),     # issue
        (0, 0.0, 1.0, hi),     # idle
    ])
    assert m.counter("attr_verdict_memory").n == 1
    assert m.counter("attr_verdict_compute").n == 1
    assert m.counter("attr_verdict_issue").n == 1
    assert m.counter("attr_verdict_idle").n == 1
    assert m.histogram("attr_step_flops").count == 4
    assert m.gauge("attr_peak_flops").value == SPEC.peak_flops

    s = EngineStats.from_registry(m, mode="continuous", wall_s=4.0)
    # device time = 0.1+1+1+1 s; useful flops = 50+500+500+0
    assert s.achieved_flops_per_s == pytest.approx(1050.0 / 3.1)
    assert s.fu_utilization == pytest.approx(1050.0 / 3.1 / 100.0)
    assert s.ridge_ai == pytest.approx(10.0)
    assert s.verdict_counts == {v: 1 for v in VERDICTS}
    assert s.bottleneck == "issue"                 # tie -> paper order


def test_attr_metrics_merge_losslessly():
    """Two replica registries with attr samples: the merged rollup equals
    attribution over the union — the cluster aggregation contract."""
    at = Attributor(spec=SPEC)
    a, b = MetricsRegistry(), MetricsRegistry()
    lo = PhaseCost(flops=50.0, mem_bytes=10.0)
    _record_steps(at, a, [(4, 0.0, 1.0, lo)] * 2)
    _record_steps(at, b, [(4, 0.0, 1.0, lo)] * 3)
    a.merge(b)
    assert a.counter("attr_verdict_memory").n == 5
    assert a.histogram("attr_step_flops").count == 5
    s = EngineStats.from_registry(a, mode="continuous", wall_s=5.0)
    assert s.achieved_flops_per_s == pytest.approx(50.0)   # 250 flops / 5 s
    assert s.verdict_counts == {"memory": 5}
    assert s.bottleneck == "memory"


def test_record_prefill_pure_roofline_verdict():
    at = Attributor(spec=SPEC)
    m = MetricsRegistry()
    at.record_prefill(m, NULL_TRACER, "trk", t0=0.0, t1=0.5,
                      cost=PhaseCost(flops=50.0, mem_bytes=10.0))
    at.record_prefill(m, NULL_TRACER, "trk", t0=0.5, t1=1.0,
                      cost=PhaseCost(flops=500.0, mem_bytes=10.0))
    assert m.counter("attr_prefill_verdict_memory").n == 1
    assert m.counter("attr_prefill_verdict_compute").n == 1
    assert m.histogram("attr_prefill_ms").count == 2
    s = EngineStats.from_registry(m, mode="continuous", wall_s=1.0)
    assert s.prefill_bottleneck in ("memory", "compute")


def test_roofline_counter_track_on_trace():
    at = Attributor(spec=SPEC)
    m = MetricsRegistry()
    clock = FakeClock(start=0.0, tick=0.0)
    tr = Tracer(clock=clock)
    at.record_step(m, tr, "replica0", t0=0.0, t_disp=0.1, t1=1.0,
                   active=4, width=4, cost=PhaseCost(50.0, 10.0))
    (ev,) = tr.events()
    assert (ev.ph, ev.name, ev.track) == ("C", "roofline", "replica0")
    # 50 useful flops over a 1 s step vs 100 FLOP/s peak -> 50% of peak
    assert ev.args["flops_pct"] == pytest.approx(50.0)
    assert ev.args["bytes_pct"] == pytest.approx(100.0)


# ---------------------------------------------------------------------------
# Engine integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_model():
    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _trace(vocab, n=4, max_new=6):
    return [Request([(5 * i + j) % vocab for j in range(4 + i)], max_new,
                    temperature=0.0, rid=i) for i in range(n)]


def test_attribution_leaves_tokens_identical(smoke_model):
    cfg, model, params = smoke_model
    eng = ServeEngine(model, params, max_batch=SLOTS, cache_len=CACHE_LEN,
                      kv_layout="paged", block_size=BLOCK)
    ref = [r.tokens for r in eng.generate(_trace(cfg.vocab_size))]

    at = Attributor()
    eng.set_attributor(at)
    try:
        got = [r.tokens for r in eng.generate(_trace(cfg.vocab_size))]
    finally:
        eng.set_attributor(NULL_ATTR)
    assert got == ref

    # HLO-derived costs are real and memoized (decode + prefill chunks)
    assert at._costs and all(c.flops > 0 and c.mem_bytes > 0
                             for c in at._costs.values())
    s = eng.last_stats
    assert s.achieved_flops_per_s > 0 and s.achieved_bytes_per_s > 0
    assert s.bottleneck in VERDICTS
    assert s.prefill_bottleneck in ("memory", "compute")
    assert 0.0 < s.fu_utilization < 1.0
    assert sum(s.verdict_counts.values()) == s.decode_steps


def test_attributed_trace_carries_roofline_counters(smoke_model):
    cfg, model, params = smoke_model
    eng = ServeEngine(model, params, max_batch=SLOTS, cache_len=CACHE_LEN,
                      kv_layout="paged", block_size=BLOCK)
    tracer, at = Tracer(), Attributor()
    eng.set_tracer(tracer)
    eng.set_attributor(at)
    try:
        eng.generate(_trace(cfg.vocab_size))
    finally:
        eng.set_tracer(NULL_TRACER)
        eng.set_attributor(NULL_ATTR)
    roofs = [e for e in tracer.events() if e.name == "roofline"]
    assert roofs and all(e.ph == "C" for e in roofs)
    assert all(e.args["flops_pct"] >= 0 for e in roofs)


def test_cluster_shares_attributor_and_rolls_up(smoke_model):
    cfg, model, params = smoke_model
    cl = ClusterEngine(model, params, replicas=2, total_slots=4,
                       cache_len=CACHE_LEN, block_size=BLOCK)
    ref = [r.tokens for r in cl.generate(_trace(cfg.vocab_size))]

    at = Attributor()
    cl.set_attributor(at)
    try:
        got = [r.tokens for r in cl.generate(_trace(cfg.vocab_size))]
    finally:
        cl.set_attributor(NULL_ATTR)
    assert got == ref
    # identical replicas share one memo entry per (phase, shape) — the
    # cost table must not scale with the replica count
    phases = {k[0] for k in at._costs}
    assert "decode" in phases
    s = cl.last_stats
    assert s.achieved_flops_per_s > 0 and s.bottleneck in VERDICTS
    assert sum(s.verdict_counts.values()) == s.decode_steps


def test_dense_engine_attribution(smoke_model):
    cfg, model, params = smoke_model
    eng = ServeEngine(model, params, max_batch=SLOTS, cache_len=CACHE_LEN,
                      kv_layout="dense", attribution=Attributor())
    res = eng.generate(_trace(cfg.vocab_size))
    assert all(r.tokens for r in res)
    s = eng.last_stats
    assert s.achieved_flops_per_s > 0 and s.bottleneck in VERDICTS
    assert s.prefill_bottleneck in ("memory", "compute")
