"""Continuous-batching serving engine: equivalence, slot reuse,
per-request sampling, metrics."""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import Request, ServeEngine

CACHE_LEN = 64


@pytest.fixture(scope="module")
def model_and_params():
    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _engine(model_and_params, **kw):
    _, model, params = model_and_params
    kw.setdefault("cache_len", CACHE_LEN)
    return ServeEngine(model, params, **kw)


def test_batched_matches_single_greedy(model_and_params):
    """(a) greedy decoding is independent of batch composition: a request
    decoded alone must produce the same tokens as the same request decoded
    in a full continuous batch."""
    reqs = [Request([1, 2, 3], 6, rid=0), Request([4, 5], 8, rid=1),
            Request([9, 8, 7, 6], 5, rid=2), Request([3], 7, rid=3)]
    batched = _engine(model_and_params, max_batch=4,
                      mode="continuous").generate(reqs)
    single_eng = _engine(model_and_params, max_batch=1, mode="continuous")
    for r, got in zip(reqs, batched):
        alone = single_eng.generate([r])[0]
        assert got.tokens == alone.tokens, r.rid


def test_slot_reuse_refills_freed_slots(model_and_params):
    """(b) short requests free their slot for queued work: every request
    still gets exactly its max_new_tokens, in fewer decode steps than the
    lock-step group schedule needs."""
    reqs = [Request([1, 2], 8, rid=0), Request([3, 4], 2, rid=1),
            Request([5, 6], 8, rid=2), Request([7, 8], 2, rid=3),
            Request([9, 1], 8, rid=4)]
    cont = _engine(model_and_params, max_batch=2, mode="continuous")
    res = cont.generate(reqs)
    assert [len(r.tokens) for r in res] == [r.max_new_tokens for r in reqs]
    assert [r.rid for r in res] == [r.rid for r in reqs]
    lock = _engine(model_and_params, max_batch=2, mode="lockstep")
    lock_res = lock.generate(reqs)
    assert [len(r.tokens) for r in lock_res] == [r.max_new_tokens
                                                 for r in reqs]
    # lock-step: 3 groups paced by their slowest member = (8-1)*3 steps;
    # continuous refills rid 1/3's slots and finishes in fewer steps
    assert lock.last_stats.decode_steps == 21
    assert cont.last_stats.decode_steps < lock.last_stats.decode_steps


@pytest.mark.parametrize("mode", ["continuous", "lockstep"])
def test_per_request_temperature(model_and_params, mode):
    """(c) temperature is per-request, not the batch max: a temperature-0
    row stays deterministic (and equal to its solo greedy decode) even when
    batched with temperature>0 rows."""
    greedy = Request([1, 2, 3], 6, temperature=0.0, rid=0)
    hot = [Request([4, 5, 6], 6, temperature=1.5, rid=1),
           Request([7, 8, 9], 6, temperature=2.0, rid=2)]
    eng = _engine(model_and_params, max_batch=3, mode=mode)
    run1 = eng.generate([greedy] + hot, key=jax.random.key(1))
    run2 = eng.generate([greedy] + hot, key=jax.random.key(2))
    assert run1[0].tokens == run2[0].tokens
    solo = _engine(model_and_params, max_batch=1,
                   mode=mode).generate([greedy])[0]
    assert run1[0].tokens == solo.tokens


def test_sampled_stream_is_placement_independent(model_and_params):
    """(c') sampling keys derive from (rid, token index), not slot/step
    order: a temperature>0 request produces the same tokens decoded alone,
    batched with neighbors, or under the lock-step scheduler (equal-length
    prompts, so lockstep's padded group prefill matches the solo one)."""
    hot = [Request([1 + i, 2 + i, 3 + i], 6, temperature=1.0, rid=i)
           for i in range(3)]
    key = jax.random.key(3)
    batched = _engine(model_and_params, max_batch=3,
                      mode="continuous").generate(hot, key=key)
    solo = _engine(model_and_params, max_batch=1, mode="continuous")
    for r, got in zip(hot, batched):
        assert solo.generate([r], key=key)[0].tokens == got.tokens, r.rid
    lock = _engine(model_and_params, max_batch=3,
                   mode="lockstep").generate(hot, key=key)
    for a, b in zip(batched, lock):
        assert a.tokens == b.tokens, a.rid


def test_metrics_sanity(model_and_params):
    """(d) prefill/decode timings positive, occupancy in (0, 1]."""
    reqs = [Request([1, 2, 3], 6, rid=0), Request([4, 5], 3, rid=1),
            Request([6], 5, rid=2)]
    eng = _engine(model_and_params, max_batch=2, mode="continuous")
    res = eng.generate(reqs)
    for r in res:
        assert r.prefill_ms > 0.0
        assert r.decode_ms_per_tok > 0.0
    s = eng.last_stats
    assert s.mode == "continuous"
    assert s.generated_tokens == sum(r.max_new_tokens for r in reqs)
    assert s.tokens_per_s > 0.0
    assert s.decode_steps > 0
    assert 0.0 < s.occupancy <= 1.0
    assert s.ttft_ms_mean > 0.0


@pytest.mark.parametrize("mode", ["continuous", "lockstep"])
def test_cache_overflow_rejected(model_and_params, mode):
    """Both schedulers enforce prefill + generation <= cache_len (writes
    beyond the cache would silently drop or clobber KV entries)."""
    eng = _engine(model_and_params, max_batch=2, mode=mode)
    with pytest.raises(ValueError, match="cache positions"):
        eng.generate([Request(list(range(10)), CACHE_LEN, rid=0)])


def test_extra_inputs_too_few_rows_rejected(model_and_params):
    """extra_inputs rows are per-request by submission order; too few rows
    must error instead of silently reusing another request's row."""
    import jax.numpy as jnp
    _, model, params = model_and_params
    eng = ServeEngine(model, params, max_batch=2, cache_len=CACHE_LEN,
                      extra_inputs={"bogus": jnp.zeros((2, 3))})
    reqs = [Request([1, 2], 2, rid=i) for i in range(3)]
    with pytest.raises(ValueError, match="one row per request"):
        eng.generate(reqs)


def _scan_setup(arch):
    import jax.numpy as jnp
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    extra = None
    if cfg.family == "encdec":
        extra = {"frames": jax.random.normal(
            jax.random.key(9), (8, 6, cfg.d_model)).astype(jnp.bfloat16)}
    return cfg, model, params, extra


@pytest.mark.parametrize("arch", ["xlstm-350m", "zamba2-1.2b",
                                  "whisper-base"])
def test_scan_family_serves_continuous(arch):
    """Slot-addressable recurrent state: the scan families run the
    continuous scheduler (no lockstep fallback) and emit byte-identical
    tokens to the lock-step baseline on a uniform-length trace, greedy
    and sampled rows alike."""
    cfg, model, params, extra = _scan_setup(arch)
    # short requests batched beside long ones: lockstep pins their slots
    # to the group's slowest member, continuous refills them
    reqs = [Request([1 + i, 2 + i, 3 + i], 8 if i % 2 else 2,
                    temperature=(1.2 if i % 2 else 0.0), rid=i)
            for i in range(4)]
    key = jax.random.key(11)
    cont = ServeEngine(model, params, max_batch=2, cache_len=32,
                       mode="continuous", extra_inputs=extra)
    assert cont.mode == "continuous"
    res = cont.generate(reqs, key=key)
    assert [len(r.tokens) for r in res] == [r.max_new_tokens for r in reqs]
    lock = ServeEngine(model, params, max_batch=2, cache_len=32,
                       mode="lockstep", extra_inputs=extra)
    for a, b in zip(res, lock.generate(reqs, key=key)):
        assert a.tokens == b.tokens, (arch, a.rid)
    # the whole point: freed slots refill instead of idling to a barrier
    assert cont.last_stats.decode_steps < lock.last_stats.decode_steps


def test_scan_family_rejects_bucketing_and_paged():
    """A scan-family prefill folds every position into recurrent state:
    right-padded bucketed prompts would corrupt it, and there is no block
    pool to page - both knobs fail loudly instead of mis-serving."""
    cfg, model, params, _ = _scan_setup("xlstm-350m")
    with pytest.raises(ValueError, match="bucket"):
        ServeEngine(model, params, max_batch=2, cache_len=32,
                    bucket="pow2")
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(model, params, max_batch=2, cache_len=32,
                    kv_layout="paged")


@pytest.mark.parametrize("arch", ["xlstm-350m", "whisper-base"])
def test_freed_scan_slot_state_is_reset(arch):
    """No-leak invariant: when a scan-family slot is freed, every leaf of
    its recurrent state (and its position) is zeroed - nothing of the
    finished request survives for a later occupant to read."""
    import numpy as np
    cfg, model, params, extra = _scan_setup(arch)
    eng = ServeEngine(model, params, max_batch=2, cache_len=32,
                      mode="continuous", extra_inputs=extra)
    eng.begin_session(jax.random.key(0))
    eng.session_admit(Request([1, 2, 3], 3, rid=0), tag=0)
    while eng.session_active:
        eng.session_step()
    cache = eng._sess.cache
    if arch == "xlstm-350m":
        from repro.models.xlstm_lm import XLSTM_STATE_AXES as axes
    else:
        from repro.models.encdec import ENCDEC_STATE_AXES as axes
    assert int(np.asarray(cache["pos"])[0]) == 0
    for name, ax in axes.items():
        row = np.moveaxis(np.asarray(cache[name], np.float32), ax, 0)[0]
        assert not row.any(), (arch, name)
    eng.end_session()


# ---------------------------------------------------------------------------
# Streaming: TokenEvents as tokens are sampled.
# ---------------------------------------------------------------------------

def test_stream_matches_generate(model_and_params):
    """Streaming is a pure view: the TokenEvents concatenate to exactly
    the generate output, per-rid indices are gapless and ordered, and
    each request carries exactly one final marker."""
    reqs = [Request([1, 2, 3], 5, rid=0), Request([4, 5], 3, rid=1),
            Request([9, 8, 7], 4, temperature=0.8, rid=2)]
    eng = _engine(model_and_params, max_batch=2, mode="continuous")
    key = jax.random.key(3)
    ref = eng.generate(reqs, key=key)
    by_rid = {}
    finals = []
    for ev in eng.stream(reqs, key=key):
        assert ev.index == len(by_rid.setdefault(ev.rid, []))
        by_rid[ev.rid].append(ev.token)
        if ev.final:
            finals.append(ev.rid)
    assert sorted(finals) == [0, 1, 2]
    for r in ref:
        assert by_rid[r.rid] == r.tokens, r.rid


def test_on_token_callback_from_generate(model_and_params):
    """generate(on_token=...) pushes the same events the stream yields,
    including the dense instant-finish path (a 1-token budget satisfied
    at admission still emits its event)."""
    reqs = [Request([1, 2, 3], 1, rid=0)]
    eng = _engine(model_and_params, max_batch=2, mode="continuous")
    got = []
    res = eng.generate(reqs, on_token=got.append)
    assert [(e.rid, e.token, e.index, e.final) for e in got] \
        == [(0, res[0].tokens[0], 0, True)]


def test_on_token_rejected_under_lockstep(model_and_params):
    """Streaming needs the continuous scheduler (lockstep materializes
    whole completions per group) - fail loudly, not silently unstreamed."""
    eng = _engine(model_and_params, max_batch=2, mode="lockstep")
    with pytest.raises(ValueError, match="on_token"):
        eng.generate([Request([1, 2], 3, rid=0)],
                     on_token=lambda ev: None)
