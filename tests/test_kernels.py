"""Per-kernel shape/dtype sweeps: Pallas (interpret) and XLA impls vs the
pure-jnp oracles in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(42)


def rn(i, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.fold_in(KEY, i), shape,
                              jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# matmul: shape x dtype sweep.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (128, 256, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_pallas_sweep(m, k, n, dtype):
    x, w = rn(1, (m, k), dtype), rn(2, (k, n), dtype)
    got = ops.matmul(x, w, impl="interpret", out_dtype=jnp.float32)
    want = ref.matmul_ref(x, w, out_dtype=jnp.float32)
    tol = 2e-5 * k if dtype == jnp.float32 else 2e-2 * np.sqrt(k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol,
                               rtol=1e-2)


@pytest.mark.parametrize("blocks", [(128, 128, 128), (64, 128, 256)])
def test_matmul_block_shapes(blocks):
    bm, bn, bk = blocks
    x, w = rn(3, (256, 256)), rn(4, (256, 256))
    got = ops.matmul(x, w, impl="interpret", bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul_ref(x, w)),
                               atol=1e-3, rtol=1e-4)


# ---------------------------------------------------------------------------
# Pool kernels.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1024, 4096])
@pytest.mark.parametrize("impl", ["interpret", "xla"])
def test_dotproduct(n, impl):
    x, y = rn(5, (n,)), rn(6, (n,))
    got = float(ops.dotproduct(x, y, impl=impl))
    np.testing.assert_allclose(got, float(ref.dotproduct_ref(x, y)),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("shape", [(8, 128), (32, 512)])
@pytest.mark.parametrize("impl", ["interpret", "xla"])
def test_softmax(shape, impl):
    x = rn(7, shape, scale=3.0)
    np.testing.assert_allclose(np.asarray(ops.softmax(x, impl=impl)),
                               np.asarray(ref.softmax_ref(x)), atol=1e-6)


@pytest.mark.parametrize("impl", ["interpret", "xla"])
def test_exp_poly(impl):
    x = rn(8, (2048,), scale=4.0)
    np.testing.assert_allclose(np.asarray(ops.exp(x, impl=impl)),
                               np.asarray(ref.exp_ref(x)), rtol=2e-5,
                               atol=1e-6)


def test_exp_poly_range():
    # the paper's software-exp must stay accurate across the fp range used
    x = jnp.linspace(-20.0, 20.0, 4096)
    got = np.asarray(ops.exp(x, impl="interpret"))
    np.testing.assert_allclose(got, np.exp(np.asarray(x)), rtol=5e-5)


@pytest.mark.parametrize("rate", [0.1, 0.5])
def test_dropout(rate):
    x = rn(9, (2048,))
    bits = jax.random.bits(jax.random.fold_in(KEY, 10), (2048,), jnp.uint32)
    got = ops.dropout(x, bits, rate=rate, impl="interpret")
    want = ref.dropout_ref(x, bits, rate)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    kept = float(jnp.mean(got != 0))
    assert abs(kept - (1 - rate)) < 0.06


@pytest.mark.parametrize("hw", [(38, 64), (22, 32)])
def test_conv2d(hw):
    h, w = hw
    x, k = rn(11, (3, h, w)), rn(12, (3, 7, 7), scale=0.3)
    got = ops.conv2d(x, k, impl="interpret")
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.conv2d_ref(x, k)),
                               atol=1e-4, rtol=1e-4)


def test_jacobi2d():
    x = rn(13, (34, 66))
    got = ops.jacobi2d(x, impl="interpret")
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.jacobi2d_ref(x)), atol=1e-6)


@pytest.mark.parametrize("levels", [1, 2, 3])
def test_dwt(levels):
    x = rn(14, (1024,))
    got = ops.dwt_haar(x, levels=levels, impl="interpret")
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.dwt_haar_ref(x, levels)),
                               atol=1e-4)
    # orthonormal: energy preserved
    np.testing.assert_allclose(float(jnp.sum(got ** 2)),
                               float(jnp.sum(x ** 2)), rtol=1e-4)


@pytest.mark.parametrize("impl", ["interpret", "xla"])
def test_pathfinder(impl):
    w = jnp.abs(rn(15, (20, 257)))
    got = ops.pathfinder(w, impl=impl)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.pathfinder_ref(w)), atol=1e-4)


@pytest.mark.parametrize("n", [64, 512, 2048])
@pytest.mark.parametrize("impl", ["interpret", "xla"])
def test_fft(n, impl):
    xr, xi = rn(16, (n,)), rn(17, (n,))
    gr, gi = ops.fft(xr, xi, impl=impl)
    wr, wi = ref.fft_ref(xr, xi)
    np.testing.assert_allclose(np.asarray(gr), np.asarray(wr),
                               atol=1e-2 * np.sqrt(n))
    np.testing.assert_allclose(np.asarray(gi), np.asarray(wi),
                               atol=1e-2 * np.sqrt(n))


def test_fft_parseval():
    n = 1024
    xr, xi = rn(18, (n,)), rn(19, (n,))
    gr, gi = ops.fft(xr, xi, impl="xla")
    e_t = float(jnp.sum(xr ** 2 + xi ** 2))
    e_f = float(jnp.sum(gr ** 2 + gi ** 2)) / n
    np.testing.assert_allclose(e_f, e_t, rtol=1e-4)


def test_roi_align():
    feat = rn(20, (4, 32, 32))
    y0, x0 = jnp.abs(rn(21, (5,))) * 3, jnp.abs(rn(22, (5,))) * 3
    rois = jnp.stack([y0, x0, y0 + 11, x0 + 9], -1)
    got = ops.roi_align(feat, rois)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.roi_align_ref(feat, rois)),
                               atol=1e-4)
