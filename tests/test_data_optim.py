"""Data pipeline determinism/restart + optimizer correctness."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.data import MMapTokens, Prefetcher, SyntheticTokens
from repro.optim import AdamW, clip_by_global_norm, warmup_cosine, wsd

CFG = smoke_config("qwen3-0.6b")


def test_synthetic_restart_determinism():
    """batch(i) is a pure function of (seed, i): resuming replays exactly."""
    d1 = SyntheticTokens(CFG, 4, 16, seed=3)
    d2 = SyntheticTokens(CFG, 4, 16, seed=3)
    for step in (0, 5, 1000):
        b1, b2 = d1(step), d2(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1(1)["tokens"], d1(2)["tokens"])
    assert not np.array_equal(SyntheticTokens(CFG, 4, 16, seed=4)(0)["tokens"],
                              d1(0)["tokens"])


def test_synthetic_labels_are_shifted():
    b = SyntheticTokens(CFG, 2, 16, seed=0)(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_mmap_tokens(tmp_path):
    path = tmp_path / "toks.bin"
    data = np.arange(10000, dtype=np.uint16) % CFG.vocab_size
    data.tofile(path)
    ds = MMapTokens(str(path), CFG, batch_size=4, seq_len=32, seed=1)
    b0a, b0b = ds(0), ds(0)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])
    np.testing.assert_array_equal(b0a["tokens"][:, 1:], b0a["labels"][:, :-1])
    assert b0a["tokens"].shape == (4, 32)


def test_prefetcher_order_and_stop():
    src = SyntheticTokens(CFG, 2, 8, seed=0)
    pf = Prefetcher(src, start_step=10, depth=2)
    steps = [pf.next()[0] for _ in range(4)]
    assert steps == [10, 11, 12, 13]
    pf.stop()


# ---------------------------------------------------------------------------
# Optimizer.
# ---------------------------------------------------------------------------

def test_adamw_matches_reference():
    """Hand-rolled AdamW vs a straightforward numpy reference, 10 steps."""
    opt = AdamW(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.1)
    w0 = jnp.asarray([[1.0, -2.0], [0.5, 3.0]], jnp.float32)
    params = {"w": w0.astype(jnp.bfloat16)}
    state = opt.init(params)
    rng = np.random.default_rng(0)
    m = np.zeros((2, 2)); v = np.zeros((2, 2)); wref = np.asarray(w0)
    for t in range(1, 11):
        g = rng.standard_normal((2, 2)).astype(np.float32)
        state = opt.update({"w": jnp.asarray(g)}, state)
        m = 0.9 * m + 0.1 * g
        v = 0.99 * v + 0.01 * g * g
        mh, vh = m / (1 - 0.9 ** t), v / (1 - 0.99 ** t)
        wref = wref - 1e-2 * (mh / (np.sqrt(vh) + 1e-8) + 0.1 * wref)
    np.testing.assert_allclose(np.asarray(state["master"]["w"]), wref,
                               rtol=1e-5, atol=1e-6)


def test_adamw_skips_decay_on_1d():
    opt = AdamW(lr=1e-2, weight_decay=1.0)
    params = {"norm": jnp.ones((8,), jnp.float32)}
    state = opt.init(params)
    state = opt.update({"norm": jnp.zeros((8,))}, state)
    np.testing.assert_array_equal(np.asarray(state["master"]["norm"]),
                                  np.ones(8, np.float32))


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((2, 2), -10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    total = np.sqrt(sum(float((x ** 2).sum())
                        for x in jax.tree_util.tree_leaves(clipped)))
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(gn), np.sqrt(800.0), rtol=1e-6)


def test_schedules():
    lr = warmup_cosine(1e-3, 10, 100, min_ratio=0.1)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(jnp.int32(100))) == pytest.approx(1e-4, rel=1e-2)
    w = wsd(1e-3, 10, 100, decay_frac=0.2)
    assert float(w(jnp.int32(50))) == pytest.approx(1e-3)
    assert float(w(jnp.int32(100))) == pytest.approx(0.0, abs=1e-9)
