"""Property-style invariants of the analytical performance model.

Runs as hypothesis property tests when hypothesis is installed and as a
parametrized grid otherwise (the shim in ``helpers`` only covers skip-on-
missing; these tests keep coverage either way, per the Fig. 4 claims).
"""
import pytest

from helpers import HAS_HYPOTHESIS
from repro.core.perf_model import KERNELS, ideality
from repro.core.vector_engine import VectorEngineConfig

if HAS_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

LANES = [2, 4, 8, 16]
BPL_GRID = [8.0, 12.0, 16.0, 24.0, 32.0, 48.0, 64.0, 96.0, 128.0,
            192.0, 256.0, 384.0, 512.0]
# Diagonal invariant: exact for kernels without a reduction tail (the tail
# is a fixed latency whose amortization depends on absolute vector length,
# not bytes/lane - the paper plots those kernels separately).
DIAG_KERNELS = sorted(k for k, s in KERNELS.items()
                      if not s.uses_reduction)
COMPUTE_BOUND = sorted(k for k, s in KERNELS.items() if s.compute_bound)


def _check_diagonal(kernel, bpl):
    """Fig. 4 diagonal: ideality depends on bytes/lane only - constant
    across (lanes, vector length) pairs at fixed bytes/lane."""
    vals = [ideality(kernel, bpl * lanes, VectorEngineConfig(n_lanes=lanes))
            for lanes in LANES]
    assert max(vals) - min(vals) < 1e-9, (kernel, bpl, vals)


def _check_monotone(kernel, lanes):
    """Ideality of compute-bound kernels is monotone nondecreasing in
    bytes/lane (more per-PE work amortizes issue/setup non-idealities)."""
    eng = VectorEngineConfig(n_lanes=lanes)
    vals = [ideality(kernel, bpl * lanes, eng) for bpl in BPL_GRID]
    for lo, hi, b in zip(vals, vals[1:], BPL_GRID[1:]):
        assert hi >= lo - 1e-12, (kernel, lanes, b, vals)


if HAS_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.sampled_from(DIAG_KERNELS),
           st.floats(min_value=8.0, max_value=512.0,
                     allow_nan=False, allow_infinity=False))
    def test_fig4_diagonal_invariant(kernel, bpl):
        _check_diagonal(kernel, bpl)

    @settings(max_examples=40, deadline=None)
    @given(st.sampled_from(COMPUTE_BOUND), st.sampled_from(LANES))
    def test_ideality_monotone_in_bytes_per_lane(kernel, lanes):
        _check_monotone(kernel, lanes)
else:
    @pytest.mark.parametrize("bpl", BPL_GRID)
    @pytest.mark.parametrize("kernel", DIAG_KERNELS)
    def test_fig4_diagonal_invariant(kernel, bpl):
        _check_diagonal(kernel, bpl)

    @pytest.mark.parametrize("lanes", LANES)
    @pytest.mark.parametrize("kernel", COMPUTE_BOUND)
    def test_ideality_monotone_in_bytes_per_lane(kernel, lanes):
        _check_monotone(kernel, lanes)
