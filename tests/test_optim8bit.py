"""8-bit AdamW + microbatched grad accumulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_mesh
from repro.optim import AdamW, AdamW8bit
from repro.optim.adamw8bit import _dq, _q_pos, _q_sym


def test_quantization_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 300)),
                    jnp.float32)
    q, s = _q_sym(x)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(_dq(q, s, x.shape)), np.asarray(x),
                               atol=float(jnp.abs(x).max()) / 120)
    v = x * x
    q, s = _q_pos(v)
    assert q.dtype == jnp.uint8
    np.testing.assert_allclose(
        np.asarray(_dq(q, s, v.shape, square=True)), np.asarray(v),
        atol=float(v.max()) / 100)


def test_8bit_trains_comparably():
    """8-bit Adam need not match fp32 elementwise; it must optimize a simple
    quadratic comparably (loss within 10% after 40 steps)."""
    target = jnp.asarray(np.random.default_rng(1).standard_normal((32, 32)),
                         jnp.float32)

    def loss_of(w):
        return jnp.mean((w - target) ** 2)

    def run(opt):
        params = {"w": jnp.zeros((32, 32), jnp.float32)}
        state = opt.init(params)
        for _ in range(40):
            w = state["master"]["w"]
            g = jax.grad(lambda w_: loss_of(w_))(w)
            state = opt.update({"w": g}, state)
        return float(loss_of(state["master"]["w"]))

    init = float(loss_of(jnp.zeros((32, 32))))
    l32 = run(AdamW(lr=5e-2, weight_decay=0.0))
    l8 = run(AdamW8bit(lr=5e-2, weight_decay=0.0))
    # linear-code 8-bit state trades fidelity for 6 bytes/param: require
    # strong descent and same order of magnitude as fp32
    assert l8 < 0.15 * init, (l8, init)
    assert l8 < 6 * l32 + 1e-3, (l8, l32)


def test_8bit_state_bytes():
    """m/v stored in ~1.06 bytes/param instead of 4."""
    params = {"w": jnp.zeros((1024, 1024), jnp.float32)}
    st = AdamW8bit().init(params)
    mb = st["m"]["w"]["q"].size + st["m"]["w"]["s"].size * 4
    assert mb < 1024 * 1024 * 1.1


def test_microbatching_matches_full_batch():
    """Grad accumulation over K microbatches == one full-batch step (linear
    loss in batch dim -> identical gradients)."""
    import jax
    from repro.configs import smoke_config
    from repro.distributed.sharding import ShardingPolicy
    from repro.models import build_model
    from repro.train.trainer import _step_body
    from repro.data import SyntheticTokens

    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    opt = AdamW(lr=1e-3)
    mesh = make_mesh((1, 1), ("data", "model"))
    policy = ShardingPolicy(fsdp=False)
    batch = {k: jnp.asarray(v)
             for k, v in SyntheticTokens(cfg, 8, 32, seed=0)(0).items()}
    params = model.init(jax.random.key(0))
    s1 = _step_body(model, opt, mesh, policy.act_rules(), 1.0, False)(
        opt.init(params), batch)
    s4 = _step_body(model, opt, mesh, policy.act_rules(), 1.0, False,
                    microbatches=4)(opt.init(params), batch)
    w1 = s1[0]["master"]
    w4 = s4[0]["master"]
    # bf16 forward + different accumulation order: not bitwise equal.
    # Aggregate over ALL params: >=99.9% match tightly (tiny leaves can have
    # a single Adam-rsqrt-sensitive element off) and none diverges past ~2lr
    flat1 = np.concatenate([np.asarray(a).ravel()
                            for a in jax.tree_util.tree_leaves(w1)])
    flat4 = np.concatenate([np.asarray(b).ravel()
                            for b in jax.tree_util.tree_leaves(w4)])
    close = np.isclose(flat1, flat4, atol=5e-5, rtol=1e-3)
    assert close.mean() > 0.999, close.mean()
    assert np.abs(flat1 - flat4).max() < 2e-3
