"""SLO scheduling tests: policy key semantics, the victim-protection
bugfix regression, the dense/scan queue-age pressure signal, and the
starvation regression (FIFO misses an adversarial trace's TTFT budgets,
``slo_adaptive`` attains >= 90% — byte-identical tokens either way).

The cluster tests run a scan-family (ssm) cluster on the dense slot
layout under a :class:`FakeClock` (1 virtual ms per clock read): these
replicas have no block pool, so ``PoolPressure`` can never fire and the
slot-count + queue-age starvation signal is the *only* pressure they can
feel — exactly the gap the signal exists to close.
"""
import dataclasses

import jax
import pytest

from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import (ClusterEngine, FakeClock, POLICIES, Request,
                           SchedPolicy, make_policy)
from repro.serving.slo import in_slack, slo_budget_s, ttft_deadline

TICK_S = 1e-3                   # 1 virtual ms per clock read


def _req(rid=0, prompt=(1, 2, 3), max_new=4, prio=0, ttft=None, tpot=None):
    return Request(prompt=list(prompt), max_new_tokens=max_new, rid=rid,
                   priority=prio, slo_ttft_ms=ttft, slo_tpot_ms=tpot)


# ---------------------------------------------------------------------------
# Pure policy semantics (no model, no clock).
# ---------------------------------------------------------------------------

def test_make_policy_registry_and_errors():
    for name in POLICIES:
        pol = make_policy(name)
        assert isinstance(pol, SchedPolicy)
        assert pol.name == name
        assert make_policy(pol) is pol          # instance passthrough
    with pytest.raises(ValueError, match="nope"):
        make_policy("nope")


def test_budget_helpers():
    best_effort = _req()
    assert ttft_deadline(best_effort, 5.0) == float("inf")
    assert slo_budget_s(best_effort) is None
    assert not in_slack(best_effort, 0.0, 0.0)  # never protected

    r = _req(ttft=100.0, tpot=10.0, max_new=4)
    assert ttft_deadline(r, 5.0) == pytest.approx(5.1)
    # 100ms TTFT + 10ms x 4 owed tokens = 140ms window
    assert slo_budget_s(r) == pytest.approx(0.140)
    assert in_slack(r, t0=0.0, now=0.139)
    assert not in_slack(r, t0=0.0, now=0.141)


def test_order_keys_degenerate_without_budgets():
    """With no budgets (and flat priorities) every policy's admission
    key sorts by arrival seq — the FIFO-equivalence contract."""
    reqs = [_req(rid=i) for i in range(5)]
    for name in POLICIES:
        pol = make_policy(name)
        keys = [pol.order_key(seq, r, 0.0, 1.0)
                for seq, r in enumerate(reqs)]
        assert keys == sorted(keys)


def test_order_keys_reorder_with_signal():
    pri = make_policy("priority")
    lo, hi = _req(rid=0, prio=0), _req(rid=1, prio=2)
    assert pri.order_key(1, hi, 0.0, 0.0) < pri.order_key(0, lo, 0.0, 0.0)

    edf = make_policy("edf")
    tight = _req(rid=0, ttft=10.0)
    loose = _req(rid=1, ttft=500.0)
    assert (edf.order_key(1, tight, 0.0, 0.0)
            < edf.order_key(0, loose, 0.0, 0.0))
    # best-effort (deadline +inf) sorts behind every budgeted request
    assert (edf.order_key(0, loose, 0.0, 0.0)
            < edf.order_key(1, _req(rid=2), 0.0, 0.0))


def test_victim_key_protects_in_slack():
    """slo_adaptive's victim key leads with the protection flag: an
    in-slack budgeted request outranks (is evicted after) any
    best-effort or already-late request, regardless of priority or
    admission recency — the classic (priority, -admit_seq) ranking only
    breaks ties within a protection class."""
    pol = make_policy("slo_adaptive")
    protected = pol.victim_key(_req(ttft=1e6, prio=0), 0, t0=0.0, now=0.01)
    best_effort = pol.victim_key(_req(prio=2), 1, t0=0.0, now=0.01)
    late = pol.victim_key(_req(ttft=5.0, prio=2), 2, t0=0.0, now=0.01)
    assert protected[0] == 1
    assert best_effort[0] == 0 and late[0] == 0
    assert min(protected, best_effort, late) != protected
    # the classic ranking (every other policy) would evict the budgeted
    # low-priority request first — the bug the injectable key fixes
    classic = make_policy("fifo")
    assert min(classic.victim_key(_req(ttft=1e6, prio=0), 0, 0.0, 0.01),
               classic.victim_key(_req(prio=2), 1, 0.0, 0.01)
               )[1:] == (0, 0)


def test_starving_guard_band():
    pol = make_policy("slo_adaptive")
    r = _req(ttft=100.0)
    # deadline = enqueue + 100ms; guard 50ms -> starving once now is
    # within 50ms of the deadline (or past it)
    assert not pol.starving(r, enqueue_t=0.0, now=0.049, guard_s=0.05)
    assert pol.starving(r, enqueue_t=0.0, now=0.051, guard_s=0.05)
    assert pol.starving(r, enqueue_t=0.0, now=1.0, guard_s=0.05)
    assert not pol.starving(_req(), 0.0, 1e9, 0.05)   # best-effort: never
    assert not make_policy("fifo").starving(r, 0.0, 1e9, 0.05)


# ---------------------------------------------------------------------------
# Cluster integration: ssm (scan-family) replicas on the dense layout.
# ---------------------------------------------------------------------------

CACHE_LEN = 96
DECOY_NEW = 64                 # straggler decode length (fills a slot)
N_SHORT = 6
SHORT_NEW = 4
#: Virtual-ms budgets: decoys carry the tightest TTFT budget (earliest
#: deadline -> admitted first under EDF too, same head-of-line setup as
#: FIFO) but a budget window so small they fall out of slack almost
#: immediately -> unprotected victims.  Shorts' budget minus the guard
#: band sets the virtual time the starvation signal trips.
DECOY_TTFT, SHORT_TTFT, GUARD_MS = 30.0, 300.0, 250.0


@pytest.fixture(scope="module")
def ssm():
    cfg = smoke_config("xlstm-350m")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _cluster(ssm, policy, **kw):
    cfg, model, params = ssm
    kw.setdefault("replicas", 2)
    kw.setdefault("total_slots", 2)
    kw.setdefault("cache_len", CACHE_LEN)
    kw.setdefault("clock", FakeClock(0.0, tick=TICK_S))
    return ClusterEngine(model, params, policy=policy, **kw)


def _starve_trace(vocab):
    """Two long best-effort-ish stragglers ahead of budgeted shorts,
    sized to fill both slots: the decoys' tight TTFT deadline admits
    them first under *every* policy (FIFO by arrival, EDF by deadline),
    then their tiny slack window expires and the shorts age."""
    decoys = [Request(prompt=[(7 * i + j) % vocab for j in range(8)],
                      max_new_tokens=DECOY_NEW, rid=i,
                      slo_ttft_ms=DECOY_TTFT)
              for i in range(2)]
    shorts = [Request(prompt=[(11 * i + j) % vocab for j in range(8)],
                      max_new_tokens=SHORT_NEW, rid=10 + i,
                      slo_ttft_ms=SHORT_TTFT)
              for i in range(N_SHORT)]
    return decoys + shorts


def test_starvation_fifo_misses_slo_adaptive_attains(ssm):
    """The starvation regression: on the adversarial trace FIFO serves
    the stragglers to completion and the shorts blow their TTFT budgets;
    slo_adaptive's queue-age pressure preempts the out-of-slack decoys
    and attains >= 90% — with byte-identical per-request tokens (the
    policies reorder, never alter, sampling)."""
    cfg, _, _ = ssm
    reqs = _starve_trace(cfg.vocab_size)

    fifo = _cluster(ssm, "fifo", preempt_hysteresis=64)
    res_f = fifo.generate(reqs, key=jax.random.key(3))
    sf = fifo.last_stats
    assert sf.slo_starve_preempts == 0          # fifo never preempts
    # decoys attain at admission; every short sits out a 64-token
    # straggler on a 1-slot replica and misses
    assert sf.slo_ttft_total == 2 + N_SHORT
    assert sf.slo_ttft_attained <= 2
    assert sf.slo_attainment <= 0.5

    ada = _cluster(ssm, "slo_adaptive", preempt_hysteresis=64,
                   slo_guard_ms=GUARD_MS)
    res_a = ada.generate(reqs, key=jax.random.key(3))
    sa = ada.last_stats
    assert sa.slo_starve_preempts >= 1          # the pressure signal fired
    assert sa.slo_ttft_total == 2 + N_SHORT
    assert sa.slo_ttft_attained >= 0.9 * sa.slo_ttft_total
    assert sa.slo_attainment >= 0.9
    assert sa.slo_attainment > sf.slo_attainment

    for a, b in zip(res_f, res_a):
        assert a.rid == b.rid and a.tokens == b.tokens, a.rid
    assert all(len(r.tokens) == q.max_new_tokens
               for r, q in zip(res_a, reqs))


def test_dense_scan_queue_age_pressure_signal(ssm):
    """Unit test of the queue-age half on a dense (scan-family) cluster:
    ``_starving_item`` fires only for a ready, budgeted item inside the
    guard band, and only under a policy that arms the signal."""
    cl = _cluster(ssm, "slo_adaptive", slo_guard_ms=50.0)
    now = cl.clock.now()
    aged = (0, 0, _req(rid=0, ttft=100.0), 0, now - 0.06)
    fresh = (1, 1, _req(rid=1, ttft=100.0), 0, now + 10.0)
    best_effort = (2, 2, _req(rid=2), 0, now - 100.0)
    cooling = (3, 3, _req(rid=3, ttft=100.0), 999, now - 0.06)

    item = cl._starving_item([fresh, aged, best_effort], rounds=0)
    assert item is aged                 # inside the guard band + ready
    assert cl._starving_item([fresh, best_effort], rounds=0) is None
    assert cl._starving_item([cooling], rounds=0) is None   # hysteresis
    assert cl._starving_item([], rounds=0) is None

    # fifo (and every non-adaptive policy) never arms the signal
    cl.policy = make_policy("fifo")
    assert cl._starving_item([aged], rounds=0) is None


def test_cluster_victim_pick_never_evicts_in_slack(ssm):
    """The bugfix regression: the cluster victim pick is ranked by the
    injected policy, and under slo_adaptive it must never select a
    budgeted request inside its deadline slack while a best-effort
    victim exists — even when the classic (priority, -admit_seq) ranking
    would have chosen the protected request first."""
    cl = _cluster(ssm, "slo_adaptive", replicas=1, total_slots=2)
    e = cl.engines[0]
    # protected: huge budget window, *lowest* priority and oldest
    # admission — the classic ranking's preferred victim
    protected = _req(rid=1, prompt=range(4), max_new=8, prio=0, ttft=1e6)
    best_effort = _req(rid=2, prompt=range(4), max_new=8, prio=2)
    e.begin_session(jax.random.key(0))
    try:
        e.session_admit(protected, tag=0, admit_seq=0)
        e.session_admit(best_effort, tag=1, admit_seq=1)
        slot_of = {s.req.rid: i for i, s in e.session_slots()}

        picked = cl._pick_victim(None, None)
        assert picked is not None and picked[1] == slot_of[2]
        picked = cl._pick_victim(None, None, require_unprotected=True)
        assert picked is not None and picked[1] == slot_of[2]

        # the injectable ranking is the fix: the classic key (any other
        # policy) picks the low-priority budgeted request instead
        cl.policy = make_policy("fifo")
        for rep in cl.engines:
            rep.policy = cl.policy
        assert cl._pick_victim(None, None)[1] == slot_of[1]
        cl.policy = make_policy("slo_adaptive")
        for rep in cl.engines:
            rep.policy = cl.policy

        # with only the protected request live: the pressure path
        # (require_unprotected) refuses it, the last-resort path may
        # still take it
        e.session_preempt(slot_of[2])
        assert cl._pick_victim(None, None, require_unprotected=True) is None
        assert cl._pick_victim(None, None)[1] == slot_of[1]
    finally:
        e.session_abort()


@pytest.mark.parametrize("depth,temp", [(2, 0.0), (9, 0.0), (9, 1.1)])
def test_scan_resume_replay_is_byte_exact(ssm, depth, temp):
    """Regression for the scan-family resume bug the starvation preempts
    exposed: chunkwise-parallel prefill and the stepwise decode
    recurrence are mathematically but not bitwise interchangeable, so
    re-admitting a preempted request by prefilling prompt+done perturbed
    the resumed logits (greedy argmax flips at near-ties).  Re-admission
    now prefills only the prompt and *replays* ``done`` through the
    decode step (``ServeEngine._replay_done``): byte-identical at any
    preemption depth, greedy or sampled."""
    from repro.serving import ServeEngine
    cfg, model, params = ssm
    key = jax.random.key(3)
    victim = Request(prompt=[(7 + j) % cfg.vocab_size for j in range(8)],
                     max_new_tokens=20, temperature=temp, rid=0)

    def fresh():
        return ServeEngine(model, params, max_batch=1, cache_len=CACHE_LEN,
                           mode="continuous")

    uninterrupted = fresh().generate([victim], key=key)[0]
    eng = fresh()
    eng.begin_session(key)
    eng.session_admit(victim, tag=0)        # admission emits token 0
    for _ in range(depth - 1):
        eng.session_step()
    _, requeued = eng.session_preempt(0)
    eng.session_abort()
    assert len(requeued.done) == depth
    resumed = fresh().generate([requeued], key=key)[0]
    assert resumed.tokens == uninterrupted.tokens


def test_cluster_rejects_bad_policy_and_guard(ssm):
    with pytest.raises(ValueError, match="policy"):
        _cluster(ssm, "deadline")
    with pytest.raises(ValueError, match="slo_guard_ms"):
        _cluster(ssm, "slo_adaptive", slo_guard_ms=-1.0)
