"""Property-based serving conformance harness.

One invariant replaces the hand-rolled per-combination equivalence
asserts scattered through the serving tests: **for any trace, every
scheduler/layout/topology combination emits byte-identical token
streams.**  Greedy rows are deterministic argmax; sampled rows are keyed
by (base key, rid, token index), so placement, scheduling, KV layout,
chunked prefill, routing, and preemption must all be invisible in the
output.

The harness draws random traces (prompt lengths and contents,
``max_new_tokens``, priorities, temperatures, base PRNG seed) and runs
each through:

  * dense continuous            (the reference)
  * dense lock-step             (uniform-length traces only: left-padded
                                 group prefill is position-exact only
                                 when the group shares one length)
  * paged continuous            (chunked paged prefill + paged decode)
  * cluster 1xN                 (one wide replica — router is a no-op)
  * cluster Nx1, every router   (round_robin / least_loaded /
                                 shortest_queue)
  * cluster 2x2 over a starved pool (overcommit admission: pool pressure
                                 forces preemption + requeue mid-trace)
  * cluster Nx1 + 2x2-pressure, threaded driver (replicas stepped on
                                 worker threads: scheduling timing is
                                 nondeterministic, tokens must not be)

A second property runs the same conformance over the **scan families**
(ssm / hybrid / encdec), whose continuous batching rides slot-addressable
recurrent state (``repro.models.slot_state``) instead of KV strips:

  {ssm, hybrid, encdec} x {continuous, lockstep-on-uniform-lengths}
                        x {single, 1xN cluster, Nx1 cluster}

must be byte-identical per trace too (their clusters run the dense slot
layout — no pool, so the drain check is vacuous there).

After every run the shared pools must be fully drained (no leaked blocks
or reservations) — a stateful invariant the random traces exercise far
harder than the fixed regression traces do.

Four cells (paged single, Nx1 cluster, pressure cluster, threaded
pressure cluster) additionally
serve every drawn trace with a live :class:`Tracer` *and* a shared
:class:`Attributor` attached: the token assert against the untraced,
unattributed reference doubles as the observer-effect gate (neither
tracing nor roofline attribution may perturb scheduling or sampling —
attribution costs come from a separate AOT lowering, never the serving
executables), and the
recorded event stream must be lifecycle-well-formed
(:func:`validate_lifecycle`: an admit precedes the first decode, every
preempt is followed by a requeue or abort, per-request block
alloc/ref/COW acquisitions balance the frees — see
docs/observability.md).

With hypothesis installed (CI) the trace space is explored and shrunk by
``@given``; without it, a seeded-PRNG fallback draws the same
distributions so the suite still runs everywhere.
"""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import (NULL_ATTR, NULL_TRACER, Attributor, ClusterEngine,
                           Request, ServeEngine, Tracer, validate_lifecycle)

from helpers import HAS_HYPOTHESIS, given, settings, st

CACHE_LEN = 48
BLOCK = 8
SLOTS = 3
MAX_PROMPT = 12
MAX_NEW = 8
TEMPERATURES = (0.0, 0.0, 0.7, 1.3)   # half greedy, half sampled
N_EXAMPLES = 50                        # CI: >= 50 random traces
N_FALLBACK = 10                        # hypothesis-less local run


@pytest.fixture(scope="module")
def harness():
    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    def eng(**kw):
        kw.setdefault("cache_len", CACHE_LEN)
        return ServeEngine(model, params, **kw)

    def cluster(**kw):
        kw.setdefault("cache_len", CACHE_LEN)
        kw.setdefault("block_size", BLOCK)
        return ClusterEngine(model, params, **kw)

    engines = {
        "dense-continuous": eng(max_batch=SLOTS, mode="continuous"),
        "dense-lockstep": eng(max_batch=SLOTS, mode="lockstep"),
        "paged-continuous": eng(max_batch=SLOTS, kv_layout="paged",
                                block_size=BLOCK),
        "cluster-1xN": cluster(replicas=1, total_slots=SLOTS),
        "cluster-Nx1-round_robin": cluster(replicas=SLOTS,
                                           total_slots=SLOTS,
                                           router="round_robin"),
        "cluster-Nx1-least_loaded": cluster(replicas=SLOTS,
                                            total_slots=SLOTS,
                                            router="least_loaded"),
        "cluster-Nx1-shortest_queue": cluster(replicas=SLOTS,
                                              total_slots=SLOTS,
                                              router="shortest_queue"),
        # starved shared pool: 7 allocatable blocks vs up to 6 requests
        # wanting 3 each — overcommit admission must preempt to serve it
        "cluster-2x2-pressure": cluster(replicas=2, total_slots=4,
                                        n_blocks=8),
        # the threaded driver re-runs the routed and the pressure cells
        # with replicas stepping on worker threads: byte-identity vs the
        # same dense reference is the sequential-vs-threaded conformance
        # bar (scheduling timing is free, tokens are not)
        "cluster-Nx1-threaded": cluster(replicas=SLOTS, total_slots=SLOTS,
                                        driver="threaded"),
        "cluster-2x2-pressure-threaded": cluster(replicas=2,
                                                 total_slots=4,
                                                 n_blocks=8,
                                                 driver="threaded"),
        # scheduling-policy cells, budgets unset: every policy must
        # degenerate to FIFO byte-for-byte (the slo.py contract) — one
        # single-engine reorderer, one reordering cluster, the adaptive
        # policy over the preempting pool, and the adaptive policy under
        # the threaded driver (test_policy_matrix_no_budgets_identical
        # sweeps the full policy x topology product on fixed seeds)
        "dense-edf": eng(max_batch=SLOTS, mode="continuous",
                         policy="edf"),
        "cluster-Nx1-priority": cluster(replicas=SLOTS,
                                        total_slots=SLOTS,
                                        policy="priority"),
        "cluster-2x2-pressure-slo": cluster(replicas=2, total_slots=4,
                                            n_blocks=8,
                                            policy="slo_adaptive"),
        "cluster-2x2-slo-threaded": cluster(replicas=2, total_slots=4,
                                            policy="slo_adaptive",
                                            driver="threaded"),
        # prefix cache on: shared-prefix traces admit by reference with
        # refcounted blocks + COW; cache state *persists across traces*
        # (cached blocks survive generate calls), so every subsequent
        # trace also checks hit-vs-cold byte identity
        "paged-prefix-cache": eng(max_batch=SLOTS, kv_layout="paged",
                                  block_size=BLOCK, prefix_cache=True),
        # ...and under a starved pool: preemption of requests *holding
        # shared blocks* must only drop their references
        "cluster-2x2-pressure-prefix": cluster(replicas=2, total_slots=4,
                                               n_blocks=8,
                                               prefix_cache=True),
    }
    return cfg, engines


def _draw_trace(rng: np.random.Generator, vocab: int):
    """Random trace + base key seed from a numpy PRNG (the single-seed
    entry point lets hypothesis and the fallback share one generator).
    Half the traces carry a shared prompt prefix (>= one full block, so
    prefix-cache cells get real hits: block sharing, COW divergence, and
    full-boundary coverage all fall out of the random tails)."""
    n = int(rng.integers(1, 7))
    uniform = bool(rng.integers(0, 2))
    fixed_len = int(rng.integers(1, MAX_PROMPT + 1))
    shared = ([int(t) for t in
               rng.integers(0, vocab, int(rng.integers(BLOCK, BLOCK + 2)))]
              if rng.integers(0, 2) else [])
    reqs = []
    for i in range(n):
        plen = fixed_len if uniform else int(rng.integers(1, MAX_PROMPT + 1))
        prompt = [int(t) for t in rng.integers(0, vocab, plen)]
        if shared and rng.integers(0, 2):
            # sharing requests carry the common prefix; a zero-length
            # tail makes the prompt end exactly on the shared span
            prompt = shared + prompt[:int(rng.integers(0, plen + 1))]
        reqs.append(Request(
            prompt=prompt,
            max_new_tokens=int(rng.integers(1, MAX_NEW + 1)),
            temperature=float(TEMPERATURES[rng.integers(len(TEMPERATURES))]),
            rid=i,
            priority=int(rng.integers(0, 3))))
    return reqs, int(rng.integers(0, 2 ** 31))


# cells that also run lifecycle-traced (single paged, routed cluster,
# preempting cluster): tokens still compare against the untraced
# reference, so these double as the tracing-observer-effect property
TRACED_CELLS = {"paged-continuous", "cluster-Nx1-round_robin",
                "cluster-2x2-pressure", "cluster-2x2-pressure-threaded"}

# one shared attributor for every traced example: the cost memo persists
# across examples (one AOT lowering per compiled shape for the whole
# run), and the token assert against the unattributed reference extends
# the observer-effect property to attribution
_ATTR = Attributor()


def _check_conformance(harness, seed: int):
    cfg, engines = harness
    rng = np.random.default_rng(seed)
    reqs, key_seed = _draw_trace(rng, cfg.vocab_size)
    key = jax.random.key(key_seed)
    uniform = len({len(r.prompt) for r in reqs}) == 1

    ref_eng = engines["dense-continuous"]
    ref = ref_eng.generate(reqs, key=key)
    assert [r.rid for r in ref] == [q.rid for q in reqs]
    assert [len(r.tokens) for r in ref] == [q.max_new_tokens for q in reqs]

    for name, eng in engines.items():
        if eng is ref_eng:
            continue
        if name == "dense-lockstep" and not uniform:
            continue    # left-padded group prefill needs one length
        tracer = Tracer() if name in TRACED_CELLS else None
        if tracer is not None:
            eng.set_tracer(tracer)
            eng.set_attributor(_ATTR)
        try:
            got = eng.generate(reqs, key=key)
        finally:
            if tracer is not None:
                # engines are module-scoped: restore the no-op defaults so
                # later examples/tests run untraced and unattributed
                eng.set_tracer(NULL_TRACER)
                eng.set_attributor(NULL_ATTR)
        if tracer is not None:
            validate_lifecycle(tracer.events())
        for a, b in zip(ref, got):
            assert a.tokens == b.tokens, (
                f"{name} diverged on rid={a.rid} (seed {seed}): "
                f"{a.tokens} vs {b.tokens}")
        pool = getattr(eng, "pool", None) or getattr(eng, "allocator", None)
        if pool is not None:
            # refcount-leak + conservation invariants: every reference
            # dropped, every reservation returned, cached blocks still
            # allocatable (n_free counts them), index consistent
            pool.check_integrity()
            assert pool.n_live == 0, (name, seed)
            assert pool.n_reserved == 0, (name, seed)
            assert pool.n_free == pool.capacity, (name, seed)


@pytest.mark.skipif(not HAS_HYPOTHESIS,
                    reason="hypothesis drives the full example budget; "
                           "the seeded fallback below covers the no-dep "
                           "environment")
@settings(max_examples=N_EXAMPLES, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_serving_conformance_random_traces(harness, seed):
    """>= 50 random traces across every scheduler/layout/topology cell
    (CI budget; shrunk counterexamples name the seed + combination)."""
    _check_conformance(harness, seed)


@pytest.mark.skipif(HAS_HYPOTHESIS,
                    reason="hypothesis variant runs the full budget")
@pytest.mark.parametrize("seed", range(N_FALLBACK))
def test_serving_conformance_fallback(harness, seed):
    _check_conformance(harness, seed)


# ---------------------------------------------------------------------------
# Scan families: slot-addressable recurrent state.
# ---------------------------------------------------------------------------

SCAN_ARCHS = {"ssm": "xlstm-350m", "hybrid": "zamba2-1.2b",
              "encdec": "whisper-base"}
N_SCAN_EXAMPLES = 20                   # per family, CI (hypothesis)
N_SCAN_FALLBACK = 4                    # per family, no-dep fallback


@pytest.fixture(scope="module")
def scan_harness():
    """One engine set per scan family: continuous reference, lock-step
    baseline, and dense-layout clusters (wide 1xN and narrow Nx1)."""
    import jax.numpy as jnp
    out = {}
    for family, arch in SCAN_ARCHS.items():
        cfg = smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        extra = None
        if family == "encdec":
            # one encoder-frame row per possible request (submission
            # order indexes extra_inputs), shared by every engine
            extra = {"frames": jax.random.normal(
                jax.random.key(42), (8, 6, cfg.d_model)
            ).astype(jnp.bfloat16)}
        kw = dict(cache_len=CACHE_LEN, extra_inputs=extra)
        engines = {
            "continuous": ServeEngine(model, params, max_batch=SLOTS,
                                      mode="continuous", **kw),
            "lockstep": ServeEngine(model, params, max_batch=SLOTS,
                                    mode="lockstep", **kw),
            "cluster-1xN": ClusterEngine(model, params, replicas=1,
                                         total_slots=SLOTS, **kw),
            "cluster-Nx1": ClusterEngine(model, params, replicas=SLOTS,
                                         total_slots=SLOTS, **kw),
        }
        assert engines["cluster-Nx1"].kv_layout == "dense"
        out[family] = (cfg, engines)
    return out


def _check_scan_conformance(scan_harness, family: str, seed: int):
    cfg, engines = scan_harness[family]
    rng = np.random.default_rng(seed)
    reqs, key_seed = _draw_trace(rng, cfg.vocab_size)
    key = jax.random.key(key_seed)
    uniform = len({len(r.prompt) for r in reqs}) == 1

    ref = engines["continuous"].generate(reqs, key=key)
    assert [r.rid for r in ref] == [q.rid for q in reqs]
    assert [len(r.tokens) for r in ref] == [q.max_new_tokens for q in reqs]
    for name, eng in engines.items():
        if name == "continuous":
            continue
        if name == "lockstep" and not uniform:
            continue    # left-padded group prefill needs one length
        got = eng.generate(reqs, key=key)
        for a, b in zip(ref, got):
            assert a.tokens == b.tokens, (
                f"{family}/{name} diverged on rid={a.rid} (seed {seed}): "
                f"{a.tokens} vs {b.tokens}")


@pytest.mark.skipif(not HAS_HYPOTHESIS,
                    reason="hypothesis drives the full example budget; "
                           "the seeded fallback below covers the no-dep "
                           "environment")
@settings(max_examples=N_SCAN_EXAMPLES, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_scan_family_conformance_random_traces(scan_harness, seed):
    """{ssm, hybrid, encdec} x {continuous, lockstep-on-uniform}
    x {single, 1xN, Nx1 cluster}: byte-identical tokens per trace (every
    family sees every drawn trace — a shrunk counterexample names the
    family in its assert message)."""
    for family in sorted(SCAN_ARCHS):
        _check_scan_conformance(scan_harness, family, seed)


@pytest.mark.skipif(HAS_HYPOTHESIS,
                    reason="hypothesis variant runs the full budget")
@pytest.mark.parametrize("family", sorted(SCAN_ARCHS))
@pytest.mark.parametrize("seed", range(N_SCAN_FALLBACK))
def test_scan_family_conformance_fallback(scan_harness, family, seed):
    _check_scan_conformance(scan_harness, family, seed)


# ---------------------------------------------------------------------------
# MoE family: dense serving prefill now routes dropless (exact=True), so
# paged==dense token identity holds and the family joins the matrix.
# ---------------------------------------------------------------------------

N_MOE_EXAMPLES = 12                    # CI (hypothesis)
N_MOE_FALLBACK = 3                     # no-dep fallback


@pytest.fixture(scope="module")
def moe_harness():
    cfg = smoke_config("granite-moe-1b-a400m")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    kw = dict(cache_len=CACHE_LEN)
    engines = {
        "dense-continuous": ServeEngine(model, params, max_batch=SLOTS,
                                        mode="continuous", **kw),
        "paged-continuous": ServeEngine(model, params, max_batch=SLOTS,
                                        kv_layout="paged", block_size=BLOCK,
                                        **kw),
        "paged-prefix-cache": ServeEngine(model, params, max_batch=SLOTS,
                                          kv_layout="paged",
                                          block_size=BLOCK,
                                          prefix_cache=True, **kw),
        "cluster-2x1": ClusterEngine(model, params, replicas=2,
                                     total_slots=2, block_size=BLOCK, **kw),
    }
    return cfg, engines


def _check_moe_conformance(moe_harness, seed: int):
    cfg, engines = moe_harness
    rng = np.random.default_rng(seed)
    reqs, key_seed = _draw_trace(rng, cfg.vocab_size)
    key = jax.random.key(key_seed)
    ref = engines["dense-continuous"].generate(reqs, key=key)
    assert [len(r.tokens) for r in ref] == [q.max_new_tokens for q in reqs]
    for name, eng in engines.items():
        if name == "dense-continuous":
            continue
        got = eng.generate(reqs, key=key)
        for a, b in zip(ref, got):
            assert a.tokens == b.tokens, (
                f"moe/{name} diverged on rid={a.rid} (seed {seed}): "
                f"{a.tokens} vs {b.tokens}")
        pool = getattr(eng, "pool", None) or getattr(eng, "allocator", None)
        if pool is not None:
            pool.check_integrity()
            assert pool.n_live == 0 and pool.n_reserved == 0, (name, seed)


@pytest.mark.skipif(not HAS_HYPOTHESIS,
                    reason="hypothesis drives the full example budget; "
                           "the seeded fallback below covers the no-dep "
                           "environment")
@settings(max_examples=N_MOE_EXAMPLES, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_moe_family_conformance_random_traces(moe_harness, seed):
    """moe x {dense, paged, paged+prefix-cache, cluster}: byte-identical
    tokens per trace — the caveat that excluded the family (capacity-
    factor routing in the dense prefill vs dropless chunks in the paged
    one) is closed by routing the dense serving prefill dropless too."""
    _check_moe_conformance(moe_harness, seed)


@pytest.mark.skipif(HAS_HYPOTHESIS,
                    reason="hypothesis variant runs the full budget")
@pytest.mark.parametrize("seed", range(N_MOE_FALLBACK))
def test_moe_family_conformance_fallback(moe_harness, seed):
    _check_moe_conformance(moe_harness, seed)


def test_pressure_cluster_actually_preempts(harness):
    """The starved-pool cell must really exercise the preemption path —
    otherwise the matrix silently stops covering requeue/resume.  A
    worst-case trace (every request wants its full 3 blocks, 12 wanted
    vs 7 allocatable) forces at least one eviction, and the outputs
    still match the uncontended reference."""
    cfg, engines = harness
    # 12-token prompts + 7 decode writes = 19 positions = 3 blocks per
    # request; 6 concurrent worst cases vs 7 allocatable blocks
    reqs = [Request(list(range(i, i + MAX_PROMPT)), MAX_NEW,
                    temperature=(0.9 if i % 2 else 0.0), rid=i)
            for i in range(6)]
    key = jax.random.key(17)
    ref = engines["dense-continuous"].generate(reqs, key=key)
    cl = engines["cluster-2x2-pressure"]
    got = cl.generate(reqs, key=key)
    assert cl.last_stats.preempted >= 1
    assert cl.last_stats.requeued == cl.last_stats.preempted
    for a, b in zip(ref, got):
        assert a.tokens == b.tokens, a.rid
    assert cl.pool.n_live == 0 and cl.pool.n_reserved == 0


def test_pressure_prefix_cluster_preempts_shared_holders(harness):
    """Preemption of requests *holding shared blocks*: every request
    carries the same full-block prefix through the starved pool with the
    prefix cache on, so victims are (with overwhelming likelihood) among
    the sharers — their eviction may only drop references, never free a
    block a survivor still reads.  Tokens must match the uncontended
    dense reference byte for byte, and the pool must drain clean."""
    cfg, engines = harness
    shared = list(range(2, 2 + BLOCK))
    reqs = [Request(shared + list(range(40 + 4 * i, 44 + 4 * i)), MAX_NEW,
                    temperature=(0.9 if i % 2 else 0.0), rid=i)
            for i in range(6)]
    key = jax.random.key(23)
    ref = engines["dense-continuous"].generate(reqs, key=key)
    cl = engines["cluster-2x2-pressure-prefix"]
    got = cl.generate(reqs, key=key)
    assert cl.last_stats.preempted >= 1
    assert cl.last_stats.prefix_hits >= 1
    for a, b in zip(ref, got):
        assert a.tokens == b.tokens, a.rid
    cl.pool.check_integrity()
    assert cl.pool.n_live == 0 and cl.pool.n_reserved == 0
    assert cl.pool.n_free == cl.pool.capacity


def _set_policy(eng, policy):
    """Swap the scheduling policy on a module-scoped engine in place
    (policies are stateless strategy objects; no recompilation).  For a
    cluster the replicas share the cluster's policy instance."""
    from repro.serving import make_policy
    pol = make_policy(policy)
    eng.policy = pol
    for e in getattr(eng, "engines", ()):
        e.policy = pol
    return pol


def test_policy_matrix_no_budgets_identical(harness):
    """Every scheduling policy x {single engine, sequential cluster,
    threaded cluster}: with no request carrying an SLO budget, each
    policy's order keys are degenerate and the schedule — hence the
    token streams — must be byte-identical to the FIFO dense reference.
    Fixed seeds here; the hypothesis matrix above adds depth on the
    dedicated policy cells."""
    from repro.serving import POLICIES
    cfg, engines = harness
    cells = ("dense-continuous", "cluster-Nx1-round_robin",
             "cluster-Nx1-threaded")
    for seed in (3, 11, 27):
        rng = np.random.default_rng(seed)
        reqs, key_seed = _draw_trace(rng, cfg.vocab_size)
        key = jax.random.key(key_seed)
        ref = engines["dense-continuous"].generate(reqs, key=key)
        for policy in POLICIES:
            for cell in cells:
                eng = engines[cell]
                old = eng.policy
                _set_policy(eng, policy)
                try:
                    got = eng.generate(reqs, key=key)
                finally:
                    eng.policy = old
                    for e in getattr(eng, "engines", ()):
                        e.policy = old
                assert eng.last_stats.sched_policy == policy
                for a, b in zip(ref, got):
                    assert a.tokens == b.tokens, (
                        f"{cell}/{policy} diverged on rid={a.rid} "
                        f"(seed {seed}): {a.tokens} vs {b.tokens}")


def test_policies_with_random_budgets_streams_unchanged(harness):
    """Attaching random SLO budgets may reorder and preempt, but sampling
    is request-keyed: every policy's per-request token streams must still
    equal the budget-less dense reference, and the shared pools must
    drain clean even when deadline pressure drove extra preemptions."""
    import dataclasses
    from repro.serving import POLICIES
    cfg, engines = harness
    cells = ("cluster-Nx1-round_robin", "cluster-2x2-pressure-slo",
             "cluster-2x2-slo-threaded")
    for seed in (5, 19):
        rng = np.random.default_rng(seed)
        reqs, key_seed = _draw_trace(rng, cfg.vocab_size)
        key = jax.random.key(key_seed)
        ref = engines["dense-continuous"].generate(reqs, key=key)
        # random budgets on a random subset (tight through generous, in
        # real ms against the monotonic clock: schedules vary run to
        # run, tokens must not)
        budgeted = [
            dataclasses.replace(
                r,
                slo_ttft_ms=(float(rng.uniform(1.0, 200.0))
                             if rng.integers(0, 2) else None),
                slo_tpot_ms=(float(rng.uniform(0.5, 50.0))
                             if rng.integers(0, 2) else None))
            for r in reqs]
        for policy in POLICIES:
            for cell in cells:
                eng = engines[cell]
                old = eng.policy
                _set_policy(eng, policy)
                try:
                    got = eng.generate(budgeted, key=key)
                finally:
                    eng.policy = old
                    for e in getattr(eng, "engines", ()):
                        e.policy = old
                for a, b in zip(ref, got):
                    assert a.tokens == b.tokens, (
                        f"{cell}/{policy} budgets changed tokens on "
                        f"rid={a.rid} (seed {seed})")
                pool = getattr(eng, "pool", None)
                if pool is not None:
                    pool.check_integrity()
                    assert pool.n_live == 0, (cell, policy, seed)
                    assert pool.n_reserved == 0, (cell, policy, seed)


def test_paged_single_compile_across_trace_shapes(harness):
    """The chunked paged prefill is shape-invariant: after serving every
    prompt length in the random-trace envelope, exactly one prefill
    shape has been compiled (the dense reference pays one per length)."""
    cfg, engines = harness
    reqs = [Request(list(range(1, 2 + i)), 2, rid=i)
            for i in range(MAX_PROMPT)]
    eng = engines["paged-continuous"]
    eng.generate(reqs)
    assert eng.last_stats.prefill_compiles == 1
