"""Telemetry subsystem tests (see docs/observability.md).

Unit level: injectable clocks, the NullTracer no-op contract, exact
nearest-rank percentiles, lossless registry merge (counters add, raw
histogram samples concatenate — the property that makes cluster p99s
meaningful), and the Chrome-trace export schema (track metadata, µs
conversion, flow pairing) — checked with the same ``tools/check_trace.py``
validator CI runs on bench artifacts.

Integration level: a ServeEngine under an injected :class:`FakeClock`
produces fully deterministic latency stats; tracing an engine leaves its
token stream byte-identical; a starved-pool cluster records a
lifecycle-well-formed event stream whose preemptions carry matched
flow-arrow pairs.
"""
import json
import pathlib
import sys

import jax
import pytest

from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import (NULL_TRACER, ClusterEngine, EngineStats,
                           FakeClock, MetricsRegistry, NullTracer, Request,
                           ServeEngine, Tracer, validate_lifecycle)
from repro.serving.telemetry import percentile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "tools"))
import check_trace  # noqa: E402  (the CI trace validator, reused here)

CACHE_LEN = 48
BLOCK = 8
SLOTS = 3


# ---------------------------------------------------------------------------
# Clocks, tracer primitives
# ---------------------------------------------------------------------------

def test_fake_clock_ticks_and_advances():
    c = FakeClock(start=10.0, tick=0.5)
    assert c.now() == 10.0
    assert c.now() == 10.5
    c.advance(2.0)
    assert c.now() == 13.0


def test_null_tracer_is_inert():
    tr = NULL_TRACER
    assert isinstance(tr, NullTracer) and not tr.enabled
    with tr.span("t", "x", rid=1):
        pass
    tr.instant("t", "x")
    tr.counter("t", "c", v=1)
    tr.flow_start("t", "f", "id0")
    tr.flow_end("t", "f", "id0")
    assert tr.events() == []


def test_tracer_records_with_fake_clock():
    clock = FakeClock(start=1.0, tick=1.0)
    tr = Tracer(clock=clock)
    with tr.span("trk", "work", rid=7):    # enters at 1.0, exits at 2.0
        pass
    tr.instant("trk", "mark", rid=7)       # 3.0
    (span, inst) = tr.events()
    assert (span.ph, span.name, span.ts, span.dur) == ("X", "work", 1.0, 1.0)
    assert span.args["rid"] == 7
    assert (inst.ph, inst.ts) == ("i", 3.0)


# ---------------------------------------------------------------------------
# Percentiles + registry merge
# ---------------------------------------------------------------------------

def test_nearest_rank_percentile_exact():
    xs = list(range(1, 101))               # 1..100: pN == N exactly
    assert percentile(xs, 50) == 50
    assert percentile(xs, 90) == 90
    assert percentile(xs, 99) == 99
    assert percentile([42.0], 99) == 42.0
    assert percentile([], 50) == 0.0
    assert percentile([3.0, 1.0, 2.0], 50) == 2.0   # unsorted input


def test_registry_merge_is_lossless():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("n").inc(3)
    b.counter("n").inc(4)
    for v in (1.0, 2.0):
        a.histogram("h").observe(v)
    for v in (100.0, 200.0):
        b.histogram("h").observe(v)
    a.gauge("g").set(1.0)
    b.gauge("g").set(2.0)
    a.merge(b)
    assert a.counter("n").n == 7
    h = a.histogram("h")
    assert h.count == 4
    # the merged p99 is the max raw sample — unreachable from a mean of
    # per-registry means (51.5), which is the cluster bug this fixes
    assert h.percentile(99) == 200.0
    assert a.gauge("g").value == 2.0


def test_stats_view_over_registry():
    m = MetricsRegistry()
    m.counter("generated_tokens").inc(10)
    m.counter("decode_steps").inc(5)
    m.counter("busy_slot_steps").inc(15)
    m.counter("offered_slot_steps").inc(20)
    for v in (1.0, 2.0, 3.0, 4.0):
        m.histogram("ttft_ms").observe(v)
    s = EngineStats.from_registry(m, mode="continuous", wall_s=2.0)
    assert s.generated_tokens == 10 and s.tokens_per_s == 5.0
    assert s.occupancy == 0.75
    assert s.ttft_ms_mean == 2.5
    assert (s.ttft_ms_p50, s.ttft_ms_p99) == (2.0, 4.0)


# ---------------------------------------------------------------------------
# Chrome-trace export
# ---------------------------------------------------------------------------

def test_chrome_trace_schema(tmp_path):
    clock = FakeClock(start=1.0, tick=1.0)
    tr = Tracer(clock=clock)
    with tr.span("replica0", "step"):
        pass
    tr.instant("replica1", "admit", rid=0)
    tr.counter("pool", "blocks", free=4, live=3)
    tr.flow_start("replica0", "preempt_flow", "preempt-0-1")
    tr.flow_end("replica1", "preempt_flow", "preempt-0-1")

    doc = tr.chrome_trace()
    events = doc["traceEvents"]
    names = {e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert names == {"replica0", "replica1", "pool"}
    span = next(e for e in events if e["ph"] == "X")
    assert span["ts"] == 1.0e6 and span["dur"] == 1.0e6   # seconds -> µs
    flows = [e for e in events if e["ph"] in ("s", "f")]
    assert {e["ph"] for e in flows} == {"s", "f"}
    assert len({e["id"] for e in flows}) == 1
    assert next(e for e in flows if e["ph"] == "f")["bp"] == "e"

    # the exported file passes the exact validator CI gates on
    path = tmp_path / "trace.json"
    n = tr.export(path)
    assert n == 5
    assert check_trace.validate(path, min_replica_tracks=2,
                                require_flow=True, require_pool=True) == []
    assert json.loads(path.read_text())["traceEvents"]


# ---------------------------------------------------------------------------
# check_trace: decode round-trip, lifecycle gate, roofline gate
# ---------------------------------------------------------------------------

def _lifecycle_tracer(*, admit=True, roofline=False):
    """A minimal well-formed single-request trace (optionally broken by
    dropping the admission, optionally carrying a roofline counter)."""
    clock = FakeClock(start=1.0, tick=0.5)
    tr = Tracer(clock=clock)
    if admit:
        tr.instant("slot0", "admit", rid=0)
    tr.instant("slot0", "kv_alloc", rid=0, n=2)
    with tr.span("engine", "decode", rid=0):
        pass
    if roofline:
        tr.counter("engine", "roofline", flops_pct=1.5, bytes_pct=40.0)
    tr.instant("slot0", "finish", rid=0)
    tr.instant("slot0", "kv_free", rid=0, n=2)
    return tr


def test_decode_events_round_trips_export():
    """Exported Chrome rows decode back into Event objects that pass the
    same lifecycle check as the live stream: tids map back to tracks via
    thread_name metadata, µs drop back to seconds, args survive."""
    tr = _lifecycle_tracer(roofline=True)
    live = tr.events()
    decoded = check_trace.decode_events(tr.chrome_trace()["traceEvents"])
    assert len(decoded) == len(live)
    for a, b in zip(live, decoded):
        assert (a.ph, a.track, a.name) == (b.ph, b.track, b.name)
        assert b.ts == pytest.approx(a.ts)
        assert b.dur == pytest.approx(a.dur)
        assert b.args == a.args
    validate_lifecycle(decoded)


def test_check_trace_catches_lifecycle_violation(tmp_path):
    """A decode with no admission passes every schema check but must
    fail the decoded lifecycle pass — the exported trace is held to the
    same contract as the in-process stream."""
    tr = _lifecycle_tracer(admit=False)
    path = tmp_path / "bad.json"
    tr.export(path)
    problems = check_trace.validate(path)
    assert any(p.startswith("lifecycle:") for p in problems), problems
    # --skip-lifecycle demotes it back to a schema-only pass
    assert check_trace.validate(path, lifecycle=False) == []


def test_check_trace_require_roofline(tmp_path):
    plain, attr = tmp_path / "plain.json", tmp_path / "attr.json"
    _lifecycle_tracer().export(plain)
    _lifecycle_tracer(roofline=True).export(attr)
    assert check_trace.validate(attr, require_roofline=True) == []
    problems = check_trace.validate(plain, require_roofline=True)
    assert any("roofline" in p for p in problems), problems
    assert check_trace.validate(plain) == []   # not required by default


# ---------------------------------------------------------------------------
# Engine integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_model():
    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _trace(vocab, n=4, max_new=6):
    return [Request([(5 * i + j) % vocab for j in range(4 + i)], max_new,
                    temperature=0.0, rid=i) for i in range(n)]


def test_fake_clock_makes_latency_stats_deterministic(smoke_model):
    """Same trace + same injected clock => bit-equal latency stats,
    independent of host timing (the property every latency regression
    test in this repo leans on)."""
    cfg, model, params = smoke_model

    def run():
        eng = ServeEngine(model, params, max_batch=SLOTS,
                          cache_len=CACHE_LEN, mode="continuous",
                          clock=FakeClock(tick=0.001))
        eng.generate(_trace(cfg.vocab_size))
        return eng.last_stats

    a, b = run(), run()
    assert a.ttft_ms_mean > 0 and a.tpot_ms_p50 > 0
    assert (a.ttft_ms_mean, a.ttft_ms_p50, a.ttft_ms_p99,
            a.tpot_ms_p50, a.tpot_ms_p99) == \
           (b.ttft_ms_mean, b.ttft_ms_p50, b.ttft_ms_p99,
            b.tpot_ms_p50, b.tpot_ms_p99)


def test_tracing_leaves_tokens_identical(smoke_model):
    cfg, model, params = smoke_model
    eng = ServeEngine(model, params, max_batch=SLOTS, cache_len=CACHE_LEN,
                      kv_layout="paged", block_size=BLOCK)
    ref = [r.tokens for r in eng.generate(_trace(cfg.vocab_size))]

    tracer = Tracer()
    eng.set_tracer(tracer)
    try:
        got = [r.tokens for r in eng.generate(_trace(cfg.vocab_size))]
    finally:
        eng.set_tracer(NULL_TRACER)
    assert got == ref
    events = tracer.events()
    validate_lifecycle(events)
    # every request shows the full arc on its slot track
    for want in ("admit", "prefill", "decode", "finish", "kv_free"):
        assert any(e.name == want for e in events), want
    assert eng.last_metrics.histogram("ttft_ms").count == 4


def test_pressure_cluster_trace_flows_and_lifecycle(smoke_model):
    """Starved shared pool: preemptions must appear as matched
    flow-arrow pairs (preempt -> re-admission) and the stream must stay
    lifecycle-well-formed; cluster percentile stats come off the merged
    histograms with one ttft sample per request."""
    cfg, model, params = smoke_model
    cl = ClusterEngine(model, params, replicas=2, total_slots=4,
                       cache_len=CACHE_LEN, block_size=BLOCK, n_blocks=8)
    reqs = [Request(list(range(i, i + 12)), 8, temperature=0.0, rid=i)
            for i in range(6)]
    tracer = Tracer()
    cl.set_tracer(tracer)
    try:
        res = cl.generate(reqs)
    finally:
        cl.set_tracer(NULL_TRACER)
    assert all(len(r.tokens) == 8 for r in res)
    s = cl.last_stats
    assert s.preempted >= 1 and s.requeued == s.preempted

    events = tracer.events()
    validate_lifecycle(events)
    starts = [e for e in events if e.ph == "s"]
    ends = [e for e in events if e.ph == "f"]
    assert len(starts) == s.preempted
    assert sorted(e.fid for e in starts) == sorted(e.fid for e in ends)
    # each flow lands at a later timestamp than it left
    t0 = {e.fid: e.ts for e in starts}
    assert all(e.ts >= t0[e.fid] for e in ends)
    # merged-histogram percentiles: one ttft sample per request, p99
    # taken over raw samples (not a mean of replica means)
    assert cl.last_metrics.histogram("ttft_ms").count == len(reqs)
    assert s.ttft_ms_p99 >= s.ttft_ms_p50 > 0


# ---------------------------------------------------------------------------
# Concurrency: the contracts the threaded cluster driver leans on.
# ---------------------------------------------------------------------------

def test_merge_during_observe_is_consistent():
    """Regression: ``merge`` snapshots the *source* under its lock, so
    merging a registry that another thread is actively observing never
    reads torn state.  The writer bumps a counter and a histogram under
    separate lock acquisitions, so any single-lock view can differ by at
    most one in-flight pair — a torn read would show arbitrary skew (or
    blow up iterating a mutating list)."""
    import threading

    live = MetricsRegistry()
    stop = threading.Event()
    writes = {"n": 0}

    def writer():
        c = live.counter("ticks")
        h = live.histogram("lat")
        while not stop.is_set():
            c.inc()
            h.observe(1.0)
            writes["n"] += 1

    t = threading.Thread(target=writer, daemon=True)
    t.start()
    try:
        for _ in range(300):
            m = MetricsRegistry()
            m.merge(live)
            skew = m.counter("ticks").n - m.histogram("lat").count
            assert skew in (0, 1), f"torn merge: skew={skew}"
    finally:
        stop.set()
        t.join(timeout=30)
    assert not t.is_alive()
    final = MetricsRegistry()
    final.merge(live)
    assert final.counter("ticks").n == writes["n"]
    assert final.histogram("lat").count == writes["n"]


def test_cross_merge_has_no_deadlock():
    """Two threads merging a->b and b->a concurrently: the stable
    (id-ordered) double-lock acquisition cannot deadlock.  Before the
    fix this was a textbook lock-order inversion."""
    import threading

    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("x").inc()
    b.counter("y").inc()

    def cross(dst, src):
        for _ in range(500):
            dst.merge(src)

    t1 = threading.Thread(target=cross, args=(a, b), daemon=True)
    t2 = threading.Thread(target=cross, args=(b, a), daemon=True)
    t1.start()
    t2.start()
    t1.join(timeout=60)
    t2.join(timeout=60)
    assert not t1.is_alive() and not t2.is_alive(), "merge deadlocked"
    a.merge(a)      # self-merge is an explicit no-op, not a deadlock
    assert a.counter("x").n >= 1 and a.counter("y").n >= 1


def test_histogram_and_snapshot_reads_under_writes():
    """Regression: mean/percentile/count and ``snapshot`` copy samples
    under the lock, so concurrent observes never tear a read (and the
    sample count a reader sees is monotone)."""
    import threading

    reg = MetricsRegistry()
    n_obs = 20_000   # bounded: each snapshot copies+sorts the samples,
                     # so an unthrottled writer makes reads quadratic

    def writer():
        h = reg.histogram("lat")
        tl = reg.timeline("occ")
        g = reg.gauge("depth")
        for i in range(n_obs):
            h.observe(float(i % 7))
            tl.record(float(i), float(i % 3))
            g.set(float(i))

    t = threading.Thread(target=writer, daemon=True)
    t.start()
    h = reg.histogram("lat")
    seen = 0
    while t.is_alive():
        n = h.count
        assert n >= seen, "sample count went backwards"
        seen = n
        assert h.mean >= 0.0
        assert 0.0 <= h.percentile(99) <= 6.0 or n == 0
        snap = reg.snapshot()
        assert snap["lat"]["count"] >= 0
    t.join(timeout=30)
    assert not t.is_alive()
    assert h.count == n_obs == len(h.values())


def test_tracer_concurrent_emit(tmp_path):
    """The tracer's event log is locked: N threads emitting on their own
    tracks lose nothing, and the exported Chrome trace still passes the
    CI validator."""
    import threading

    tr = Tracer()
    n_threads, per = 4, 200

    def emitter(i):
        for k in range(per):
            with tr.span(f"replica{i}", "step", k=k):
                tr.instant(f"replica{i}", "tick", k=k)

    threads = [threading.Thread(target=emitter, args=(i,), daemon=True)
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive()
    events = tr.events()
    assert len(events) == n_threads * per * 2     # one span + one instant
    for i in range(n_threads):
        assert sum(1 for e in events
                   if e.track == f"replica{i}") == per * 2
    path = tmp_path / "threaded.json"
    tr.export(str(path))
    assert check_trace.validate(path, min_replica_tracks=n_threads) == []
