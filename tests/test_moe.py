"""MoE dispatch: sort-based capacity routing vs the dense-all-experts oracle."""
import jax
import jax.numpy as jnp
import numpy as np
from helpers import given, settings, st

from repro.models.layers import init_params
from repro.models.moe import moe_apply, moe_apply_dense, moe_templates

settings.register_profile("fast", max_examples=10, deadline=None)
settings.load_profile("fast")

KEY = jax.random.key(5)


def setup(d=32, f=16, e=4):
    return init_params(moe_templates(d, f, e), KEY)


def test_dispatch_matches_dense_oracle():
    p = setup()
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (6, 11, 32))
    got = moe_apply(p, x, top_k=2, capacity_factor=8.0)
    want = moe_apply_dense(p, x, top_k=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_exact_mode_never_drops():
    p = setup()
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (3, 32))
    got = moe_apply(p, x, top_k=2, exact=True)
    want = moe_apply_dense(p, x, top_k=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@given(st.integers(min_value=1, max_value=4))
def test_topk_mass_and_aux(k):
    p = setup(e=8)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (5, 7, 32))
    out, aux = moe_apply(p, x, top_k=k, capacity_factor=8.0, return_aux=True)
    assert out.shape == x.shape
    assert float(aux["drop_frac"]) == 0.0        # cf=8 on e=8: no drops
    assert float(aux["lb_loss"]) > 0.0
    assert bool(jnp.isfinite(out).all())


def test_capacity_drops_are_bounded():
    """With tight capacity some tokens drop, output stays finite and close
    in norm."""
    p = setup()
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (16, 16, 32))
    full = moe_apply(p, x, top_k=2, capacity_factor=8.0)
    tight, aux = moe_apply(p, x, top_k=2, capacity_factor=1.0,
                           return_aux=True)
    assert bool(jnp.isfinite(tight).all())
    # dropped fraction is small for balanced-ish routing
    assert float(aux["drop_frac"]) < 0.5
    assert float(jnp.linalg.norm(tight)) <= float(jnp.linalg.norm(full)) * 1.1


def test_gradients_flow_through_router():
    p = setup()
    x = jax.random.normal(jax.random.fold_in(KEY, 6), (4, 8, 32))
    g = jax.grad(lambda pp: (moe_apply(pp, x, top_k=2,
                                       capacity_factor=8.0) ** 2).sum())(p)
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["gate"]).max()) > 0
