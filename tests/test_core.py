"""Core vector-engine layer: lanes, slides, reductions, interconnect model."""
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import given, settings, st

from repro.core import (decompose_pow2, hierarchical_reduce, mux_count,
                        reduction_drain_cycles, rotate, simd_tree_reduce,
                        sldu_saving, slide, vector_reduction_cycles)
from repro.core.lanes import (reshuffle, stripe, stripe_bytes, unstripe,
                              unstripe_bytes)

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")


# ---------------------------------------------------------------------------
# C2: pow2 slide decomposition.
# ---------------------------------------------------------------------------

@given(st.integers(min_value=-200, max_value=200))
def test_decompose_pow2_sums_to_amount(amount):
    parts = decompose_pow2(amount)
    assert sum(parts) == amount
    for p in parts:
        v = abs(p)
        assert v & (v - 1) == 0 and v > 0
    # <= log2 micro-ops (the paper's area argument)
    if amount:
        assert len(parts) <= abs(amount).bit_length()


@given(st.integers(min_value=-40, max_value=40),
       st.integers(min_value=1, max_value=64))
def test_slide_equals_single_shift(amount, n):
    x = jnp.arange(1, n + 1, dtype=jnp.float32)
    got = np.asarray(slide(x, amount))
    want = np.zeros(n, np.float32)
    src = np.arange(1, n + 1, dtype=np.float32)
    if amount >= 0:
        m = max(0, n - amount)
        want[amount:amount + m] = src[:m]
    else:
        m = max(0, n + amount)
        want[:m] = src[-amount:-amount + m]
    np.testing.assert_allclose(got, want)


@given(st.integers(min_value=0, max_value=257),
       st.sampled_from([4, 8, 16, 32]))
def test_rotate_equals_roll(amount, n):
    x = jnp.arange(n, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(rotate(x, amount)),
                               np.roll(np.arange(n, dtype=np.float32), amount))


# ---------------------------------------------------------------------------
# C1: lane striping / byte layout.
# ---------------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=100),
       st.sampled_from([2, 4, 8, 16]))
def test_stripe_roundtrip(n, lanes):
    x = jnp.arange(n, dtype=jnp.float32)
    assert np.array_equal(np.asarray(unstripe(stripe(x, lanes), n)),
                          np.asarray(x))


def test_stripe_element_to_lane_mapping():
    # element i lives in lane i % L (the Ara2 byte layout, §2)
    lanes = stripe(jnp.arange(12, dtype=jnp.int32), 4)
    for i in range(12):
        assert int(lanes[i % 4, i // 4]) == i


@given(st.sampled_from([np.float64, np.float32, np.uint16]),
       st.sampled_from([2, 4, 8]))
def test_byte_image_roundtrip(dtype, lanes):
    n = 16
    x = np.arange(n).astype(dtype)
    img = stripe_bytes(x, lanes)
    back = unstripe_bytes(img, dtype, n)
    np.testing.assert_array_equal(back, x)


def test_reshuffle_preserves_byte_stream():
    # EW64 -> EW32 re-encode: logical byte stream invariant (§2)
    x = np.arange(8).astype(np.float64)
    img = stripe_bytes(x, 4)
    img32 = reshuffle(img, np.float64, np.float32, 8)
    back = unstripe_bytes(img32, np.float32, 16)
    np.testing.assert_array_equal(back.view(np.float64), x)


# ---------------------------------------------------------------------------
# C3: hierarchical reductions.
# ---------------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=300),
       st.sampled_from([2, 4, 8, 16]))
def test_hierarchical_reduce_equals_sum(n, lanes):
    x = jnp.asarray(np.random.default_rng(n * lanes).standard_normal(n),
                    jnp.float32)
    got = float(hierarchical_reduce(x, lanes))
    np.testing.assert_allclose(got, float(np.sum(np.asarray(x))), rtol=1e-5,
                               atol=1e-5)


@given(st.integers(min_value=1, max_value=65))
def test_simd_tree_reduce(n):
    x = jnp.asarray(np.random.default_rng(n).standard_normal((3, n)),
                    jnp.float32)
    np.testing.assert_allclose(np.asarray(simd_tree_reduce(x, axis=-1)),
                               np.asarray(x).sum(-1), rtol=2e-5, atol=2e-5)


def test_reduction_drain_formula():
    # paper closed form: R*(1+log2(R)) - 1 for power-of-two R (§3)
    import math
    for r in (2, 4, 8):
        assert reduction_drain_cycles(r) == r * (1 + math.log2(r)) - 1
    # non-integer R: R*(1+log2(ceil R)) - (ceil R - R) - 1
    assert reduction_drain_cycles(3.5) == pytest.approx(
        3.5 * (1 + 2) - (4 - 3.5) - 1)


def test_reduction_latency_grows_with_lanes():
    # Fig 4-left: dotproduct ideality decreases with lane count at fixed
    # bytes/lane because the inter-lane tree deepens
    lat = [vector_reduction_cycles(1024, L, 64, 4) -
           1024 / L for L in (2, 4, 8, 16)]
    assert lat == sorted(lat)


# ---------------------------------------------------------------------------
# C2: interconnect cost model (Fig 3).
# ---------------------------------------------------------------------------

def test_mux_count_scaling():
    # all-to-all grows ~quadratically; slideP2 ~n log n
    a2a = [mux_count(l, "all_to_all") for l in (2, 4, 8, 16)]
    p2 = [mux_count(l, "slideP2_tmux") for l in (2, 4, 8, 16)]
    assert a2a[-1] / a2a[-2] > 3.5          # ~4x per lane doubling
    assert p2[-1] / p2[-2] < 2.5            # ~2x per lane doubling


def test_sldu_saving_70pct_at_16_lanes():
    # §3/Fig 2: "saving up to 70% of the estimated area and wires"
    assert 0.65 <= sldu_saving(16) <= 0.75
    # saving grows with lanes
    assert sldu_saving(16) > sldu_saving(8)
