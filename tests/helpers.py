"""Shared test utilities, including an optional-``hypothesis`` shim.

Property tests import ``given``/``settings``/``st`` (and the stateful
API: ``RuleBasedStateMachine``/``rule``/``invariant``/``precondition``/
``run_state_machine_as_test``) from here instead of from ``hypothesis``
directly.  When hypothesis is installed the real objects are
re-exported; when it is missing the shim turns every ``@given``-decorated
test (and every ``run_state_machine_as_test`` call) into a skipped test
with a clear reason, so tier-1 collection never errors on the missing
dependency.  Suites that want coverage either way pair each hypothesis
test with a seeded-PRNG fallback gated on ``HAS_HYPOTHESIS``.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    from hypothesis.stateful import (RuleBasedStateMachine,  # noqa: F401
                                     invariant, precondition, rule,
                                     run_state_machine_as_test)
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

    class _Strategy:
        """Opaque stand-in so module-level strategy expressions evaluate."""

        def __init__(self, name="strategy"):
            self._name = name

        def __call__(self, *a, **kw):
            return _Strategy(self._name)

        def __getattr__(self, item):
            return _Strategy(f"{self._name}.{item}")

    class _StrategiesModule:
        def __getattr__(self, item):
            return _Strategy(f"st.{item}")

    st = _StrategiesModule()

    def given(*_args, **_kwargs):
        def deco(fn):
            # NB: no functools.wraps - copying fn's signature would make
            # pytest treat the hypothesis-drawn arguments as fixtures.
            def skipper():
                pytest.skip("hypothesis not installed (see "
                            "requirements-dev.txt); property test skipped")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    class _Settings:
        """No-op hypothesis.settings replacement (decorator + profiles)."""

        def __init__(self, *a, **kw):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*a, **kw):
            pass

        @staticmethod
        def load_profile(*a, **kw):
            pass

    settings = _Settings

    class RuleBasedStateMachine:
        """Stand-in base so state-machine classes still define cleanly."""

        def __init__(self):
            pass

    def _passthrough_decorator(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    rule = _passthrough_decorator
    invariant = _passthrough_decorator
    precondition = _passthrough_decorator

    def run_state_machine_as_test(machine_cls, *, settings=None):
        pytest.skip("hypothesis not installed (see requirements-dev.txt); "
                    "stateful property test skipped")


def run_with_devices(script: str, n_devices: int = 8, timeout=600):
    """Run a python snippet in a subprocess with N fake CPU devices.
    The snippet must print 'PASS' on success."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    # every snippet gets the version-compat mesh constructor plus a
    # jax.shard_map alias (older jax only has jax.experimental.shard_map)
    prelude = textwrap.dedent("""\
        from repro.launch.mesh import make_mesh
        import jax as _jax_compat
        if not hasattr(_jax_compat, "shard_map"):
            from jax.experimental.shard_map import shard_map as _shard_map
            _jax_compat.shard_map = _shard_map
        """)
    proc = subprocess.run([sys.executable, "-c",
                           prelude + textwrap.dedent(script)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    assert "PASS" in proc.stdout, f"stdout:\n{proc.stdout[-2000:]}" \
                                  f"\nstderr:\n{proc.stderr[-2000:]}"
    return proc.stdout
