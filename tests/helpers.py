"""Shared test utilities."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_with_devices(script: str, n_devices: int = 8, timeout=600):
    """Run a python snippet in a subprocess with N fake CPU devices.
    The snippet must print 'PASS' on success."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    assert "PASS" in proc.stdout, f"stdout:\n{proc.stdout[-2000:]}" \
                                  f"\nstderr:\n{proc.stderr[-2000:]}"
    return proc.stdout
