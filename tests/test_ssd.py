"""Mamba2 SSD: chunked (xla), Pallas, and single-step vs the sequential
recurrence oracle; chunk-size invariance property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import ssd_ref
from repro.kernels.ssd_scan import ssd_pallas, ssd_step_xla, ssd_xla

KEY = jax.random.key(1)


def make_inputs(b=2, s=128, h=4, p=16, g=2, n=8):
    f = jax.random.fold_in
    x = jax.random.normal(f(KEY, 1), (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(f(KEY, 2), (b, s, h))) * 0.1
    a_log = jax.random.normal(f(KEY, 3), (h,)) * 0.5
    bm = jax.random.normal(f(KEY, 4), (b, s, g, n)) * 0.3
    cm = jax.random.normal(f(KEY, 5), (b, s, g, n)) * 0.3
    return x, dt, a_log, bm, cm


@pytest.mark.parametrize("chunk", [16, 32, 128])
def test_chunked_matches_sequential(chunk):
    args = make_inputs()
    yr, hr = ssd_ref(*args)
    yx, hx = ssd_xla(*args, chunk=chunk)
    np.testing.assert_allclose(np.asarray(yx), np.asarray(yr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(hx), np.asarray(hr), atol=2e-5)


@pytest.mark.parametrize("g", [1, 2, 4])
def test_group_broadcast(g):
    args = make_inputs(g=g, h=4)
    yr, _ = ssd_ref(*args)
    yx, _ = ssd_xla(*args, chunk=32)
    np.testing.assert_allclose(np.asarray(yx), np.asarray(yr), atol=2e-5)


def test_pallas_matches_sequential():
    args = make_inputs()
    yr, hr = ssd_ref(*args)
    yp, hp = ssd_pallas(*args, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(hr), atol=2e-5)


def test_d_skip_and_h0():
    x, dt, a_log, bm, cm = make_inputs(s=64)
    d_skip = jnp.ones((4,)) * 0.5
    h0 = jax.random.normal(jax.random.fold_in(KEY, 9), (2, 4, 16, 8)) * 0.2
    yr, hr = ssd_ref(x, dt, a_log, bm, cm, d_skip=d_skip, h0=h0)
    yx, hx = ssd_xla(x, dt, a_log, bm, cm, d_skip=d_skip, h0=h0, chunk=16)
    np.testing.assert_allclose(np.asarray(yx), np.asarray(yr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(hx), np.asarray(hr), atol=2e-5)


def test_step_equals_prefix_of_scan():
    """Decode recurrence == chunked scan, token by token."""
    x, dt, a_log, bm, cm = make_inputs(s=16)
    yr, _ = ssd_xla(x, dt, a_log, bm, cm, chunk=8)
    h = jnp.zeros((2, 4, 16, 8))
    for t in range(16):
        y, h = ssd_step_xla(h, x[:, t], dt[:, t], a_log, bm[:, t], cm[:, t])
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr[:, t]),
                                   atol=3e-5)


def test_gradients_finite():
    args = make_inputs(s=64)
    g = jax.grad(lambda x: ssd_xla(x, *args[1:])[0].sum())(args[0])
    assert bool(jnp.isfinite(g).all())


def test_decay_stability():
    """Very large dt must decay the state, not blow it up (A < 0)."""
    x, dt, a_log, bm, cm = make_inputs(s=64)
    y, h = ssd_xla(x, dt * 100.0, a_log, bm, cm, chunk=16)
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(h).all())
