import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests run on the single real CPU device; only the dry-run uses fake
# devices (in subprocesses).  Do NOT set xla_force_host_platform_device_count
# here (dry-run contract).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
