"""Checkpointing: exact roundtrip, latest/cleanup, async, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_mesh
from repro.train import checkpoint as ckpt

KEY = jax.random.key(11)


def tree(seed=0):
    f = jax.random.fold_in
    return {
        "a": jax.random.normal(f(KEY, seed), (16, 8), jnp.float32),
        "nested": {"b": jax.random.normal(f(KEY, seed + 1), (4,),
                                          jnp.bfloat16),
                   "step": jnp.int32(7)},
        "lst": [jnp.ones((2, 2)), (jnp.zeros((3,)), jnp.float32(2.5))],
    }


def assert_tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_roundtrip_exact(tmp_path):
    t = tree()
    ckpt.save(str(tmp_path), 5, t)
    step, back = ckpt.restore(str(tmp_path))
    assert step == 5
    assert_tree_equal(t, back)


def test_latest_and_cleanup(tmp_path):
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree(s), keep_last=3)
    assert ckpt.latest_step(str(tmp_path)) == 5
    assert ckpt.list_steps(str(tmp_path)) == [3, 4, 5]


def test_async_save(tmp_path):
    t = tree(9)
    th = ckpt.save(str(tmp_path), 2, t, async_=True)
    th.join()
    step, back = ckpt.restore(str(tmp_path))
    assert step == 2
    assert_tree_equal(t, back)


def test_restore_specific_step(tmp_path):
    ckpt.save(str(tmp_path), 1, tree(1))
    ckpt.save(str(tmp_path), 2, tree(2))
    step, back = ckpt.restore(str(tmp_path), step=1)
    assert step == 1
    assert_tree_equal(tree(1), back)


def test_no_partial_checkpoints(tmp_path):
    """A .tmp dir (simulated crash mid-write) is never listed."""
    ckpt.save(str(tmp_path), 1, tree())
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_elastic_restore_with_shardings(tmp_path):
    """Restore onto explicit (trivial 1-device) shardings - the elastic
    re-mesh path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_mesh((1,), ("data",))
    t = tree(3)
    ckpt.save(str(tmp_path), 7, t)
    sh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), t)
    step, back = ckpt.restore(str(tmp_path), shardings=sh)
    assert_tree_equal(t, back)
