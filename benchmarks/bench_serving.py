"""Serving scheduler benchmark: lock-step groups vs continuous batching
(dense slot KV) vs continuous batching with the paged KV layout.

The serving analog of the paper's fixed-FPU-budget sweep (Ara2 §7.1:
eight 2-lane cores beat one 16-lane core at equal FPU count because eight
independent issue streams remove the single-dispatcher bottleneck).  Here
the FPU budget is the ``max_batch`` slot pool and the trace mixes short
and long requests (``max_new_tokens`` in {8, 64}): lock-step pins every
slot to its group's slowest member, continuous batching refills freed
slots immediately.

The paged run demonstrates the memory-side claim (Ara2's bottleneck
analysis: memory organization, not raw FPU count, gates utilization): its
block pool holds exactly the dense layout's KV footprint
(``max_batch * cache_len`` positions), yet it admits a trace whose
*summed* KV footprint exceeds that capacity, because finished requests
return their blocks immediately instead of holding a worst-case
``cache_len`` reservation.  The bench checks paged greedy tokens match
the dense run token-for-token and exits non-zero with a per-request
diff summary on divergence, so CI catches layout drift diagnosably.

The prefill-memory report makes the chunked-prefill claim a measured
number: the dense path hands a batch-1 ``(L, Hkv, prompt_len, hd)`` K/V
intermediate from prefill to the block scatter, the paged path's chunk
step only ever holds one ``block_size`` chunk — both sizes come from the
abstract shapes, and the compiled temp footprints from XLA's
``memory_analysis`` when the backend reports them.

A second trace runs a **scan family** (ssm: xlstm) through the same
lock-step-vs-continuous comparison: its recurrent state serves from the
slot-addressable layout (``repro.models.slot_state``), so freed slots
refill immediately instead of idling to the group barrier — the same
issue-stream argument, demonstrated on a cache with no KV strips at all.
Tokens must match byte-for-byte and continuous must win occupancy and
decode-step count (both deterministic; tok/s is reported, not asserted,
to keep CI timing-independent).

A third trace hammers one **shared prompt prefix** (the production
shape: system prompts / few-shot templates) through the paged engine
with the prefix cache off vs on: the cached run must emit byte-identical
tokens while admitting most prompt blocks by reference — hit rate is
asserted > 0; TTFT and pool peak are reported (cache on skips prefill
chunks and shares blocks, so both should drop, but wall-clock is not
asserted to keep CI timing-independent).

Emits ``name,us_per_call,derived`` CSV rows like the other benches:
  serving_lockstep,<wall_us>,tok/s=...;occ=...
  serving_continuous,<wall_us>,tok/s=...;occ=...
  serving_paged,<wall_us>,tok/s=...;occ=...;block_util=...;compiles=...
  serving_speedup,,continuous/lockstep=...
  serving_paged_admission,,footprint=...;capacity=...;admitted=...
  serving_prefill_mem,,dense_kv_intermediate=...;paged_chunk_kv=...;...
  serving_prefix_off,<wall_us>,ttft_ms=...;pool_peak=...;hits=0
  serving_prefix_on,<wall_us>,ttft_ms=...;pool_peak=...;hits=...
  serving_prefix_summary,,ttft=...;hit_rate=...;pool_peak=...
  serving_scan_ssm_lockstep,<wall_us>,tok/s=...;occ=...
  serving_scan_ssm_continuous,<wall_us>,tok/s=...;occ=...
  serving_scan_speedup,,continuous/lockstep=...
  serving_latency_{continuous,paged},,ttft_ms_p50=...;...;tpot_ms_p50=...
  serving_trace,<wall_us>,events=...;spans=...;lifecycle=ok;tokens=...
  serving_attr_decode,,fu_utilization=...;achieved_gflops_s=...;bottleneck=...
  serving_attr_prefill,,bottleneck=...;chunks=...;gflops_s=...
  serving_nulltracer_overhead,,ns_per_guarded_call=...;bound=...
  serving_attr_overhead,,ns_per_guarded_call=...;bound=...

The trailing rows are the observability gates (docs/observability.md):
percentile latency rows come off the :class:`MetricsRegistry` every run
now feeds; the trace row re-runs the paged trace with a live
:class:`Tracer` *and* :class:`Attributor` attached and asserts tokens
stay byte-identical (tracing/attribution must never perturb scheduling
or sampling) and the event stream is lifecycle-well-formed; the
``serving_attr_*`` rows surface the roofline-joined utilization
accounting (achieved FLOP/s and bytes/s vs peak, ``fu_utilization``,
per-phase bottleneck verdicts) that ``tools/bench_compare.py`` gates
against ``benchmarks/baselines/``; and the overhead rows bound the
disabled-path cost of the default :class:`NullTracer` /
:class:`NullAttributor`.

``--smoke`` shrinks the trace/model work for the CI CPU regression gate;
``--json PATH`` additionally dumps every row for the CI artifact;
``--trace PATH`` exports the traced re-run as Chrome-trace JSON
(validated in CI by ``tools/check_trace.py``).
"""
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks.common import check_tokens, emit, trace_bursty, write_json

MAX_BATCH = 4
CACHE_LEN = 128
BLOCK = 16
PROMPT_LEN = 8
SHORT_NEW, LONG_NEW = 8, 64
N_REQS = 16


def _trace(vocab, n_reqs, short_new, long_new):
    # the shared bursty generator at burst=1 is this bench's historic
    # interleaved long/short trace byte-for-byte (baselines unchanged)
    return trace_bursty(vocab, n=n_reqs, prompt_len=PROMPT_LEN,
                        short_new=short_new, long_new=long_new)


def _compiled_temp_bytes(fn, *args):
    """Temp-buffer bytes of the compiled fn, or None when the backend's
    memory analysis is unavailable (args may be ShapeDtypeStructs)."""
    try:
        ma = jax.jit(fn).lower(*args).compile().memory_analysis()
        return None if ma is None else int(ma.temp_size_in_bytes)
    except Exception:
        return None


def _prefill_mem_report(model, params, cache_len, block_size, smoke):
    """Measure the prefill path's peak transient KV bytes, dense-then-
    scatter vs chunked paged, for a worst-case ``cache_len`` prompt.

    The dense-layout admission runs ``model.prefill`` and materializes a
    batch-1 (L, Hkv, prompt_len, hd) K/V cache; the paged chunk step
    (``model.prefill_paged``) writes block-sized pieces straight into the
    pool, so its largest KV-side value is one (Hkv, block_size, hd) chunk
    per layer scan step.  Both are read off the abstract output/jaxpr
    shapes; compiled temp totals are reported alongside when XLA's
    memory_analysis is available on this backend."""
    from repro.serving import blocks_needed
    batch = {"tokens": jnp.zeros((1, cache_len), jnp.int32)}
    cache = jax.eval_shape(
        lambda p, b: model.prefill(p, b, cache_len=None)[1], params, batch)
    itemsize = cache["k"].dtype.itemsize
    dense_kv = 2 * cache["k"].size * itemsize        # k + v
    l, _, hkv, s, hd = cache["k"].shape
    assert s == cache_len
    paged_chunk_kv = 2 * hkv * block_size * hd * itemsize

    max_blocks = blocks_needed(cache_len, block_size)
    n_blocks = MAX_BATCH * max_blocks + 1
    pcache = jax.eval_shape(lambda: model.paged_cache_init(
        batch=MAX_BATCH, n_blocks=n_blocks, block_size=block_size,
        max_blocks=max_blocks, dtype=cache["k"].dtype))
    i32 = jax.ShapeDtypeStruct((), jnp.int32)
    chunk_batch = {"tokens": jax.ShapeDtypeStruct((1, block_size),
                                                  jnp.int32)}
    dense_tmp = _compiled_temp_bytes(
        lambda p, b: model.prefill(p, b, cache_len=None), params, batch)
    paged_tmp = _compiled_temp_bytes(
        model.prefill_paged, params, pcache, chunk_batch, i32, i32, i32)

    # the removed materialization, as numbers: the dense path's handed-off
    # KV intermediate stacks all L layers of the full prompt, the chunk
    # transient is one block of one layer (the scan carry updates the
    # pool slice in place)
    assert dense_kv == paged_chunk_kv * l * (cache_len // block_size)
    measured = ""
    if dense_tmp is not None and paged_tmp is not None:
        measured = f";dense_tmp={dense_tmp}B;paged_chunk_tmp={paged_tmp}B"
        if not smoke:
            # compiled-temp check: one chunk step's whole scratch
            # footprint must undercut the intermediate the old path
            # materialized.  Gated off the smoke shapes, where the KV
            # intermediate (8KB) is dwarfed by fixed per-call temps and
            # the margin would be one XLA padding change wide.
            assert paged_tmp < dense_tmp + dense_kv, (paged_tmp, dense_tmp,
                                                      dense_kv)
    emit("serving_prefill_mem", "",
         f"dense_kv_intermediate={dense_kv}B;paged_chunk_kv="
         f"{paged_chunk_kv}B;ratio={dense_kv / paged_chunk_kv:.1f}x"
         f"({l} layers x prompt {cache_len} / block {block_size})"
         f"{measured}")
    return dense_kv, paged_chunk_kv


def _scan_family_report(smoke: bool):
    """Continuous-vs-lockstep on a scan family (ssm: xlstm), slot state
    served from the slot-addressable recurrent layout.

    Uniform prompt lengths (so lockstep's left-padded group prefill is
    position-exact and tokens must match byte-for-byte) with mixed decode
    budgets: lockstep pins every slot to its group's slowest member,
    continuous refills freed slots.  Asserts the deterministic wins
    (occupancy and decode-step count) and token identity; tok/s is
    reported for the JSON artifact but not asserted (CI timing noise)."""
    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.serving import Request, ServeEngine

    cfg = smoke_config("xlstm-350m")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n_reqs = 8 if smoke else N_REQS
    long_new = 16 if smoke else LONG_NEW
    reqs = _trace(cfg.vocab_size, n_reqs, SHORT_NEW, long_new)

    stats, tokens = {}, {}
    for name in ("lockstep", "continuous"):
        eng = ServeEngine(model, params, max_batch=MAX_BATCH,
                          cache_len=32 if smoke else CACHE_LEN, mode=name)
        eng.generate([Request(list(range(PROMPT_LEN)), 2, rid=-1)
                      for _ in range(MAX_BATCH)])   # warmup compile
        res = eng.generate(reqs)
        tokens[name] = [r.tokens for r in res]
        s = stats[name] = eng.last_stats
        emit(f"serving_scan_ssm_{name}", s.wall_s * 1e6,
             f"tok/s={s.tokens_per_s:.1f};occ={s.occupancy:.2f};"
             f"steps={s.decode_steps};ttft_ms={s.ttft_ms_mean:.1f}")

    check_tokens("bench_serving", "scan_ssm_lockstep", tokens["lockstep"],
                 "scan_ssm_continuous", tokens["continuous"],
                 [r.rid for r in reqs])
    cont, lock = stats["continuous"], stats["lockstep"]
    assert cont.occupancy > lock.occupancy, (cont.occupancy, lock.occupancy)
    assert cont.decode_steps < lock.decode_steps, (cont.decode_steps,
                                                   lock.decode_steps)
    speedup = cont.tokens_per_s / max(lock.tokens_per_s, 1e-9)
    emit("serving_scan_speedup", "",
         f"continuous/lockstep={speedup:.2f}x;occ={cont.occupancy:.2f}"
         f"vs{lock.occupancy:.2f};steps={cont.decode_steps}"
         f"vs{lock.decode_steps} (ssm family, slot-addressable "
         "recurrent state)")


def _prefix_cache_report(smoke: bool):
    """Shared-prefix trace through the paged engine, prefix cache off vs
    on.

    Every request opens with the same system-prompt-shaped prefix
    (whole ``BLOCK``-sized spans, so the chain keys resolve) followed by
    a short per-request tail.  With the cache on, the first admission
    registers the prefix blocks and every later one references them
    (refcount++, prefill fast-forwarded past the hit chunks), so tokens
    must stay byte-identical to the cold run while TTFT and the pool
    peak drop.  Hit rate and token identity are asserted; the timing
    deltas are reported only (CI timing noise)."""
    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.serving import Request, ServeEngine

    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cache_len = 32 if smoke else CACHE_LEN
    n_reqs = 6 if smoke else 12
    prefix_blocks = 1 if smoke else 3
    prefix = [(3 * j + 1) % cfg.vocab_size
              for j in range(prefix_blocks * BLOCK)]
    reqs = [Request(prefix + [(11 * i + j) % cfg.vocab_size
                              for j in range(4)],
                    SHORT_NEW, temperature=0.0, rid=i)
            for i in range(n_reqs)]

    stats, tokens = {}, {}
    for name, pc in (("off", False), ("on", True)):
        eng = ServeEngine(model, params, max_batch=2, cache_len=cache_len,
                          kv_layout="paged", block_size=BLOCK,
                          prefix_cache=pc)
        # warmup compile with sub-block prompts: registers nothing, so
        # the timed trace still sees one cold admission then pure hits
        eng.generate([Request(list(range(PROMPT_LEN)), 2, rid=-1)
                      for _ in range(2)])
        res = eng.generate(reqs)
        tokens[name] = [r.tokens for r in res]
        s = stats[name] = eng.last_stats
        emit(f"serving_prefix_{name}", s.wall_s * 1e6,
             f"ttft_ms={s.ttft_ms_mean:.2f};"
             f"pool_peak={s.block_util_peak:.2f};hits={s.prefix_hits};"
             f"reused={s.prefix_tokens_reused}")

    check_tokens("bench_serving", "prefix_off", tokens["off"],
                 "prefix_on", tokens["on"], [r.rid for r in reqs])
    on, off = stats["on"], stats["off"]
    assert on.prefix_hits > 0, \
        "prefix cache saw no hits on a shared-prefix trace"
    assert off.prefix_hits == 0, off.prefix_hits
    total_prompt = sum(len(r.prompt) for r in reqs)
    emit("serving_prefix_summary", "",
         f"ttft_on={on.ttft_ms_mean:.2f}ms_vs_off={off.ttft_ms_mean:.2f}"
         f"ms;hit_rate={on.prefix_tokens_reused / total_prompt:.2f};"
         f"pool_peak_on={on.block_util_peak:.2f}"
         f"vs{off.block_util_peak:.2f} "
         f"({n_reqs} reqs x {prefix_blocks * BLOCK}-token shared prefix)")


def _telemetry_report(model, params, vocab, n_reqs, long_new, cache_len,
                      n_blocks, base_tokens, trace_path):
    """Traced + attributed re-run of the paged trace: tracing and
    utilization attribution must not change tokens (the
    zero-observer-effect contract — this is the conformance gate the
    acceptance criteria name), the recorded event stream must be
    lifecycle-well-formed and carry the ``roofline`` achieved-vs-peak
    counter track, and the default :class:`NullTracer` /
    :class:`NullAttributor` guards must be cheap enough to leave step
    timing untouched (docs/observability.md).  ``--trace PATH``
    additionally exports the Chrome-trace JSON for Perfetto /
    tools/check_trace.py."""
    from repro.serving import (NULL_ATTR, NULL_TRACER, Attributor, Request,
                               ServeEngine, Tracer, validate_lifecycle)

    eng = ServeEngine(model, params, max_batch=MAX_BATCH,
                      cache_len=cache_len, mode="continuous",
                      kv_layout="paged", block_size=BLOCK,
                      n_blocks=n_blocks)
    eng.generate([Request(list(range(PROMPT_LEN)), 2, rid=-1)
                  for _ in range(MAX_BATCH)])   # warmup compile
    tracer = Tracer()
    eng.set_tracer(tracer)
    eng.set_attributor(Attributor())
    reqs = _trace(vocab, n_reqs, SHORT_NEW, long_new)
    res = eng.generate(reqs)
    eng.set_tracer(NULL_TRACER)
    eng.set_attributor(NULL_ATTR)
    # observer-effect gate: the traced+attributed run's bytes must match
    # the untraced paged run of the same trace exactly
    check_tokens("bench_serving", "paged", base_tokens, "paged_traced",
                 [r.tokens for r in res], [r.rid for r in reqs])
    events = tracer.events()
    validate_lifecycle(events)
    spans = sum(1 for e in events if e.ph == "X")
    s = eng.last_stats
    emit("serving_trace", s.wall_s * 1e6,
         f"events={len(events)};spans={spans};lifecycle=ok;"
         f"tokens=identical({n_reqs})")
    assert any(e.name == "roofline" for e in events), \
        "attributed traced run emitted no roofline counter track"

    # attribution rows (the serving_attr_* gates): achieved FLOP/s and
    # bytes/s vs the machine roofline, the engine fu_utilization figure,
    # and the per-phase bottleneck verdicts.  On the CI CPU the absolute
    # utilization is tiny and the expected regime is the paper's §6
    # short-vector story (decode issue- or memory-bound, never
    # compute-bound at smoke shapes) — the row just has to be present,
    # self-consistent, and inside the baseline's tolerance band.
    assert s.achieved_flops_per_s > 0 and s.bottleneck, s
    assert 0.0 < s.fu_utilization < 1.0, s.fu_utilization
    m = eng.last_metrics
    verdicts = ";".join(f"{k}={v}" for k, v in s.verdict_counts.items())
    emit("serving_attr_decode", "",
         f"fu_utilization={s.fu_utilization:.3e};"
         f"achieved_gflops_s={s.achieved_flops_per_s / 1e9:.3f};"
         f"achieved_gbytes_s={s.achieved_bytes_per_s / 1e9:.3f};"
         f"ai={s.decode_ai:.2f};ridge={s.ridge_ai:.2f};"
         f"bottleneck={s.bottleneck};{verdicts}")
    pf_ms = sum(m.histogram("attr_prefill_ms").samples)
    pf_fl = sum(m.histogram("attr_prefill_flops").samples)
    n_chunks = m.histogram("attr_prefill_ms").count
    emit("serving_attr_prefill", "",
         f"bottleneck={s.prefill_bottleneck};chunks={n_chunks};"
         f"gflops_s={pf_fl / max(pf_ms, 1e-9) / 1e6:.3f};"
         f"chunk_ms_mean={pf_ms / max(n_chunks, 1):.2f}")
    if trace_path:
        n = tracer.export(trace_path)
        print(f"[bench] wrote {trace_path} ({n} trace events)",
              file=sys.stderr)

    # NullTracer overhead: the hot-path guard (``if tracer.enabled:``) on
    # the default tracer, per call.  A decode step takes O(10) of these;
    # the bound is deliberately loose (CI CPU noise) — the point is
    # catching an accidentally-instantiated recording tracer or an
    # attribute-heavy guard, either of which blows it by orders of
    # magnitude.
    tr = NULL_TRACER
    n_calls = 200_000
    t0 = time.perf_counter()
    for _ in range(n_calls):
        if tr.enabled:
            tr.instant("t", "x")
    ns = (time.perf_counter() - t0) / n_calls * 1e9
    bound = 2000.0
    assert ns < bound, f"NullTracer guard costs {ns:.0f}ns/call"
    emit("serving_nulltracer_overhead", "",
         f"ns_per_guarded_call={ns:.1f};bound={bound:.0f}ns;"
         f"calls={n_calls}")

    # NullAttributor overhead: the same contract for the attribution
    # guard (one ``if attr.enabled:`` per decode launch + one per prefill
    # chunk) — attribution off must cost one attribute check, nothing
    # else.
    at = NULL_ATTR
    t0 = time.perf_counter()
    for _ in range(n_calls):
        if at.enabled:
            at.record_step(None, None, "t", t0=0, t_disp=0, t1=0,
                           active=0, width=1, cost=None)
    ns = (time.perf_counter() - t0) / n_calls * 1e9
    assert ns < bound, f"NullAttributor guard costs {ns:.0f}ns/call"
    emit("serving_attr_overhead", "",
         f"ns_per_guarded_call={ns:.1f};bound={bound:.0f}ns;"
         f"calls={n_calls}")


def run(smoke: bool = False, json_path: str | None = None,
        trace_path: str | None = None):
    from benchmarks.common import reset_rows
    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.serving import Request, ServeEngine

    reset_rows()

    cache_len = 32 if smoke else CACHE_LEN
    n_reqs = 8 if smoke else N_REQS
    long_new = 16 if smoke else LONG_NEW
    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    reqs = _trace(cfg.vocab_size, n_reqs, SHORT_NEW, long_new)

    # paged pool sized to the dense layout's exact KV footprint: admission
    # beyond it can only come from block recycling, not extra memory
    pool_positions = MAX_BATCH * cache_len
    engines = {
        "lockstep": dict(mode="lockstep"),
        "continuous": dict(mode="continuous"),
        "paged": dict(mode="continuous", kv_layout="paged",
                      block_size=BLOCK,
                      n_blocks=pool_positions // BLOCK + 1),
    }
    stats, tokens = {}, {}
    for name, kw in engines.items():
        eng = ServeEngine(model, params, max_batch=MAX_BATCH,
                          cache_len=cache_len, **kw)
        # warmup: compile prefill/decode/sample outside the timed run
        eng.generate([Request(list(range(PROMPT_LEN)), 2, rid=-1)
                      for _ in range(MAX_BATCH)])
        res = eng.generate(reqs)
        tokens[name] = [r.tokens for r in res]
        s = eng.last_stats
        stats[name] = s
        extra = ""
        if name == "paged":
            extra = (f";block_util={s.block_util_peak:.2f}"
                     f";compiles={s.prefill_compiles}")
        emit(f"serving_{name}", s.wall_s * 1e6,
             f"tok/s={s.tokens_per_s:.1f};occ={s.occupancy:.2f};"
             f"steps={s.decode_steps};ttft_ms={s.ttft_ms_mean:.1f};"
             f"preempted={s.preempted};requeued={s.requeued}" + extra)

    # exit non-zero with a per-request diff summary on divergence (a bare
    # assert left CI logs undiagnosable)
    check_tokens("bench_serving", "continuous", tokens["continuous"],
                 "paged", tokens["paged"], [r.rid for r in reqs])

    # percentile latency rows straight off the metrics registry each run
    # feeds (EngineStats.from_registry); CI gates on their presence
    for name in ("continuous", "paged"):
        s = stats[name]
        emit(f"serving_latency_{name}", "",
             f"ttft_ms_p50={s.ttft_ms_p50:.1f};p90={s.ttft_ms_p90:.1f};"
             f"p99={s.ttft_ms_p99:.1f};tpot_ms_p50={s.tpot_ms_p50:.2f};"
             f"p99={s.tpot_ms_p99:.2f};n={n_reqs}")

    speedup = (stats["continuous"].tokens_per_s
               / max(stats["lockstep"].tokens_per_s, 1e-9))
    emit("serving_speedup", "",
         f"continuous/lockstep={speedup:.2f}x "
         f"(trace: {n_reqs} reqs, max_new {SHORT_NEW}/{long_new}, "
         f"{MAX_BATCH} slots)")

    # admission headline: summed trace KV footprint vs the pool capacity
    # (== dense max_batch * cache_len) that nonetheless served it
    footprint = sum(len(r.prompt) + r.max_new_tokens - 1 for r in reqs)
    served = all(len(t) == r.max_new_tokens
                 for t, r in zip(tokens["paged"], reqs))
    assert footprint > pool_positions, \
        "trace too small to demonstrate block recycling"
    assert served, "paged engine failed to serve the full trace"
    emit("serving_paged_admission", "",
         f"footprint={footprint}pos;capacity={pool_positions}pos;"
         f"admitted=all({n_reqs});block_util_peak="
         f"{stats['paged'].block_util_peak:.2f}")

    # prefill transient memory: the dense (L, Hkv, prompt, hd) KV
    # intermediate vs the chunked path's single-block transient
    _prefill_mem_report(model, params, cache_len, BLOCK, smoke)

    # shared-prefix trace: refcounted prefix cache off vs on, tokens
    # byte-identical, hit rate asserted
    _prefix_cache_report(smoke)

    # scan family (slot-addressable recurrent state): same scheduler
    # comparison, no KV strips involved
    _scan_family_report(smoke)

    # telemetry gates: traced re-run (byte-identical tokens + well-formed
    # lifecycle) and the NullTracer disabled-path overhead bound
    _telemetry_report(model, params, cfg.vocab_size, n_reqs, long_new,
                      cache_len, pool_positions // BLOCK + 1,
                      tokens["paged"], trace_path)
    if json_path:
        write_json(json_path, bench="bench_serving", smoke=smoke)
    return speedup


if __name__ == "__main__":
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from benchmarks.common import json_path_arg, path_arg
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv, json_path=json_path_arg(sys.argv),
        trace_path=path_arg(sys.argv, "--trace"))
