"""Serving scheduler benchmark: continuous batching vs lock-step groups.

The serving analog of the paper's fixed-FPU-budget sweep (Ara2 §7.1:
eight 2-lane cores beat one 16-lane core at equal FPU count because eight
independent issue streams remove the single-dispatcher bottleneck).  Here
the FPU budget is the ``max_batch`` slot pool and the trace mixes short
and long requests (``max_new_tokens`` in {8, 64}): lock-step pins every
slot to its group's slowest member, continuous batching refills freed
slots immediately.

Emits ``name,us_per_call,derived`` CSV rows like the other benches:
  serving_lockstep,<wall_us>,tok/s=...;occ=...
  serving_continuous,<wall_us>,tok/s=...;occ=...
  serving_speedup,,continuous/lockstep=...
"""
import jax

from benchmarks.common import emit

MAX_BATCH = 4
CACHE_LEN = 128
PROMPT_LEN = 8
SHORT_NEW, LONG_NEW = 8, 64
N_REQS = 16


def _trace(vocab):
    from repro.serving import Request
    reqs = []
    for i in range(N_REQS):
        prompt = [(7 * i + j) % vocab for j in range(PROMPT_LEN)]
        max_new = SHORT_NEW if i % 2 else LONG_NEW
        reqs.append(Request(prompt, max_new, temperature=0.0, rid=i))
    return reqs


def run():
    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.serving import Request, ServeEngine

    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    reqs = _trace(cfg.vocab_size)

    stats = {}
    for mode in ("lockstep", "continuous"):
        eng = ServeEngine(model, params, max_batch=MAX_BATCH,
                          cache_len=CACHE_LEN, mode=mode)
        # warmup: compile prefill/decode/sample outside the timed run
        eng.generate([Request(list(range(PROMPT_LEN)), 2, rid=-1)
                      for _ in range(MAX_BATCH)])
        eng.generate(reqs)
        s = eng.last_stats
        stats[mode] = s
        emit(f"serving_{mode}", s.wall_s * 1e6,
             f"tok/s={s.tokens_per_s:.1f};occ={s.occupancy:.2f};"
             f"steps={s.decode_steps};ttft_ms={s.ttft_ms_mean:.1f}")
    speedup = (stats["continuous"].tokens_per_s
               / max(stats["lockstep"].tokens_per_s, 1e-9))
    emit("serving_speedup", "",
         f"continuous/lockstep={speedup:.2f}x "
         f"(trace: {N_REQS} reqs, max_new {SHORT_NEW}/{LONG_NEW}, "
         f"{MAX_BATCH} slots)")
    return speedup


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    print("name,us_per_call,derived")
    run()
