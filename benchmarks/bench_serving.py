"""Serving scheduler benchmark: lock-step groups vs continuous batching
(dense slot KV) vs continuous batching with the paged KV layout.

The serving analog of the paper's fixed-FPU-budget sweep (Ara2 §7.1:
eight 2-lane cores beat one 16-lane core at equal FPU count because eight
independent issue streams remove the single-dispatcher bottleneck).  Here
the FPU budget is the ``max_batch`` slot pool and the trace mixes short
and long requests (``max_new_tokens`` in {8, 64}): lock-step pins every
slot to its group's slowest member, continuous batching refills freed
slots immediately.

The paged run demonstrates the memory-side claim (Ara2's bottleneck
analysis: memory organization, not raw FPU count, gates utilization): its
block pool holds exactly the dense layout's KV footprint
(``max_batch * cache_len`` positions), yet it admits a trace whose
*summed* KV footprint exceeds that capacity, because finished requests
return their blocks immediately instead of holding a worst-case
``cache_len`` reservation.  The bench checks paged greedy tokens match
the dense run token-for-token and exits non-zero with a per-request
diff summary on divergence, so CI catches layout drift diagnosably.

Emits ``name,us_per_call,derived`` CSV rows like the other benches:
  serving_lockstep,<wall_us>,tok/s=...;occ=...
  serving_continuous,<wall_us>,tok/s=...;occ=...
  serving_paged,<wall_us>,tok/s=...;occ=...;block_util=...;compiles=...
  serving_speedup,,continuous/lockstep=...
  serving_paged_admission,,footprint=...;capacity=...;admitted=...

``--smoke`` shrinks the trace/model work for the CI CPU regression gate.
"""
import jax

from benchmarks.common import check_tokens, emit

MAX_BATCH = 4
CACHE_LEN = 128
BLOCK = 16
PROMPT_LEN = 8
SHORT_NEW, LONG_NEW = 8, 64
N_REQS = 16


def _trace(vocab, n_reqs, short_new, long_new):
    from repro.serving import Request
    reqs = []
    for i in range(n_reqs):
        prompt = [(7 * i + j) % vocab for j in range(PROMPT_LEN)]
        max_new = short_new if i % 2 else long_new
        reqs.append(Request(prompt, max_new, temperature=0.0, rid=i))
    return reqs


def run(smoke: bool = False):
    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.serving import Request, ServeEngine

    cache_len = 32 if smoke else CACHE_LEN
    n_reqs = 8 if smoke else N_REQS
    long_new = 16 if smoke else LONG_NEW
    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    reqs = _trace(cfg.vocab_size, n_reqs, SHORT_NEW, long_new)

    # paged pool sized to the dense layout's exact KV footprint: admission
    # beyond it can only come from block recycling, not extra memory
    pool_positions = MAX_BATCH * cache_len
    engines = {
        "lockstep": dict(mode="lockstep"),
        "continuous": dict(mode="continuous"),
        "paged": dict(mode="continuous", kv_layout="paged",
                      block_size=BLOCK,
                      n_blocks=pool_positions // BLOCK + 1),
    }
    stats, tokens = {}, {}
    for name, kw in engines.items():
        eng = ServeEngine(model, params, max_batch=MAX_BATCH,
                          cache_len=cache_len, **kw)
        # warmup: compile prefill/decode/sample outside the timed run
        eng.generate([Request(list(range(PROMPT_LEN)), 2, rid=-1)
                      for _ in range(MAX_BATCH)])
        res = eng.generate(reqs)
        tokens[name] = [r.tokens for r in res]
        s = eng.last_stats
        stats[name] = s
        extra = ""
        if name == "paged":
            extra = (f";block_util={s.block_util_peak:.2f}"
                     f";compiles={s.prefill_compiles}")
        emit(f"serving_{name}", s.wall_s * 1e6,
             f"tok/s={s.tokens_per_s:.1f};occ={s.occupancy:.2f};"
             f"steps={s.decode_steps};ttft_ms={s.ttft_ms_mean:.1f};"
             f"preempted={s.preempted};requeued={s.requeued}" + extra)

    # exit non-zero with a per-request diff summary on divergence (a bare
    # assert left CI logs undiagnosable)
    check_tokens("bench_serving", "continuous", tokens["continuous"],
                 "paged", tokens["paged"], [r.rid for r in reqs])

    speedup = (stats["continuous"].tokens_per_s
               / max(stats["lockstep"].tokens_per_s, 1e-9))
    emit("serving_speedup", "",
         f"continuous/lockstep={speedup:.2f}x "
         f"(trace: {n_reqs} reqs, max_new {SHORT_NEW}/{long_new}, "
         f"{MAX_BATCH} slots)")

    # admission headline: summed trace KV footprint vs the pool capacity
    # (== dense max_batch * cache_len) that nonetheless served it
    footprint = sum(len(r.prompt) + r.max_new_tokens - 1 for r in reqs)
    served = all(len(t) == r.max_new_tokens
                 for t, r in zip(tokens["paged"], reqs))
    assert footprint > pool_positions, \
        "trace too small to demonstrate block recycling"
    assert served, "paged engine failed to serve the full trace"
    emit("serving_paged_admission", "",
         f"footprint={footprint}pos;capacity={pool_positions}pos;"
         f"admitted=all({n_reqs});block_util_peak="
         f"{stats['paged'].block_util_peak:.2f}")
    return speedup


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    smoke = "--smoke" in sys.argv
    print("name,us_per_call,derived")
    run(smoke=smoke)
