"""Paper Tables 3-4: PPA metrics and per-dtype matmul efficiency."""
from repro.core.ppa import (CELL_MACRO_AREA_KGE, DIE_AREA_MM2,
                            ENERGY_EFF_TABLE3, TABLE4, TT_FREQ_GHZ)

from benchmarks.common import emit


def run():
    for lanes in (2, 4, 8, 16, "16*"):
        eff = ENERGY_EFF_TABLE3.get(lanes, float("nan"))
        emit(f"table3/L{lanes}", 0.0,
             f"tt_ghz={TT_FREQ_GHZ[lanes]}|die_mm2={DIE_AREA_MM2[lanes]}|"
             f"kge={CELL_MACRO_AREA_KGE[lanes]}|eff={eff}")
    for prog, (elems, mw, gops, gopsw) in TABLE4.items():
        emit(f"table4/{prog}", 0.0,
             f"elems={elems}|mw={mw}|gops={gops}|gops_w={gopsw}")
