"""Workload-matrix scenario harness: {traces} x {policies} x {cluster
shapes} x {KV layouts}, scored on tail latency and SLO attainment.

AraOS's methodological point (PAPERS.md) applied to the serving stack:
a scheduling claim is only trusted after sweeping it against a matrix of
workload scenarios, not one cherry-picked trace.  Every cell stages one
shared trace generator (``benchmarks.common.TRACE_KINDS``) against one
scheduling policy (``repro.serving.slo.POLICIES``) on one cluster shape
and KV layout, and reports p50/p99 TTFT/TPOT plus SLO attainment.

**Virtual time.**  Every cell runs under a
:class:`repro.serving.telemetry.FakeClock` ticking 1 virtual ms per
clock read, with the deterministic sequential driver: latency numbers
are a pure function of the schedule (clock reads), not of CI machine
speed, so the percentile and attainment rows are *deterministic* and
zero/tight-tolerance gateable by ``tools/bench_compare.py`` against
``benchmarks/baselines/run_matrix_smoke.json``.  SLO budgets below are
expressed in virtual ms against that clock.  (The threaded driver is
timing-dependent by construction; its byte-identity is covered by the
conformance matrix in ``tests/test_serving_props.py`` instead.)

**The adversarial headline** (CI-asserted, not just reported): on the
adversarial trace — best-effort stragglers submitted *ahead* of budgeted
shorts, sized to fill every slot — FIFO serves the stragglers first and
the shorts' TTFT budgets blow past; ``slo_adaptive`` must beat FIFO on
both TTFT-SLO attainment and virtual p99 TTFT, despite paying extra
virtual time for every scheduling-decision clock read.

Within each (trace, shape, layout) group the per-request token streams
must be byte-identical across every policy (policies reorder, never
alter, sampling) — checked per group, exits non-zero with a diff.

Emits ``name,us_per_call,derived`` rows (us = *virtual* wall us):
  matrix_{trace}_{policy}_{R}x{S}_{layout},<virtual_us>,
      ttft_p50=..;ttft_p99=..;tpot_p50=..;tpot_p99=..;attain=..;
      ttft_att=..;ttft_tot=..;starve_preempts=..;preempted=..;gen=..
  matrix_headline,,fifo_attain=..;slo_attain=..;fifo_ttft_p99=..;
      slo_ttft_p99=..;trace=adversarial_2x4_dense

``--smoke`` runs the CI subset (adversarial x all policies on 2x4 dense,
fifo/slo_adaptive on 2x4 paged, the other traces under slo_adaptive);
the full run sweeps TRACE_KINDS x POLICIES x {1x8,2x4,4x2} x
{dense,paged}.  ``--json PATH`` dumps the rows + an slo summary for the
CI artifact/gate.
"""
import sys

import jax

from benchmarks.common import (check_tokens, emit, make_trace, reset_rows,
                               write_json)

CACHE_LEN = 64
BLOCK = 8
PROMPT_LEN = 8
TICK_S = 1e-3                  # 1 virtual ms per clock read

#: Trace shapes per kind: the adversarial cell sizes its straggler wave
#: to the whole slot budget (n_long = total_slots) so FIFO head-of-line
#: blocks every budgeted short behind ~LONG_NEW decode steps.
SHORT_NEW, LONG_NEW = 4, 32
N_SHORT = 16
#: Virtual-ms budgets (FakeClock reads, not wall time): generous enough
#: for a deadline policy to clear on the smoke model's schedule (a
#: deadline-ordered short sees first token within a few virtual ms),
#: far tighter than sitting out a straggler wave (~hundreds of virtual
#: ms) - calibrated so the adversarial headline separates fifo from
#: slo_adaptive.
TTFT_MS, TPOT_MS = 120.0, 10.0

SHAPES = ((1, 8), (2, 4), (4, 2))
SMOKE_SHAPE = (2, 4)


def _trace_kw(kind: str, total_slots: int) -> dict:
    kw = dict(prompt_len=PROMPT_LEN, slo_ttft_ms=TTFT_MS,
              slo_tpot_ms=TPOT_MS)
    if kind == "uniform":
        kw.update(n=total_slots + 4, max_new=SHORT_NEW * 2)
    elif kind == "bursty":
        kw.update(n=N_SHORT, burst=2, short_new=SHORT_NEW,
                  long_new=LONG_NEW // 2)
    elif kind == "heavy_tailed":
        kw.update(n=N_SHORT, tail_at=(0, 4), short_new=SHORT_NEW,
                  tail_new=LONG_NEW)
    else:                       # adversarial: stragglers fill every slot
        kw.update(n=total_slots + N_SHORT, n_long=total_slots,
                  short_new=SHORT_NEW, long_new=LONG_NEW)
    return kw


def _cells(smoke: bool):
    from repro.serving import POLICIES
    if not smoke:
        return [(k, p, s, lay)
                for k in ("uniform", "bursty", "heavy_tailed",
                          "adversarial")
                for p in POLICIES for s in SHAPES
                for lay in ("dense", "paged")]
    cells = [("adversarial", p, SMOKE_SHAPE, "dense") for p in POLICIES]
    cells += [("adversarial", p, SMOKE_SHAPE, "paged")
              for p in ("fifo", "slo_adaptive")]
    cells += [(k, "slo_adaptive", SMOKE_SHAPE, "dense")
              for k in ("uniform", "bursty", "heavy_tailed")]
    return cells


def _run_cell(model, params, vocab, kind, policy, shape, layout):
    from repro.serving import ClusterEngine, FakeClock
    replicas, slots = shape
    total = replicas * slots
    eng = ClusterEngine(model, params, replicas=replicas,
                        total_slots=total, cache_len=CACHE_LEN,
                        kv_layout=layout, block_size=BLOCK,
                        policy=policy, driver="sequential",
                        clock=FakeClock(0.0, tick=TICK_S))
    reqs = make_trace(kind, vocab, **_trace_kw(kind, total))
    res = eng.generate(reqs)
    return ([r.tokens for r in res], [r.rid for r in reqs],
            eng.last_stats, eng.last_metrics)


def _pctl(samples, q: float) -> float:
    """Nearest-rank percentile over raw samples (0.0 when empty)."""
    if not samples:
        return 0.0
    xs = sorted(samples)
    return xs[min(len(xs) - 1, max(0, int(round(q / 100 * len(xs))) - 1))]


def _client_ttft(metrics):
    """Client-perceived TTFT (enqueue -> first token, virtual ms) of the
    *budgeted* requests, recovered exactly from the SLO slack samples
    (slack = budget - attained): the engine's ``ttft_ms`` histogram is
    admit-based and cannot see queue wait, which is the whole story on
    the adversarial trace."""
    return [TTFT_MS - s
            for s in metrics.histogram("slo_ttft_slack_ms").samples]


def _cell_line(s, cttft) -> str:
    return (f"cttft_p50={_pctl(cttft, 50):.0f};"
            f"cttft_p99={_pctl(cttft, 99):.0f};"
            f"ttft_p50={s.ttft_ms_p50:.0f};ttft_p99={s.ttft_ms_p99:.0f};"
            f"tpot_p50={s.tpot_ms_p50:.1f};tpot_p99={s.tpot_ms_p99:.1f};"
            f"attain={s.slo_attainment:.3f};"
            f"ttft_att={s.slo_ttft_attained};ttft_tot={s.slo_ttft_total};"
            f"starve_preempts={s.slo_starve_preempts};"
            f"preempted={s.preempted};gen={s.generated_tokens}")


def run(smoke: bool = False, json_path: str | None = None):
    from repro.configs import smoke_config
    from repro.models import build_model

    reset_rows()
    cfg = smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    vocab = cfg.vocab_size

    stats = {}
    cttfts = {}
    groups: dict[tuple, tuple] = {}   # (kind, shape, layout) -> ref toks
    for kind, policy, shape, layout in _cells(smoke):
        name = (f"matrix_{kind}_{policy}_{shape[0]}x{shape[1]}_{layout}")
        toks, rids, s, metrics = _run_cell(model, params, vocab, kind,
                                           policy, shape, layout)
        stats[(kind, policy, shape, layout)] = s
        cttfts[(kind, policy, shape, layout)] = _client_ttft(metrics)
        emit(name, s.wall_s * 1e6,
             _cell_line(s, cttfts[(kind, policy, shape, layout)]))
        # policies reorder, never alter, sampling: within a cell group
        # every policy's per-request streams must be byte-identical
        gkey = (kind, shape, layout)
        if gkey in groups:
            ref_policy, ref = groups[gkey]
            check_tokens(f"run_matrix/{kind}_{shape}_{layout}",
                         ref_policy, ref, policy, toks, rids)
        else:
            groups[gkey] = (policy, toks)

    # the adversarial headline: slo_adaptive must beat fifo on both
    # TTFT attainment and virtual p99 TTFT (asserted, not reported)
    hshape, hlayout = (SMOKE_SHAPE, "dense") if smoke else (SHAPES[1],
                                                            "dense")
    f = stats[("adversarial", "fifo", hshape, hlayout)]
    a = stats[("adversarial", "slo_adaptive", hshape, hlayout)]
    f_p99 = _pctl(cttfts[("adversarial", "fifo", hshape, hlayout)], 99)
    a_p99 = _pctl(cttfts[("adversarial", "slo_adaptive", hshape,
                          hlayout)], 99)
    f_att = f.slo_ttft_attained / max(f.slo_ttft_total, 1)
    a_att = a.slo_ttft_attained / max(a.slo_ttft_total, 1)
    emit("matrix_headline", "",
         f"fifo_attain={f_att:.3f};slo_attain={a_att:.3f};"
         f"fifo_cttft_p99={f_p99:.0f};slo_cttft_p99={a_p99:.0f};"
         f"trace=adversarial_{hshape[0]}x{hshape[1]}_{hlayout}")
    assert a_att > f_att, (
        f"slo_adaptive TTFT attainment {a_att:.3f} does not beat fifo "
        f"{f_att:.3f} on the adversarial trace")
    assert a_p99 < f_p99, (
        f"slo_adaptive virtual p99 client TTFT {a_p99:.0f}ms does not "
        f"beat fifo {f_p99:.0f}ms on the adversarial trace")
    if smoke:
        # the CI bar from the starvation satellite: the adaptive policy
        # attains >= 90% of the budgeted shorts' TTFT deadlines while
        # fifo, serving the straggler wave first, misses them all
        assert a_att >= 0.9, (
            f"slo_adaptive attainment {a_att:.3f} < 0.9 on the "
            "adversarial smoke trace")

    if json_path:
        write_json(json_path, bench="run_matrix", smoke=smoke,
                   slo={"fifo_attain": f_att, "slo_attain": a_att})
    return stats


if __name__ == "__main__":
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "src"))
    from benchmarks.common import json_path_arg
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv, json_path=json_path_arg(sys.argv))
