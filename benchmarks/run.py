"""Benchmark harness (deliverable d): one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV."""
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks import (bench_ideality, bench_mesh_policy,
                            bench_multicore, bench_ppa, bench_reduction,
                            bench_roofline, bench_serving, bench_slide,
                            bench_whatif)
    benches = [
        ("ideality (Figs 4-5, Table 2)", bench_ideality),
        ("slide unit (Fig 3, Table 5)", bench_slide),
        ("reductions (par.3)", bench_reduction),
        ("multi-core (Figs 13-18)", bench_multicore),
        ("what-if (Figs 6-10)", bench_whatif),
        ("PPA (Tables 3-4)", bench_ppa),
        ("mesh policy (par.7 on TPU)", bench_mesh_policy),
        ("roofline (dry-run)", bench_roofline),
        ("serving scheduler (par.7 analog)", bench_serving),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for title, mod in benches:
        print(f"# --- {title} ---")
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"# BENCH FAILED: {e}")
            traceback.print_exc()
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
