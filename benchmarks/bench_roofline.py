"""Deliverable (g): the TPU roofline table from the dry-run artifacts in
results/dryrun/ (run ``python -m repro.launch.dryrun --all --mesh both``
first; this bench only reads)."""
import glob
import json
import os

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def run():
    files = sorted(glob.glob(os.path.join(RESULTS, "*.json")))
    if not files:
        emit("roofline/missing", 0.0, "run repro.launch.dryrun first")
        return
    for f in files:
        r = json.load(open(f))
        tag = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] == "skipped":
            emit(f"roofline/{tag}", 0.0, "skipped:" + r["reason"][:40])
            continue
        if r["status"] != "ok":
            emit(f"roofline/{tag}", 0.0, "ERROR")
            continue
        rf = r["roofline"]
        emit(f"roofline/{tag}", rf["t_bound"] * 1e6 if "t_bound" in rf else 0.0,
             f"comp={rf['t_compute']:.4f}s|mem={rf['t_memory']:.4f}s|"
             f"coll={rf['t_collective']:.4f}s|dom={rf['dominant']}|"
             f"useful={rf['useful_flops_fraction']:.2f}|"
             f"frac={rf['roofline_fraction']:.4f}|"
             f"hbm={r.get('hbm_used_gb', '?')}GB")
