"""Paper Figs 6-10: what-if attributions (ideal dispatcher, ideal cache,
streamlined vector unit, Barber's Pole layout)."""
from repro.core import ideality
from repro.core.perf_model import WhatIf
from repro.core.vector_engine import VectorEngineConfig

from benchmarks.common import emit

E16 = VectorEngineConfig(n_lanes=16)
E2 = VectorEngineConfig(n_lanes=2)


def run():
    for nbytes in (512, 1024, 2048, 8192):
        base = ideality("matmul", nbytes, E16)
        idd = ideality("matmul", nbytes, E16, WhatIf(ideal_dispatcher=True))
        idc = ideality("matmul", nbytes, E16, WhatIf(ideal_cache=True))
        stream = ideality("matmul", nbytes, E16,
                          WhatIf(ideal_dispatcher=True, streamlined=True))
        emit(f"fig9/16L_{nbytes}B", 0.0,
             f"base={base:.3f}|ideal_disp={idd:.3f}|ideal_cache={idc:.3f}|"
             f"streamlined={stream:.3f}")
        # Fig 10 decomposition: inefficiency attribution
        emit(f"fig10/16L_{nbytes}B", 0.0,
             f"ara2={max(0., stream-base):.3f}|"
             f"cache={max(0., idc-base):.3f}|"
             f"cva6={max(0., idd-idc):.3f}")
    for nbytes in (64, 128, 256, 512, 2048):
        bp = ideality("matmul", nbytes, E2, WhatIf(barber_pole=True))
        nobp = ideality("matmul", nbytes, E2)
        emit(f"fig8/2L_{nbytes}B", 0.0, f"barber={bp:.3f}|plain={nobp:.3f}")
