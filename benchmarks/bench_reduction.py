"""Paper §3 reduction model + measured 3-step hierarchical reduction."""
import jax
import jax.numpy as jnp

from repro.core import (hierarchical_reduce, reduction_drain_cycles,
                        vector_reduction_cycles)

from benchmarks.common import emit, timeit


def run():
    for r in (2, 3, 4, 8):
        emit(f"reduction/drain_R{r}", 0.0,
             f"cycles={reduction_drain_cycles(r):.2f}")
    for lanes in (2, 4, 8, 16):
        for n in (64, 256, 1024):
            c = vector_reduction_cycles(n, lanes, 64, 4)
            emit(f"reduction/latency_L{lanes}_n{n}", 0.0,
             f"cycles={c:.1f}|opc={2*n/c:.2f}")
    x = jax.random.normal(jax.random.key(0), (1 << 16,), jnp.float32)
    for lanes in (4, 16):
        us = timeit(jax.jit(lambda v, l=lanes: hierarchical_reduce(v, l)), x)
        emit(f"reduction/hierarchical_64k_L{lanes}", us, "")
