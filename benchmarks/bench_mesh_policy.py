"""Paper §7 transplanted (C4): mesh-shape ranking per (arch x shape) at a
fixed 256-chip budget - 'many small vector cores' (large DP) vs 'one big
core' (large TP)."""
from repro.configs import SHAPES, get_config
from repro.distributed.mesh_policy import choose_mesh

from benchmarks.common import emit

CASES = [
    ("qwen3-0.6b", "train_4k"),
    ("qwen3-0.6b", "decode_32k"),
    ("yi-6b", "train_4k"),
    ("gemma3-27b", "train_4k"),
    ("qwen3-moe-235b-a22b", "train_4k"),
    ("whisper-base", "train_4k"),
]


def run():
    for arch, shape in CASES:
        cands = choose_mesh(get_config(arch), SHAPES[shape], 256)
        top = [f"dp{c.dp}xtp{c.tp}({c.t_total*1e3:.1f}ms"
               f"{'' if c.fits else ',OOM'})" for c in cands[:3]]
        emit(f"meshpolicy/{arch}/{shape}", cands[0].t_total * 1e6,
             "|".join(top))
