"""Paper Figs 4-5 + Table 2: raw-throughput ideality over the benchmark
pool x vector length x lanes (perf model), plus measured CPU wall time of
the production (xla) kernel impls at matched problem sizes."""
import jax
import jax.numpy as jnp

from repro.core import KERNELS, ideality
from repro.core.vector_engine import VectorEngineConfig
from repro.kernels import ops

from benchmarks.common import emit, timeit

VL_BYTES = (32, 64, 128, 256, 512, 1024, 2048, 4096)
LANES = (2, 4, 8, 16)


def run():
    # Fig 5 heatmap: ideality per kernel x lanes x vector length
    for kern in KERNELS:
        for lanes in LANES:
            eng = VectorEngineConfig(n_lanes=lanes)
            row = [f"{ideality(kern, vb, eng):.3f}" for vb in VL_BYTES]
            emit(f"fig5/{kern}/L{lanes}", 0.0, "|".join(row))
    # Fig 4 diagonals: constant bytes/lane
    for bpl in (32, 64, 128, 256):
        vals = [f"{ideality('matmul', bpl * l, VectorEngineConfig(n_lanes=l)):.3f}"
                for l in LANES]
        emit(f"fig4/diag_bpl{bpl}", 0.0, "|".join(vals))
    # measured wall time of xla kernel impls (CPU)
    key = jax.random.key(0)
    x = jax.random.normal(key, (512, 512), jnp.float32)
    us = timeit(jax.jit(lambda a: ops.matmul(a, a, impl="xla")), x)
    emit("kernel/matmul_512", us, f"gflops={2*512**3/us/1e3:.2f}")
    v = jax.random.normal(key, (1 << 16,), jnp.float32)
    us = timeit(jax.jit(lambda a: ops.dotproduct(a, a, impl="xla")), v)
    emit("kernel/dotproduct_64k", us, f"gbps={2*4*(1<<16)/us/1e3:.2f}")
    sm = jax.random.normal(key, (256, 1024), jnp.float32)
    emit("kernel/softmax_256x1024",
         timeit(jax.jit(lambda a: ops.softmax(a, impl="xla")), sm), "")
    fr = jax.random.normal(key, (4096,), jnp.float32)
    emit("kernel/fft_4096",
         timeit(jax.jit(lambda a: ops.fft(a, a, impl="xla")[0]), fr), "")
    img = jax.random.normal(key, (3, 128, 128), jnp.float32)
    kw = jax.random.normal(key, (3, 7, 7), jnp.float32)
    emit("kernel/conv2d_3x128x128",
         timeit(jax.jit(lambda a, b: ops.conv2d(a, b, impl="xla")), img, kw),
         "")
    pw = jnp.abs(jax.random.normal(key, (64, 4096), jnp.float32))
    emit("kernel/pathfinder_64x4096",
         timeit(jax.jit(lambda a: ops.pathfinder(a, impl="xla")), pw), "")
