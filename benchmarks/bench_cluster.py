"""Multi-replica cluster benchmark: 1x8 vs 2x4 vs 4x2 replica shapes on
one fixed 512-position shared KV block pool.

The paper's headline multi-core sweep (Ara2 §7: eight 2-lane cores with
16 FPUs beat one 16-lane core with the same 16 FPUs by >3x on 32x32x32
matmul, because many small issue streams overcome the single scalar
core's issue-rate bound).  The serving analog at a fixed slot budget
(= FPU count): a single wide engine's decode step has a fixed compiled
width, so it pays for all 8 slot lanes even when short-request traffic
leaves most of them idle (the drain tail); narrow replicas strand at
most their own width, and a fully drained replica skips its step
entirely.  All shapes draw from the *same* 512-position block pool, so
the memory budget is constant across the sweep - only the issue
structure changes.

Two traces:

* **short-request trace** - mostly 4-token requests plus two 64-token
  stragglers (heavy-tailed traffic).  Greedy outputs must be
  token-identical across every replica shape and the plain single
  engine; the many-small shapes must beat 1x8 tokens/s (asserted in the
  full run, reported in ROADMAP).

* **pressure trace** - 8 concurrent requests whose worst case (40
  blocks) exceeds the pool (32 blocks).  Under the cluster's overcommit
  admission this forces **preemption**: lazy block growth finds the pool
  empty, the youngest request is evicted and re-queued with its
  generated prefix.  Asserted: at least one preemption fires and the
  preempted outputs are still token-identical to a reserve-admission
  reference on the same pool (preemption is invisible in the output).

A second axis rides the same 4x2 shape: the **driver**.  The sequential
driver steps replicas one after another in a Python loop, serializing
per-launch dispatch; the threaded driver overlaps the replicas' steps on
worker threads (JAX dispatch releases the GIL).  ``cluster_overlap``
reports the wall-clock speedup; token identity vs the single engine is
asserted for both drivers, and on a multi-core host (>= 2 usable cores,
i.e. CI) the speedup must clear 1.2x - on a single core there is no
parallelism to win, so only the wide baseline band applies.

Emits ``name,us_per_call,derived`` CSV rows like the other benches:
  cluster_single_1x8,<wall_us>,tok/s=...;occ=...
  cluster_{1x8,2x4,4x2},<wall_us>,tok/s=...;occ=...;preempted=...
  cluster_speedup,,best_small/1x8=...
  cluster_overlap,<threaded_wall_us>,speedup=...;seq_us=...;cores=...
  cluster_pressure_{reserve,preempt},<wall_us>,tok/s=...;preempted=...
  serving_latency_cluster,,ttft_ms_p50=...;...;tpot_ms_p50=...
  serving_latency_cluster_pressure,,ttft_ms_p50=...;...
  cluster_trace,,events=...;flows=...;lifecycle=ok
  serving_attr_cluster,,fu_utilization=...;bottleneck=...;verdicts...

The latency rows come off the cluster's *merged* per-replica metric
registries (raw histogram samples concatenated before the percentile is
taken — a mean of replica means cannot produce a cluster p99; see
docs/observability.md).  The pressure run serves with a live
:class:`Tracer` attached: its tokens are checked against the untraced
reserve reference (tracing must not perturb scheduling), the event
stream must be lifecycle-well-formed with at least one preempt→requeue
flow, and ``--trace PATH`` exports it as Chrome-trace JSON (validated
in CI by ``tools/check_trace.py``).  The same run carries a shared
:class:`Attributor` across both replicas (one AOT cost lowering per
compiled shape, not per replica); ``serving_attr_cluster`` reports the
cluster-merged roofline rollup — fu_utilization and verdict counts come
off the lossless registry merge, so they aggregate replicas exactly
like the latency percentiles do.

``--smoke`` shrinks to the smoke model for the CI gate: it asserts
token identity and the preemption count but not the throughput ordering
(the tiny model's step cost is dispatch-bound, not width-bound).
"""
import dataclasses
import os
import sys

import jax

from benchmarks.common import (check_tokens, emit, trace_heavy_tailed,
                               trace_uniform)

TOTAL_SLOTS = 8
CACHE_LEN = 512                # per-request context bound (block-table
                               # width: decode pays it per slot lane, live
                               # or idle - the width cost the sweep measures)
BLOCK = 16
POOL_POSITIONS = 512           # fixed shared budget for every shape
PROMPT_LEN = 16
SHORT_NEW, TAIL_NEW = 4, 64
N_SHORT_REQS = 12
N_PRESSURE_REQS = 8


def _serve_config(smoke: bool):
    """Mid-size config for the full run: decode cost must be dominated by
    per-row work (attention + per-token matmuls), not per-launch dispatch,
    for the replica-shape comparison to measure the paper's effect."""
    from repro.configs import smoke_config
    cfg = smoke_config("qwen3-0.6b")
    if smoke:
        return cfg
    return dataclasses.replace(
        cfg, name="qwen3-serve", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=1024, vocab_size=4096, head_dim=64)


def _short_trace(vocab: int):
    """Heavy-tailed short-request traffic: the two stragglers sit at
    submission positions 0 and 4, so round-robin co-locates them on one
    replica in every shape (1, 2, or 4 replicas) - the narrow shapes
    quarantine the tail instead of stalling the whole slot pool on it.
    (The shared generator's defaults ARE this bench's historic trace -
    baselines unchanged.)"""
    return trace_heavy_tailed(vocab, n=N_SHORT_REQS,
                              prompt_len=PROMPT_LEN, short_new=SHORT_NEW,
                              tail_new=TAIL_NEW)


def _pressure_trace(vocab: int):
    """8 concurrent worst cases of 5 blocks each = 40 blocks against the
    32-block pool: overcommit admission must preempt to serve this."""
    return trace_uniform(vocab, n=N_PRESSURE_REQS, prompt_len=PROMPT_LEN,
                         max_new=TAIL_NEW)


def _warmup(eng, vocab: int, slots: int):
    from repro.serving import Request
    eng.generate([Request([j % vocab for j in range(PROMPT_LEN)], 2,
                          rid=-1) for _ in range(slots)])


def _stats_line(s):
    return (f"tok/s={s.tokens_per_s:.1f};occ={s.occupancy:.2f};"
            f"steps={s.decode_steps};preempted={s.preempted};"
            f"requeued={s.requeued};router={s.router_policy or '-'};"
            f"pool_util_peak={s.block_util_peak:.2f}")


def _latency_line(s, n: int):
    return (f"ttft_ms_p50={s.ttft_ms_p50:.1f};p90={s.ttft_ms_p90:.1f};"
            f"p99={s.ttft_ms_p99:.1f};tpot_ms_p50={s.tpot_ms_p50:.2f};"
            f"p99={s.tpot_ms_p99:.2f};"
            f"queue_age_ms_p99={s.queue_age_ms_p99:.1f};n={n}")


def run(smoke: bool = False, json_path: str | None = None,
        trace_path: str | None = None):
    from benchmarks.common import reset_rows
    from repro.models import build_model
    from repro.serving import ClusterEngine, ServeEngine

    reset_rows()

    cfg = _serve_config(smoke)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    vocab = cfg.vocab_size
    pool_kw = dict(cache_len=CACHE_LEN, block_size=BLOCK,
                   n_blocks=POOL_POSITIONS // BLOCK + 1)

    # ---- short-request sweep: 1x8 vs 2x4 vs 4x2 ----------------------
    reqs = _short_trace(vocab)
    rids = [r.rid for r in reqs]

    single = ServeEngine(model, params, max_batch=TOTAL_SLOTS,
                         kv_layout="paged", **pool_kw)
    _warmup(single, vocab, TOTAL_SLOTS)
    ref = [r.tokens for r in single.generate(reqs)]
    s = single.last_stats
    emit("cluster_single_1x8", s.wall_s * 1e6, _stats_line(s))

    toks_per_s = {}
    for replicas in (1, 2, 4):
        shape = f"{replicas}x{TOTAL_SLOTS // replicas}"
        cl = ClusterEngine(model, params, replicas=replicas,
                           total_slots=TOTAL_SLOTS, router="round_robin",
                           **pool_kw)
        _warmup(cl, vocab, TOTAL_SLOTS)
        got = [r.tokens for r in cl.generate(reqs)]
        check_tokens("bench_cluster/short", "single", ref, shape, got,
                     rids)
        s = cl.last_stats
        toks_per_s[shape] = s.tokens_per_s
        emit(f"cluster_{shape}", s.wall_s * 1e6, _stats_line(s))
        if replicas == 2:
            # cluster percentiles from the merged replica histograms
            emit("serving_latency_cluster", "",
                 _latency_line(s, N_SHORT_REQS))

    base = toks_per_s["1x8"]
    best = max((v, k) for k, v in toks_per_s.items() if k != "1x8")
    emit("cluster_speedup", "",
         f"best_small={best[1]} {best[0] / max(base, 1e-9):.2f}x over 1x8 "
         f"(trace: {N_SHORT_REQS} reqs, tail {TAIL_NEW} @ {{0,4}}, "
         f"{TOTAL_SLOTS} total slots, {POOL_POSITIONS}-pos shared pool)")
    if not smoke:
        assert best[0] > base, (
            f"many-small shapes did not beat 1x8: {toks_per_s}")

    # ---- sequential vs threaded driver: dispatch overlap -------------
    # same 4x2 cluster (``cl`` is the sweep's last shape), same trace:
    # the only change is whether the 4 replicas' steps are serialized in
    # one loop or overlapped on worker threads.  Best-of-3 per driver
    # (wall-clock rows jitter; the schedule does not).
    ncores = (len(os.sched_getaffinity(0))
              if hasattr(os, "sched_getaffinity")
              else (os.cpu_count() or 1))
    walls = {}
    for drv in ("sequential", "threaded"):
        best_wall = None
        for _ in range(3):
            got = [r.tokens for r in cl.generate(reqs, driver=drv)]
            w = cl.last_stats.wall_s
            best_wall = w if best_wall is None else min(best_wall, w)
        check_tokens("bench_cluster/overlap", "single", ref, drv, got,
                     rids)
        walls[drv] = best_wall
    overlap = walls["sequential"] / max(walls["threaded"], 1e-9)
    emit("cluster_overlap", walls["threaded"] * 1e6,
         f"speedup={overlap:.2f}x;seq_us={walls['sequential'] * 1e6:.0f};"
         f"cores={ncores};shape=4x2;drivers=byte-identical")
    if ncores >= 2:
        # the tentpole's bar: with real cores to overlap on, threading
        # the replica steps must buy >= 1.2x on the 4x2 smoke shape
        # (ROADMAP measured ~1.65x available for 4 threads on 2 cores)
        assert overlap >= 1.2, (
            f"threaded driver overlap {overlap:.2f}x < 1.2x on "
            f"{ncores} cores: dispatch is serializing somewhere")

    # ---- pressure trace: preemption vs worst-case reservation --------
    preqs = _pressure_trace(vocab)
    prids = [r.rid for r in preqs]

    # pow2 bucketing on both pressure engines: every preemption re-prefills
    # at a new prompt+prefix length, and bucketing collapses those to a
    # handful of compiled shapes (outputs are unchanged - asserted below)
    # reserve admission on the same pool: admissions serialize so lazy
    # growth can never fail (the pre-PR behavior; never preempts)
    reserve = ServeEngine(model, params, max_batch=TOTAL_SLOTS,
                          kv_layout="paged", admission="reserve",
                          bucket="pow2", **pool_kw)
    _warmup(reserve, vocab, TOTAL_SLOTS)
    pref = [r.tokens for r in reserve.generate(preqs)]
    s = reserve.last_stats
    emit("cluster_pressure_reserve", s.wall_s * 1e6, _stats_line(s))

    cl = ClusterEngine(model, params, replicas=2, total_slots=TOTAL_SLOTS,
                       router="round_robin", admission="overcommit",
                       bucket="pow2", **pool_kw)
    _warmup(cl, vocab, TOTAL_SLOTS)
    # serve the pressure run with a live tracer attached (after warmup,
    # so the trace holds only the timed run): its tokens are checked
    # against the *untraced* reserve reference below, which is the
    # observer-effect gate for the cluster path
    from repro.serving import (NULL_ATTR, NULL_TRACER, Attributor, Tracer,
                               validate_lifecycle)
    tracer = Tracer()
    cl.set_tracer(tracer)
    cl.set_attributor(Attributor())     # shared across both replicas
    pgot = [r.tokens for r in cl.generate(preqs)]
    cl.set_tracer(NULL_TRACER)
    cl.set_attributor(NULL_ATTR)
    s = cl.last_stats
    emit("cluster_pressure_preempt", s.wall_s * 1e6, _stats_line(s))
    emit("serving_latency_cluster_pressure", "",
         _latency_line(s, N_PRESSURE_REQS))
    check_tokens("bench_cluster/pressure", "reserve", pref, "preempt",
                 pgot, prids)
    assert s.preempted >= 1, (
        "pressure trace exercised no preemption (pool too large or "
        "admission not overcommitted?)")
    events = tracer.events()
    validate_lifecycle(events)
    flows = sum(1 for e in events if e.ph == "s")
    assert flows >= 1, "preemption fired but recorded no flow arrow"
    emit("cluster_trace", "",
         f"events={len(events)};flows={flows};lifecycle=ok")
    # cluster-merged attribution rollup: both replicas' attr_* metrics
    # concatenate losslessly before the stats view derives these
    assert s.achieved_flops_per_s > 0 and s.bottleneck, (
        "attribution produced no cluster rollup")
    assert 0.0 < s.fu_utilization < 1.0, (
        f"implausible cluster fu_utilization {s.fu_utilization}")
    assert any(e.name == "roofline" for e in events), (
        "attributed cluster trace has no roofline counter track")
    verdicts = ";".join(f"{k}={v}"
                        for k, v in sorted(s.verdict_counts.items()))
    emit("serving_attr_cluster", "",
         f"fu_utilization={s.fu_utilization:.3e};"
         f"achieved_gflops_s={s.achieved_flops_per_s / 1e9:.3f};"
         f"ai={s.decode_ai:.2f};ridge={s.ridge_ai:.2f};"
         f"bottleneck={s.bottleneck};"
         f"prefill_bottleneck={s.prefill_bottleneck};{verdicts}")
    if trace_path:
        n = tracer.export(trace_path)
        print(f"[bench] wrote {trace_path} ({n} trace events)",
              file=sys.stderr)
    served = all(len(t) == r.max_new_tokens for t, r in zip(pgot, preqs))
    assert served, "cluster failed to serve the full pressure trace"
    assert cl.pool.n_live == 0 and cl.pool.n_reserved == 0, (
        "shared pool leaked blocks after drain")
    emit("cluster_pressure_admission", "",
         f"worst_case={N_PRESSURE_REQS * 5}blocks;"
         f"pool={POOL_POSITIONS // BLOCK}blocks;"
         f"preempted={s.preempted};requeued={s.requeued};served=all"
         f"({N_PRESSURE_REQS})")
    if json_path:
        from benchmarks.common import write_json
        write_json(json_path, bench="bench_cluster", smoke=smoke)
    return toks_per_s


if __name__ == "__main__":
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from benchmarks.common import json_path_arg, path_arg
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv, json_path=json_path_arg(sys.argv),
        trace_path=path_arg(sys.argv, "--trace"))
