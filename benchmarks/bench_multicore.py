"""Paper Figs 13-18: single- vs multi-core trade-off at fixed FPU budgets
(raw throughput, real throughput at implementation frequencies, energy
efficiency), from the calibrated perf+PPA model."""
from repro.core import (energy_efficiency_gflops_w, fixed_fpu_sweep,
                        issue_rate_limit_opc, matmul_opc,
                        real_throughput_gflops)
from repro.core.perf_model import WhatIf
from repro.core.vector_engine import ClusterConfig, VectorEngineConfig

from benchmarks.common import emit

SIZES = (8, 16, 32, 64, 128, 256)


def run():
    # Fig 13: raw throughput, 16 FPUs
    for c in fixed_fpu_sweep(16):
        row = [f"{matmul_opc(n, c):.1f}" for n in SIZES]
        emit(f"fig13/raw_opc/{c.describe()}", 0.0, "|".join(row))
    emit("fig13/issue_limit", 0.0,
         "|".join(f"{issue_rate_limit_opc(n):.1f}" for n in SIZES))
    # Fig 16: ideal dispatcher comparison at 32^3
    for c in fixed_fpu_sweep(16):
        base = matmul_opc(32, c)
        ideal = matmul_opc(32, c, WhatIf(ideal_dispatcher=True))
        emit(f"fig16/{c.describe()}", 0.0,
             f"base={base:.1f}|ideal_dispatch={ideal:.1f}")
    # Fig 14/15: real throughput + efficiency
    for c in fixed_fpu_sweep(16):
        row = [f"{real_throughput_gflops(n, c):.1f}" for n in SIZES]
        emit(f"fig14/gflops/{c.describe()}", 0.0, "|".join(row))
        row = [f"{energy_efficiency_gflops_w(n, c):.1f}" for n in SIZES]
        emit(f"fig15/gflops_w/{c.describe()}", 0.0, "|".join(row))
    # Fig 17/18: sweeps at 2-16 FPUs
    for fpus in (2, 4, 8, 16):
        for c in fixed_fpu_sweep(fpus):
            emit(f"fig17/{fpus}fpu/{c.describe()}", 0.0,
                 f"gflops@256={real_throughput_gflops(256, c):.1f}|"
                 f"eff@256={energy_efficiency_gflops_w(256, c):.1f}")
