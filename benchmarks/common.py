"""Benchmark utilities: timing + CSV emission (name,us_per_call,derived)
with an optional JSON sink (CI uploads the --smoke rows as an artifact),
plus the shared workload-trace generators (``TRACE_KINDS``) used by
bench_serving, bench_cluster, run_matrix, and the SLO tests — one seeded,
shape-parameterized implementation instead of a hand-rolled copy per
bench."""
import json
import random
import sys
import time

import jax

# every emit() is also recorded here so benches can dump a machine-
# readable copy of their run (write_json)
_ROWS: list = []


#: Workload-trace shapes for the scenario matrix (run_matrix.py):
#: uniform (every request identical), bursty (alternating long/short
#: bursts), heavy_tailed (mostly shorts + a few stragglers), adversarial
#: (best-effort stragglers submitted *ahead* of budgeted shorts - the
#: head-of-line-blocking worst case SLO scheduling exists for).
TRACE_KINDS = ("uniform", "bursty", "heavy_tailed", "adversarial")


def _prompt(i: int, vocab: int, prompt_len: int, stride: int, rng):
    """One prompt row.  seed=None (rng=None) keeps the benches' exact
    deterministic stride pattern ``(stride*i + j) % vocab``; a seeded rng
    varies prompts across matrix repetitions instead."""
    if rng is None:
        return [(stride * i + j) % vocab for j in range(prompt_len)]
    return [rng.randrange(vocab) for _ in range(prompt_len)]


def _rng(seed):
    return None if seed is None else random.Random(seed)


def trace_uniform(vocab: int, n: int = 8, prompt_len: int = 16,
                  max_new: int = 64, stride: int = 7, seed=None,
                  slo_ttft_ms=None, slo_tpot_ms=None):
    """Every request identical in shape (bench_cluster's pressure trace
    is ``trace_uniform(vocab, 8, 16, 64)``).  Budgets, when given, attach
    to every request."""
    from repro.serving import Request
    rng = _rng(seed)
    return [Request(_prompt(i, vocab, prompt_len, stride, rng), max_new,
                    temperature=0.0, rid=i, slo_ttft_ms=slo_ttft_ms,
                    slo_tpot_ms=slo_tpot_ms)
            for i in range(n)]


def trace_bursty(vocab: int, n: int = 16, prompt_len: int = 8,
                 short_new: int = 8, long_new: int = 64, burst: int = 1,
                 stride: int = 7, seed=None, slo_ttft_ms=None,
                 slo_tpot_ms=None):
    """Alternating bursts of ``burst`` long then ``burst`` short requests
    (burst=1 is bench_serving's interleaved long/short trace,
    byte-for-byte).  Budgets, when given, attach to the short requests
    only — the interactive half of the mix."""
    from repro.serving import Request
    rng = _rng(seed)
    reqs = []
    for i in range(n):
        long = (i // burst) % 2 == 0
        reqs.append(Request(
            _prompt(i, vocab, prompt_len, stride, rng),
            long_new if long else short_new, temperature=0.0, rid=i,
            slo_ttft_ms=None if long else slo_ttft_ms,
            slo_tpot_ms=None if long else slo_tpot_ms))
    return reqs


def trace_heavy_tailed(vocab: int, n: int = 12, prompt_len: int = 16,
                       short_new: int = 4, tail_new: int = 64,
                       tail_at=(0, 4), stride: int = 5, seed=None,
                       slo_ttft_ms=None, slo_tpot_ms=None):
    """Mostly short requests plus stragglers at submission positions
    ``tail_at`` (the defaults reproduce bench_cluster's short-request
    trace byte-for-byte: round-robin co-locates positions 0 and 4 on one
    replica in every shape).  Budgets attach to the shorts only."""
    from repro.serving import Request
    rng = _rng(seed)
    reqs = []
    for i in range(n):
        tail = i in tail_at
        reqs.append(Request(
            _prompt(i, vocab, prompt_len, stride, rng),
            tail_new if tail else short_new, temperature=0.0, rid=i,
            slo_ttft_ms=None if tail else slo_ttft_ms,
            slo_tpot_ms=None if tail else slo_tpot_ms))
    return reqs


def trace_adversarial(vocab: int, n: int = 12, prompt_len: int = 16,
                      short_new: int = 4, long_new: int = 64,
                      n_long: int = 2, stride: int = 5, seed=None,
                      slo_ttft_ms=None, slo_tpot_ms=None):
    """The starvation worst case: ``n_long`` best-effort stragglers
    submitted *first*, then a stream of budgeted shorts behind them.
    FIFO serves the stragglers to completion while every short's TTFT
    clock runs; a deadline policy overtakes (and, under slo_adaptive,
    preempts) instead.  Budgets attach to the shorts only."""
    from repro.serving import Request
    rng = _rng(seed)
    reqs = []
    for i in range(n):
        long = i < n_long
        reqs.append(Request(
            _prompt(i, vocab, prompt_len, stride, rng),
            long_new if long else short_new, temperature=0.0, rid=i,
            slo_ttft_ms=None if long else slo_ttft_ms,
            slo_tpot_ms=None if long else slo_tpot_ms))
    return reqs


def make_trace(kind: str, vocab: int, **kw):
    """Dispatch on ``kind`` in ``TRACE_KINDS`` (run_matrix's axis)."""
    fns = {"uniform": trace_uniform, "bursty": trace_bursty,
           "heavy_tailed": trace_heavy_tailed,
           "adversarial": trace_adversarial}
    if kind not in fns:
        raise ValueError(f"trace kind={kind!r}: pick one of {TRACE_KINDS}")
    return fns[kind](vocab, **kw)


def timeit(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def token_diff_summary(name_a: str, toks_a, name_b: str, toks_b, rids):
    """Human-readable per-request divergence lines for two token-list
    sets (empty when identical).  Benches print these and exit non-zero
    instead of tripping a bare assert, so CI failures are diagnosable
    from the log."""
    lines = []
    if not (len(toks_a) == len(toks_b) == len(rids)):
        lines.append(
            f"  result-count mismatch: {name_a} has {len(toks_a)}, "
            f"{name_b} has {len(toks_b)}, trace has {len(rids)} requests")
    for ta, tb, rid in zip(toks_a, toks_b, rids):
        if ta == tb:
            continue
        k = 0
        while k < min(len(ta), len(tb)) and ta[k] == tb[k]:
            k += 1
        lines.append(
            f"  rid={rid}: first divergence at token {k} "
            f"({name_a}[{k}:{k + 4}]={ta[k:k + 4]} vs "
            f"{name_b}[{k}:{k + 4}]={tb[k:k + 4]}; "
            f"lengths {len(ta)} vs {len(tb)})")
    return lines


def check_tokens(label: str, name_a: str, toks_a, name_b: str, toks_b,
                 rids):
    """Exit non-zero with a diff summary when two token sets mismatch."""
    lines = token_diff_summary(name_a, toks_a, name_b, toks_b, rids)
    if lines:
        print(f"[{label}] TOKEN MISMATCH: {name_a} vs {name_b} "
              f"({len(lines)} of {len(rids)} requests diverge)",
              file=sys.stderr)
        for ln in lines:
            print(ln, file=sys.stderr)
        sys.exit(1)


def emit(name: str, us_per_call, derived):
    us = f"{us_per_call:.1f}" if isinstance(us_per_call, float) else us_per_call
    _ROWS.append({"name": name, "us_per_call": us, "derived": derived})
    print(f"{name},{us},{derived}")
    sys.stdout.flush()


def reset_rows() -> None:
    """Start a fresh row log (benches call this at the top of run(), so a
    prior in-process bench that never wrote JSON cannot leak rows into
    this one's artifact)."""
    _ROWS.clear()


def path_arg(argv, flag: str) -> str | None:
    """Pull a ``FLAG PATH`` value out of a bench's argv (None when the
    flag is absent; a missing value is a clear error, not an IndexError)."""
    if flag not in argv:
        return None
    i = argv.index(flag)
    if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
        sys.exit(f"{flag} needs a file path argument")
    return argv[i + 1]


def json_path_arg(argv) -> str | None:
    return path_arg(argv, "--json")


def write_json(path: str, **extra) -> None:
    """Dump every row emitted since the last write (plus bench-specific
    ``extra`` key/values) as JSON — the CI workflow uploads these as
    artifacts so a regression's numbers are diffable without scraping
    logs.  Clears the accumulator (paired with ``reset_rows`` at run()
    entry, two benches in one process each dump only their own rows)."""
    rows = list(_ROWS)
    _ROWS.clear()
    with open(path, "w") as f:
        json.dump({"rows": rows, **extra}, f, indent=2, sort_keys=True)
    print(f"[bench] wrote {path} ({len(rows)} rows)", file=sys.stderr)
