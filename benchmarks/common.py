"""Benchmark utilities: timing + CSV emission (name,us_per_call,derived)
with an optional JSON sink (CI uploads the --smoke rows as an artifact)."""
import json
import sys
import time

import jax

# every emit() is also recorded here so benches can dump a machine-
# readable copy of their run (write_json)
_ROWS: list = []


def timeit(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def token_diff_summary(name_a: str, toks_a, name_b: str, toks_b, rids):
    """Human-readable per-request divergence lines for two token-list
    sets (empty when identical).  Benches print these and exit non-zero
    instead of tripping a bare assert, so CI failures are diagnosable
    from the log."""
    lines = []
    if not (len(toks_a) == len(toks_b) == len(rids)):
        lines.append(
            f"  result-count mismatch: {name_a} has {len(toks_a)}, "
            f"{name_b} has {len(toks_b)}, trace has {len(rids)} requests")
    for ta, tb, rid in zip(toks_a, toks_b, rids):
        if ta == tb:
            continue
        k = 0
        while k < min(len(ta), len(tb)) and ta[k] == tb[k]:
            k += 1
        lines.append(
            f"  rid={rid}: first divergence at token {k} "
            f"({name_a}[{k}:{k + 4}]={ta[k:k + 4]} vs "
            f"{name_b}[{k}:{k + 4}]={tb[k:k + 4]}; "
            f"lengths {len(ta)} vs {len(tb)})")
    return lines


def check_tokens(label: str, name_a: str, toks_a, name_b: str, toks_b,
                 rids):
    """Exit non-zero with a diff summary when two token sets mismatch."""
    lines = token_diff_summary(name_a, toks_a, name_b, toks_b, rids)
    if lines:
        print(f"[{label}] TOKEN MISMATCH: {name_a} vs {name_b} "
              f"({len(lines)} of {len(rids)} requests diverge)",
              file=sys.stderr)
        for ln in lines:
            print(ln, file=sys.stderr)
        sys.exit(1)


def emit(name: str, us_per_call, derived):
    us = f"{us_per_call:.1f}" if isinstance(us_per_call, float) else us_per_call
    _ROWS.append({"name": name, "us_per_call": us, "derived": derived})
    print(f"{name},{us},{derived}")
    sys.stdout.flush()


def reset_rows() -> None:
    """Start a fresh row log (benches call this at the top of run(), so a
    prior in-process bench that never wrote JSON cannot leak rows into
    this one's artifact)."""
    _ROWS.clear()


def path_arg(argv, flag: str) -> str | None:
    """Pull a ``FLAG PATH`` value out of a bench's argv (None when the
    flag is absent; a missing value is a clear error, not an IndexError)."""
    if flag not in argv:
        return None
    i = argv.index(flag)
    if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
        sys.exit(f"{flag} needs a file path argument")
    return argv[i + 1]


def json_path_arg(argv) -> str | None:
    return path_arg(argv, "--json")


def write_json(path: str, **extra) -> None:
    """Dump every row emitted since the last write (plus bench-specific
    ``extra`` key/values) as JSON — the CI workflow uploads these as
    artifacts so a regression's numbers are diffable without scraping
    logs.  Clears the accumulator (paired with ``reset_rows`` at run()
    entry, two benches in one process each dump only their own rows)."""
    rows = list(_ROWS)
    _ROWS.clear()
    with open(path, "w") as f:
        json.dump({"rows": rows, **extra}, f, indent=2, sort_keys=True)
    print(f"[bench] wrote {path} ({len(rows)} rows)", file=sys.stderr)
