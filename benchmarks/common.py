"""Benchmark utilities: timing + CSV emission (name,us_per_call,derived)."""
import sys
import time

import jax


def timeit(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def emit(name: str, us_per_call, derived):
    us = f"{us_per_call:.1f}" if isinstance(us_per_call, float) else us_per_call
    print(f"{name},{us},{derived}")
    sys.stdout.flush()
