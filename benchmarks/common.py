"""Benchmark utilities: timing + CSV emission (name,us_per_call,derived)."""
import sys
import time

import jax


def timeit(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def token_diff_summary(name_a: str, toks_a, name_b: str, toks_b, rids):
    """Human-readable per-request divergence lines for two token-list
    sets (empty when identical).  Benches print these and exit non-zero
    instead of tripping a bare assert, so CI failures are diagnosable
    from the log."""
    lines = []
    if not (len(toks_a) == len(toks_b) == len(rids)):
        lines.append(
            f"  result-count mismatch: {name_a} has {len(toks_a)}, "
            f"{name_b} has {len(toks_b)}, trace has {len(rids)} requests")
    for ta, tb, rid in zip(toks_a, toks_b, rids):
        if ta == tb:
            continue
        k = 0
        while k < min(len(ta), len(tb)) and ta[k] == tb[k]:
            k += 1
        lines.append(
            f"  rid={rid}: first divergence at token {k} "
            f"({name_a}[{k}:{k + 4}]={ta[k:k + 4]} vs "
            f"{name_b}[{k}:{k + 4}]={tb[k:k + 4]}; "
            f"lengths {len(ta)} vs {len(tb)})")
    return lines


def check_tokens(label: str, name_a: str, toks_a, name_b: str, toks_b,
                 rids):
    """Exit non-zero with a diff summary when two token sets mismatch."""
    lines = token_diff_summary(name_a, toks_a, name_b, toks_b, rids)
    if lines:
        print(f"[{label}] TOKEN MISMATCH: {name_a} vs {name_b} "
              f"({len(lines)} of {len(rids)} requests diverge)",
              file=sys.stderr)
        for ln in lines:
            print(ln, file=sys.stderr)
        sys.exit(1)


def emit(name: str, us_per_call, derived):
    us = f"{us_per_call:.1f}" if isinstance(us_per_call, float) else us_per_call
    print(f"{name},{us},{derived}")
    sys.stdout.flush()
