"""Paper Fig 3 + Table 5: slide-unit interconnect cost model and measured
area scaling (old all-to-all vs optimized power-of-two SLDU)."""
from repro.core import mux_count, sldu_saving
from repro.core.ppa import AREA_KGE, sldu_area_saving, system_area_kge

from benchmarks.common import emit


def run():
    for lanes in (2, 4, 8, 16):
        a2a = mux_count(lanes, "all_to_all")
        p2 = mux_count(lanes, "slideP2_tmux")
        s1 = mux_count(lanes, "slide1")
        emit(f"fig3/muxes_L{lanes}", 0.0,
             f"a2a={a2a}|slideP2={p2}|slide1={s1}|saving={sldu_saving(lanes):.2%}")
    for lanes in (2, 4, 8, 16):
        emit(f"table5/sldu_L{lanes}", 0.0,
             f"old={AREA_KGE['old_sldu'][lanes]}kGE|"
             f"new={AREA_KGE['new_sldu'][lanes]}kGE|"
             f"saving={sldu_area_saving(lanes):.2%}")
    for lanes in (2, 4, 8, 16):
        emit(f"table5/system_L{lanes}", 0.0,
             f"new_sldu={system_area_kge(lanes, 'new_sldu'):.0f}kGE|"
             f"old_sldu={system_area_kge(lanes, 'old_sldu'):.0f}kGE")
