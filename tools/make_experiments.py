"""Generate EXPERIMENTS.md from results/dryrun/*.json + results/perf_log.md.

  PYTHONPATH=src python tools/make_experiments.py
"""
import glob
import json
import os
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(ROOT, "src"))

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load():
    recs = {}
    for f in glob.glob(os.path.join(ROOT, "results", "dryrun", "*.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_cell(r, arch=None, shape=None):
    if r is None:
        # skip records aren't persisted; re-derive applicability
        if arch and shape:
            from repro.configs import get_config, SHAPES, cell_applicable
            ok, _ = cell_applicable(get_config(arch), SHAPES[shape])
            if not ok:
                return "skip"
        return "—"
    if r["status"] == "skipped":
        return "skip"
    if r["status"] != "ok":
        return "ERR"
    return f"{r['hbm_used_gb']:.1f}GB"


def dryrun_section(recs):
    from repro.configs import list_archs
    out = ["## §Dry-run", "",
           "Every assigned (arch × shape) cell lowered + compiled with full "
           "in/out shardings on the production meshes (single-pod "
           "`(data=16, model=16)` = 256 chips and multi-pod "
           "`(pod=2, data=16, model=16)` = 512; 512 forced host devices).",
           "Cell values: `memory_analysis()` bytes/device "
           "(args+outputs+temps−aliased). v5e budget: 16 GB/chip.",
           "`long_500k` runs only for the sub-quadratic archs (zamba2, "
           "xlstm); the 8 full-attention archs skip it by design "
           "(DESIGN.md §4).", ""]
    for mesh in ("data16xmodel16", "pod2xdata16xmodel16"):
        out.append(f"### mesh `{mesh}`")
        out.append("")
        out.append("| arch | " + " | ".join(SHAPE_ORDER) + " |")
        out.append("|---" * (len(SHAPE_ORDER) + 1) + "|")
        for arch in list_archs():
            row = [fmt_cell(recs.get((arch, s, mesh)), arch, s)
                   for s in SHAPE_ORDER]
            out.append(f"| {arch} | " + " | ".join(row) + " |")
        out.append("")
    # collective schedule summary
    out.append("### Collective schedules (per-device bytes/step, single-pod)")
    out.append("")
    out.append("| cell | all-gather | all-reduce | reduce-scatter | "
               "all-to-all | collective-permute |")
    out.append("|---|---|---|---|---|---|")
    for (arch, shape, mesh), r in sorted(recs.items()):
        if mesh != "data16xmodel16" or r["status"] != "ok":
            continue
        bd = r["roofline"]["coll_breakdown"]
        out.append(
            f"| {arch}/{shape} | "
            + " | ".join(f"{bd.get(k, 0)/1e9:.2f}G" for k in
                         ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute")) + " |")
    out.append("")
    return out


def roofline_section(recs):
    out = ["## §Roofline", "",
           "Terms per the spec (v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s "
           "ICI/link): `compute = HLO_FLOPs/dev ÷ peak`, `memory = "
           "HLO_bytes/dev ÷ HBM_bw`, `collective = coll_bytes/dev ÷ "
           "link_bw`, all in seconds/step. FLOPs/bytes come from the "
           "while-trip-scaled HLO parser (`repro.roofline.hlo_cost`): this "
           "jax build's `cost_analysis()` counts scan bodies once, which "
           "would undercount every layer stack ~n_layers× (verified). "
           "`useful` = MODEL_FLOPS ÷ (HLO_FLOPs × chips); `frac` = "
           "MODEL_FLOPS/(t_bound × cluster peak) — the roofline fraction. "
           "Single-pod mesh (per spec).", "",
           "| cell | t_comp | t_mem | t_coll | dominant | useful | frac | "
           "one-line lever |",
           "|---|---|---|---|---|---|---|---|"]
    levers = {
        "compute": "more MXU-efficient attention/expert tiling (pallas)",
        "memory": "pallas flash/SSD kernels keep score+state traffic in "
                  "VMEM; fuse elementwise chains",
        "collective": "shrink FSDP gathers (bigger per-step tokens) or "
                      "overlap grad RS/AG with bwd compute",
    }
    for shape in SHAPE_ORDER:
        for (arch, s, mesh), r in sorted(recs.items()):
            if s != shape or mesh != "data16xmodel16" or r["status"] != "ok":
                continue
            rf = r["roofline"]
            out.append(
                f"| {arch}/{s} | {rf['t_compute']:.3f} | "
                f"{rf['t_memory']:.3f} | {rf['t_collective']:.3f} | "
                f"{rf['dominant']} | {rf['useful_flops_fraction']:.2f} | "
                f"{rf['roofline_fraction']:.4f} | {levers[rf['dominant']]} |")
    out.append("")
    out.append(
        "Reading the table: decode cells are memory-dominant by physics "
        "(weight+cache streaming per token); their roofline *fraction of "
        "compute peak* is inherently small and the right metric there is "
        "t_mem vs the cache+weights bytes lower bound. The CPU-lowered XLA "
        "path overstates memory traffic vs the TPU+Pallas target (flash/SSD "
        "keep score traffic in VMEM; CPU fusion is weaker) — the Pallas "
        "kernels in `src/repro/kernels/` are the deployment path for the "
        "memory-dominant terms.")
    out.append("")
    return out


def main():
    recs = load()
    parts = [
        "# EXPERIMENTS", "",
        "Reproduction of *Ara2: Exploring Single- and Multi-Core Vector "
        "Processing...* (TC 2024) as a JAX/TPU framework + the assigned "
        "10-arch × 4-shape production matrix. See DESIGN.md for the "
        "paper→TPU mapping.", "",
    ]
    # paper validation
    parts += [
        "## §Paper-validation", "",
        "The paper-faithful layer (perf model + PPA tables + kernels) "
        "reproduces the paper's printed claims; each is pinned by a test "
        "in `tests/test_paper_claims.py` / `tests/test_core.py` "
        "(all green in test_output.txt):", "",
        "| paper claim | source | ours |",
        "|---|---|---|",
    ]
    from repro.core import (energy_efficiency_gflops_w, ideality,
                            issue_rate_limit_opc, matmul_opc, mux_count,
                            pool_average_ideality, sldu_saving,
                            dotproduct_speedup_vs_scalar)
    from repro.core.ppa import sldu_area_saving
    from repro.core.vector_engine import ClusterConfig, VectorEngineConfig
    e2, e4, e16 = (VectorEngineConfig(n_lanes=l) for l in (2, 4, 16))
    rows = [
        ("16 DP-FLOP/cycle issue bound at 32³ (§7.1)", "16",
         f"{issue_rate_limit_opc(32):.1f}"),
        ("matmul ≥95% ideality from 128 B/lane (§5.2)", "≥0.95",
         f"{ideality('matmul', 128*4, e4):.3f}"),
        ("matmul ≥75% from 64 B/lane (§5.2)", "≥0.75",
         f"{ideality('matmul', 64*4, e4):.3f}"),
        ("pool average ≥50% from 128 B/lane (§5.2)", "≥0.50",
         f"{pool_average_ideality(128, e4):.3f}"),
        ("8×2L ≈23.6 DP-FLOP/cycle at 32³ (§7.1)", "23.6",
         f"{matmul_opc(32, ClusterConfig(8, e2)):.1f}"),
        ("8×2L > 3× 1×16L at 32³ (abstract)", ">3×",
         f"{matmul_opc(32, ClusterConfig(8, e2)) / matmul_opc(32, ClusterConfig(1, e16)):.2f}×"),
        ("SLDU interconnect saving ~70% predicted (Fig 3)", "~0.70",
         f"{sldu_saving(16):.2f}"),
        ("SLDU area saving ≥83% measured at 8L (§6)", "0.837",
         f"{sldu_area_saving(8):.3f}"),
        ("4×4L most efficient, ≈39 GFLOPS/W at 256³ (§7.2)", "39.2",
         f"{energy_efficiency_gflops_w(256, ClusterConfig(4, VectorEngineConfig(n_lanes=4))):.1f}"),
        ("2-lane dot speedup vs CVA6: 1.4× fp / 2.2× int (§8.1)",
         "1.4 / 2.2",
         f"{dotproduct_speedup_vs_scalar(128, e2, 'fp'):.2f} / "
         f"{dotproduct_speedup_vs_scalar(128, e2, 'int'):.2f}"),
    ]
    parts += [f"| {a} | {b} | {c} |" for a, b, c in rows]
    parts += ["",
              "Known modeling deviation: Fig 15's '16L overtakes 8×2L at "
              "256³' is not reproduced by our power anchors "
              "(core/ppa.py docstring).", ""]
    parts += dryrun_section(recs)
    parts += roofline_section(recs)
    # Perf section: the iteration log verbatim + summary
    parts += ["## §Perf", "",
              "Method: hypothesis → change → re-lower/re-analyse → "
              "confirm/refute, iterating on the dominant roofline term of "
              "the three hillclimb cells (worst-fraction: qwen3-moe "
              "train_4k; most collective-bound: qwen3-moe/granite train; "
              "most paper-representative: qwen3-0.6b train_4k, the C1–C4 "
              "stack). The paper-faithful baseline (It.0) and every "
              "beyond-paper step are recorded separately below.", ""]
    log = open(os.path.join(ROOT, "results", "perf_log.md")).read()
    parts.append(log[log.index("\n") + 1:])
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write("\n".join(parts))
    print("wrote EXPERIMENTS.md",
          f"({sum(1 for r in recs.values() if r['status']=='ok')} ok cells)")


if __name__ == "__main__":
    main()
