"""Markdown link-check + lint for the repo docs (stdlib only; CI gate).

Checks, over README.md, ROADMAP.md, and docs/**/*.md:

  * every relative link target exists on disk (``[text](path)`` and
    ``[text](path#anchor)``);
  * every in-document / cross-document ``#anchor`` resolves to a heading
    (GitHub slug rules: lowercase, spaces -> dashes, punctuation
    stripped);
  * fenced code blocks are balanced (an unclosed ``` renders half the
    page as code);
  * no literal tab characters (GitHub renders them 8 wide and breaks
    table alignment).

http(s) links are *not* fetched (CI must stay hermetic); they are only
required to be non-empty.

Exit status is the number of problems found; problems print as
``path:line: message`` so editors and CI logs can jump to them.
"""
from __future__ import annotations

import functools
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = ["README.md", "ROADMAP.md", "PAPER.md", "PAPERS.md",
             "CHANGES.md", "ISSUE.md"]

LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$")


def doc_paths() -> list[pathlib.Path]:
    out = [ROOT / f for f in DOC_FILES if (ROOT / f).exists()]
    out += sorted((ROOT / "docs").glob("**/*.md"))
    return out


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markdown emphasis/code/links, lowercase,
    drop punctuation, spaces to dashes."""
    h = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)   # [t](u) -> t
    h = h.replace("`", "").replace("*", "").strip()   # underscores survive
    h = h.lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


@functools.lru_cache(maxsize=None)
def headings_of(path: pathlib.Path) -> set[str]:
    slugs: set[str] = set()
    counts: dict[str, int] = {}
    in_fence = False
    for line in path.read_text().splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = github_slug(m.group(2))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def check_file(path: pathlib.Path, problems: list[str]) -> None:
    text = path.read_text()
    rel = path.relative_to(ROOT)
    fence_depth = 0
    in_fence = False
    for i, line in enumerate(text.splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            fence_depth += 1
        if "\t" in line:
            problems.append(f"{rel}:{i}: literal tab character")
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            base, _, anchor = target.partition("#")
            if base:
                dest = (path.parent / base).resolve()
                if not dest.exists():
                    problems.append(
                        f"{rel}:{i}: broken link target {target!r}")
                    continue
            else:
                dest = path
            if anchor:
                if dest.suffix != ".md" or dest.is_dir():
                    continue        # anchors into code files: not checked
                if anchor not in headings_of(dest):
                    problems.append(
                        f"{rel}:{i}: anchor #{anchor} not found in "
                        f"{dest.relative_to(ROOT)}")
    if fence_depth % 2:
        problems.append(f"{rel}: unbalanced ``` code fence")


def main() -> int:
    paths = doc_paths()
    problems: list[str] = []
    for p in paths:
        check_file(p, problems)
    for msg in problems:
        print(msg, file=sys.stderr)
    print(f"[check_docs] {len(paths)} files, {len(problems)} problems")
    return min(len(problems), 125)


if __name__ == "__main__":
    sys.exit(main())
