"""Chrome-trace-event JSON validator (stdlib only; CI gate).

Validates the Perfetto-loadable traces emitted by
``repro.serving.telemetry.Tracer.export`` (see docs/observability.md):

  * the file is valid JSON with a non-empty ``traceEvents`` array;
  * every event carries the fields its phase requires (``ph``, ``pid``,
    ``tid``, ``ts``; ``dur`` for complete events, ``id`` for flows,
    ``s`` scope for instants, ``args`` for counters and metadata);
  * complete-event durations are non-negative;
  * any legacy ``B``/``E`` begin/end pairs balance per (pid, tid);
  * ``--min-replica-tracks N`` — at least N distinct ``replica<i>``
    tracks are named via thread_name metadata (cluster traces);
  * ``--require-flow`` — at least one flow exists and every flow id's
    starts (``s``) match its ends (``f``);
  * ``--require-pool`` — the block-pool watermark counter (``blocks``)
    is present.

Exit status is the number of problems found; problems print as
``path: message`` so CI logs can jump to them.
"""
from __future__ import annotations

import argparse
import collections
import json
import pathlib
import re
import sys

REPLICA_RE = re.compile(r"^replica\d+$")

# phase -> extra required fields beyond ph/pid/tid/ts (metadata aside)
_PH_FIELDS = {
    "X": ("dur", "name"),
    "i": ("s", "name"),
    "I": ("s", "name"),
    "C": ("args", "name"),
    "s": ("id", "name"),
    "f": ("id", "name"),
    "B": ("name",),
    "E": (),
    "M": ("name", "args"),
}


def validate(path: pathlib.Path, *, min_replica_tracks: int = 0,
             require_flow: bool = False,
             require_pool: bool = False) -> list[str]:
    """Return the list of problems with the trace at ``path``."""
    problems: list[str] = []
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable trace: {e}"]
    events = doc.get("traceEvents") if isinstance(doc, dict) else doc
    if not isinstance(events, list):
        return ["no traceEvents array"]
    if not events:
        return ["traceEvents is empty"]

    thread_names: dict[tuple, str] = {}
    flow_starts: collections.Counter = collections.Counter()
    flow_ends: collections.Counter = collections.Counter()
    be_depth: collections.Counter = collections.Counter()
    n_spans = n_flows = 0
    saw_pool_counter = False

    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PH_FIELDS:
            problems.append(f"event {i}: unknown phase {ph!r}")
            continue
        for field in ("pid", "tid", "ts"):
            if ph != "M" and field not in ev:
                problems.append(f"event {i} (ph={ph}): missing {field!r}")
        for field in _PH_FIELDS[ph]:
            if field not in ev:
                problems.append(f"event {i} (ph={ph}): missing {field!r}")
        if ph == "X":
            n_spans += 1
            if ev.get("dur", 0) < 0:
                problems.append(
                    f"event {i}: negative dur {ev['dur']} "
                    f"({ev.get('name')!r})")
        elif ph == "M" and ev.get("name") == "thread_name":
            name = (ev.get("args") or {}).get("name", "")
            thread_names[(ev.get("pid"), ev.get("tid"))] = name
        elif ph == "s":
            n_flows += 1
            flow_starts[ev.get("id")] += 1
        elif ph == "f":
            flow_ends[ev.get("id")] += 1
            if ev.get("bp") != "e":
                problems.append(
                    f"event {i}: flow end without bp='e' "
                    f"(id={ev.get('id')!r})")
        elif ph == "B":
            be_depth[(ev.get("pid"), ev.get("tid"))] += 1
        elif ph == "E":
            be_depth[(ev.get("pid"), ev.get("tid"))] -= 1
        elif ph == "C" and ev.get("name") == "blocks":
            saw_pool_counter = True

    if n_spans == 0:
        problems.append("no complete ('X') span events")
    for (pid, tid), depth in be_depth.items():
        if depth != 0:
            problems.append(
                f"unbalanced B/E events on pid={pid} tid={tid}: "
                f"depth {depth}")
    for fid in flow_starts.keys() | flow_ends.keys():
        if flow_starts[fid] != flow_ends[fid]:
            problems.append(
                f"flow id {fid!r}: {flow_starts[fid]} start(s) vs "
                f"{flow_ends[fid]} end(s)")

    if min_replica_tracks:
        replicas = {n for n in thread_names.values() if REPLICA_RE.match(n)}
        if len(replicas) < min_replica_tracks:
            problems.append(
                f"expected >= {min_replica_tracks} replica tracks, "
                f"found {sorted(replicas)}")
    if require_flow and n_flows == 0:
        problems.append("no flow ('s'/'f') events (expected preemption "
                        "flow arrows)")
    if require_pool and not saw_pool_counter:
        problems.append("no 'blocks' pool-watermark counter events")
    return problems


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", type=pathlib.Path, nargs="+")
    ap.add_argument("--min-replica-tracks", type=int, default=0)
    ap.add_argument("--require-flow", action="store_true")
    ap.add_argument("--require-pool", action="store_true")
    args = ap.parse_args(argv)
    n = 0
    for path in args.trace:
        problems = validate(path,
                            min_replica_tracks=args.min_replica_tracks,
                            require_flow=args.require_flow,
                            require_pool=args.require_pool)
        for p in problems:
            print(f"{path}: {p}")
        if not problems:
            print(f"{path}: OK")
        n += len(problems)
    return n


if __name__ == "__main__":
    sys.exit(main())
