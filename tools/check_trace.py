"""Chrome-trace-event JSON validator (stdlib only; CI gate).

Validates the Perfetto-loadable traces emitted by
``repro.serving.telemetry.Tracer.export`` (see docs/observability.md):

  * the file is valid JSON with a non-empty ``traceEvents`` array;
  * every event carries the fields its phase requires (``ph``, ``pid``,
    ``tid``, ``ts``; ``dur`` for complete events, ``id`` for flows,
    ``s`` scope for instants, ``args`` for counters and metadata);
  * complete-event durations are non-negative;
  * any legacy ``B``/``E`` begin/end pairs balance per (pid, tid);
  * ``--min-replica-tracks N`` — at least N distinct ``replica<i>``
    tracks are named via thread_name metadata (cluster traces);
  * ``--require-flow`` — at least one flow exists and every flow id's
    starts (``s``) match its ends (``f``);
  * ``--require-pool`` — the block-pool watermark counter (``blocks``)
    is present;
  * ``--require-roofline`` — the attribution counter track
    (``roofline``; achieved-vs-peak percent series, see
    docs/observability.md) is present;
  * unless ``--skip-lifecycle``: the events are decoded back into the
    host-side representation and run through the same
    ``validate_lifecycle`` conformance check the property suite applies
    to in-process streams (admits precede decodes, preempts answered,
    per-request KV acquisitions balance releases) — so an exported
    trace is held to the identical lifecycle contract as a live one,
    in one validation path instead of two.

``validate_lifecycle`` is imported from
``src/repro/serving/telemetry.py`` by file path: that module is
deliberately stdlib-only, so this tool stays runnable before any heavy
dependency is installed.

Exit status is the number of problems found; problems print as
``path: message`` so CI logs can jump to them.
"""
from __future__ import annotations

import argparse
import collections
import importlib.util
import json
import pathlib
import re
import sys

REPLICA_RE = re.compile(r"^replica\d+$")

# phase -> extra required fields beyond ph/pid/tid/ts (metadata aside)
_PH_FIELDS = {
    "X": ("dur", "name"),
    "i": ("s", "name"),
    "I": ("s", "name"),
    "C": ("args", "name"),
    "s": ("id", "name"),
    "f": ("id", "name"),
    "B": ("name",),
    "E": (),
    "M": ("name", "args"),
}


def _load_telemetry():
    """Import ``repro.serving.telemetry`` by file path (stdlib-only by
    design — see module doc), without touching the package __init__
    (which pulls in the model stack)."""
    name = "_check_trace_telemetry"
    if name in sys.modules:
        return sys.modules[name]
    here = pathlib.Path(__file__).resolve().parent
    src = here.parent / "src" / "repro" / "serving" / "telemetry.py"
    spec = importlib.util.spec_from_file_location(name, src)
    mod = importlib.util.module_from_spec(spec)
    # dataclass processing resolves cls.__module__ through sys.modules,
    # so the module must be registered before exec
    sys.modules[name] = mod
    try:
        spec.loader.exec_module(mod)
    except BaseException:
        del sys.modules[name]
        raise
    return mod


def decode_events(events: list[dict], telemetry=None) -> list:
    """Rebuild host-side telemetry ``Event`` objects from exported
    Chrome rows (the inverse of ``Tracer.chrome_trace``): thread_name
    metadata maps tids back to track strings, timestamps and durations
    drop from microseconds back to seconds, flow ids come off ``id``.
    Metadata rows are skipped; unknown phases are ignored (the schema
    pass reports those)."""
    tel = telemetry if telemetry is not None else _load_telemetry()
    tracks = {}
    for ev in events:
        if not isinstance(ev, dict):
            continue
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            tracks[(ev.get("pid"), ev.get("tid"))] = \
                (ev.get("args") or {}).get("name", "")
    out = []
    for ev in events:
        if not isinstance(ev, dict):
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "I", "C", "s", "f"):
            continue
        track = tracks.get((ev.get("pid"), ev.get("tid")), "")
        name = ev.get("name", "")
        ts = float(ev.get("ts", 0.0)) / 1e6
        if ph == "X":
            out.append(tel.Event("X", track, name, ts,
                                 float(ev.get("dur", 0.0)) / 1e6,
                                 ev.get("args") or {}))
        elif ph in ("i", "I"):
            out.append(tel.Event("i", track, name, ts, 0.0,
                                 ev.get("args") or {}))
        elif ph == "C":
            out.append(tel.Event("C", track, name, ts, 0.0,
                                 ev.get("args") or {}))
        else:                       # "s" / "f"
            out.append(tel.Event(ph, track, name, ts, 0.0, {},
                                 str(ev.get("id", ""))))
    return out


def validate(path: pathlib.Path, *, min_replica_tracks: int = 0,
             require_flow: bool = False,
             require_pool: bool = False,
             require_roofline: bool = False,
             lifecycle: bool = True) -> list[str]:
    """Return the list of problems with the trace at ``path``."""
    problems: list[str] = []
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable trace: {e}"]
    events = doc.get("traceEvents") if isinstance(doc, dict) else doc
    if not isinstance(events, list):
        return ["no traceEvents array"]
    if not events:
        return ["traceEvents is empty"]

    thread_names: dict[tuple, str] = {}
    flow_starts: collections.Counter = collections.Counter()
    flow_ends: collections.Counter = collections.Counter()
    be_depth: collections.Counter = collections.Counter()
    n_spans = n_flows = 0
    saw_pool_counter = saw_roofline = False

    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PH_FIELDS:
            problems.append(f"event {i}: unknown phase {ph!r}")
            continue
        for field in ("pid", "tid", "ts"):
            if ph != "M" and field not in ev:
                problems.append(f"event {i} (ph={ph}): missing {field!r}")
        for field in _PH_FIELDS[ph]:
            if field not in ev:
                problems.append(f"event {i} (ph={ph}): missing {field!r}")
        if ph == "X":
            n_spans += 1
            if ev.get("dur", 0) < 0:
                problems.append(
                    f"event {i}: negative dur {ev['dur']} "
                    f"({ev.get('name')!r})")
        elif ph == "M" and ev.get("name") == "thread_name":
            name = (ev.get("args") or {}).get("name", "")
            thread_names[(ev.get("pid"), ev.get("tid"))] = name
        elif ph == "s":
            n_flows += 1
            flow_starts[ev.get("id")] += 1
        elif ph == "f":
            flow_ends[ev.get("id")] += 1
            if ev.get("bp") != "e":
                problems.append(
                    f"event {i}: flow end without bp='e' "
                    f"(id={ev.get('id')!r})")
        elif ph == "B":
            be_depth[(ev.get("pid"), ev.get("tid"))] += 1
        elif ph == "E":
            be_depth[(ev.get("pid"), ev.get("tid"))] -= 1
        elif ph == "C":
            if ev.get("name") == "blocks":
                saw_pool_counter = True
            elif ev.get("name") == "roofline":
                saw_roofline = True

    if n_spans == 0:
        problems.append("no complete ('X') span events")
    for (pid, tid), depth in be_depth.items():
        if depth != 0:
            problems.append(
                f"unbalanced B/E events on pid={pid} tid={tid}: "
                f"depth {depth}")
    for fid in flow_starts.keys() | flow_ends.keys():
        if flow_starts[fid] != flow_ends[fid]:
            problems.append(
                f"flow id {fid!r}: {flow_starts[fid]} start(s) vs "
                f"{flow_ends[fid]} end(s)")

    if min_replica_tracks:
        replicas = {n for n in thread_names.values() if REPLICA_RE.match(n)}
        if len(replicas) < min_replica_tracks:
            problems.append(
                f"expected >= {min_replica_tracks} replica tracks, "
                f"found {sorted(replicas)}")
    if require_flow and n_flows == 0:
        problems.append("no flow ('s'/'f') events (expected preemption "
                        "flow arrows)")
    if require_pool and not saw_pool_counter:
        problems.append("no 'blocks' pool-watermark counter events")
    if require_roofline and not saw_roofline:
        problems.append("no 'roofline' attribution counter events "
                        "(achieved-vs-peak track)")
    if lifecycle:
        # same contract as the in-process property checks, applied to
        # the exported stream (schema problems above don't block this:
        # decode skips what it cannot interpret)
        try:
            tel = _load_telemetry()
            tel.validate_lifecycle(decode_events(events, tel))
        except AssertionError as e:
            problems.append(f"lifecycle: {e}")
        except Exception as e:     # import/decoding failure is a problem
            problems.append(f"lifecycle check unavailable: {e!r}")
    return problems


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", type=pathlib.Path, nargs="+")
    ap.add_argument("--min-replica-tracks", type=int, default=0)
    ap.add_argument("--require-flow", action="store_true")
    ap.add_argument("--require-pool", action="store_true")
    ap.add_argument("--require-roofline", action="store_true")
    ap.add_argument("--skip-lifecycle", action="store_true",
                    help="schema checks only (for traces from foreign "
                         "tools that don't follow the lifecycle taxonomy)")
    args = ap.parse_args(argv)
    n = 0
    for path in args.trace:
        problems = validate(path,
                            min_replica_tracks=args.min_replica_tracks,
                            require_flow=args.require_flow,
                            require_pool=args.require_pool,
                            require_roofline=args.require_roofline,
                            lifecycle=not args.skip_lifecycle)
        for p in problems:
            print(f"{path}: {p}")
        if not problems:
            print(f"{path}: OK")
        n += len(problems)
    return n


if __name__ == "__main__":
    sys.exit(main())
